"""Prefill jit churn vs bucketed pad-aware prefill.

Both engines compile prefill once per distinct context shape. Without
bucketing, a heterogeneous traffic mix (the StraightLine setting: many apps,
many prompt lengths, preemption-resume multiplying lengths further) pays a
full XLA compile on the FIRST request at every new length — exactly the
time-to-first-token tail the placer is supposed to eliminate. Bucketing
right-pads every context to a power-of-two page multiple, so compilation is
O(num_buckets) and the tail disappears after warm-up.

This benchmark serves one request per distinct prompt length through each
engine with bucketing off/on and reports compile events plus p50/p99
time-to-first-token (the step that performs admission prefill).

    PYTHONPATH=src:. python benchmarks/prefill_churn.py
"""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core.telemetry import percentile

PAGE = 4
MAX_SEQ = 64
LENGTHS = list(range(1, 19))     # 18 distinct prompt lengths
NEW = 2


def _serve_lengths(eng):
    """One request per length, measuring the admission step's wall time."""
    ttfts = []
    for L in LENGTHS:
        eng.submit([1 + (i % (eng.cfg.vocab_size - 1)) for i in range(L)])
        t0 = time.perf_counter()
        out = eng.step()                           # admit + prefill (+ decode)
        ttfts.append(time.perf_counter() - t0)
        for _ in range(50):
            if out:
                break
            out = eng.step()
    return ttfts


def _engines(cfg, params, bucket: bool):
    from repro.serving.engine import (
        EngineConfig,
        InferenceEngine,
        PagedEngineConfig,
        PagedInferenceEngine,
    )

    paged = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PAGE, num_pages=1 + MAX_SEQ // PAGE, max_slots=2,
                          max_seq_len=MAX_SEQ, max_new_tokens=NEW, bucket_prefill=bucket),
        params=params,
    )
    dense = InferenceEngine(
        cfg,
        EngineConfig(max_slots=2, max_len=MAX_SEQ, max_new_tokens=NEW,
                     bucket_unit=PAGE, bucket_prefill=bucket),
        params=paged.params,
    )
    return {"paged": paged, "dense": dense}


def main() -> None:
    from repro.configs.registry import get_config
    from repro.serving.paging import num_buckets

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    bound = num_buckets(PAGE, MAX_SEQ)
    results = {}
    params = None
    for bucket in (False, True):
        for name, eng in _engines(cfg, params, bucket).items():
            params = eng.params
            ttfts = _serve_lengths(eng)
            key = f"{name}.{'bucketed' if bucket else 'per_length'}"
            results[key] = (eng.compile_events, ttfts)
            emit(
                f"prefill_churn.{key}",
                percentile(ttfts, 50) * 1e6,
                f"compile_events={eng.compile_events};"
                f"p99_ttft_us={percentile(ttfts, 99) * 1e6:.0f};"
                f"lengths={len(LENGTHS)}",
            )

    for name in ("paged", "dense"):
        churn, _ = results[f"{name}.per_length"]
        bucketed, _ = results[f"{name}.bucketed"]
        assert churn == len(LENGTHS), (name, churn)
        assert bucketed <= bound, (name, bucketed, bound)
        print(
            f"{name}: {churn} prefill compiles for {len(LENGTHS)} lengths without "
            f"bucketing -> {bucketed} (bound {bound}) with bucketing"
        )
    print("OK — prefill compilation is O(num_buckets), not O(distinct lengths)")


if __name__ == "__main__":
    main()
