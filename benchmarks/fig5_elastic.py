"""Paper Fig 5: elastic tier with 2 GB vs 3 GB memory classes. Claims:
failed rate drops with provisioned memory; median response ~flat in load."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import SimConfig, Simulation, StaticPolicy, Tier
from repro.core.testbed import paper_tiers
from repro.core.workload import ramp

LOADS = [500, 2000, 4000, 6000]


def main() -> None:
    for mem in ("2GB", "3GB"):
        for load in LOADS:
            sim = Simulation(
                StaticPolicy(Tier.SERVERLESS), paper_tiers(seed=1, elastic_mem=mem), SimConfig()
            )
            s = sim.run(ramp(load, seed=load)).summary()
            emit(
                f"fig5.elastic.{mem}.load{load}",
                s["median_response_s"] * 1e6,
                f"fail_rate={s['failure_rate']:.3f};p95_s={s['p95_response_s']:.2f}",
            )


if __name__ == "__main__":
    main()
