"""Paper Fig 3: model-development convergence on two resource profiles.

The paper trains Xception on a CPU vs GPU cluster and reports that the GPU
cluster reaches stable accuracy in 1-2 epochs vs 9-10. The analogue here:
the same reduced LM trained under a small-batch profile (CPU-class) and a
large-batch profile (accelerator-class); the large-batch profile reaches the
loss target in fewer optimizer steps. Also trains the Xception-analog
classifier itself (the paper's own app model)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.registry import get_config
from repro.models import get_model
from repro.models.xception import XceptionConfig, init, loss_fn
from repro.train.data import DataConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def lm_profile(name: str, batch: int, steps: int) -> None:
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=16, ce_chunks=2)
    model = get_model(cfg)
    tr = Trainer(
        model, None,
        TrainConfig(steps=steps, ckpt_every=10**9, ckpt_dir=None, log_every=1, opt=OptConfig(lr=2e-3)),
        DataConfig(batch_size=batch, seq_len=32, vocab_size=cfg.vocab_size, seed=5),
    )
    r = tr.run(seed=0)
    losses = [h["loss"] for h in r["history"]]
    target = 4.5
    hit = next((h["step"] for h in r["history"] if h["loss"] < target), -1)
    emit(
        f"fig3.lm.{name}",
        r["wall_s"] / max(1, r["steps_done"]) * 1e6,
        f"loss0={losses[0]:.3f};lossN={losses[-1]:.3f};steps_to_{target}={hit}",
    )


def xception_train() -> None:
    cfg = XceptionConfig(img_size=32, width=16, n_blocks=2)
    params = init(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    # 4-class synthetic image task (class-dependent color bias => learnable)
    def batch(step):
        lab = rng.integers(0, 4, 32)
        img = rng.normal(0, 1, (32, cfg.img_size, cfg.img_size, 3)).astype(np.float32)
        img[..., 0] += lab[:, None, None] * 1.5
        return jnp.asarray(img), jnp.asarray(lab)

    opt_lr = 1e-2

    @jax.jit
    def step(params, img, lab):
        (l, m), g = jax.value_and_grad(lambda p: loss_fn(cfg, p, img, lab), has_aux=True)(params)
        params = jax.tree.map(lambda p, gg: p - opt_lr * gg, params, g)
        return params, m

    accs = []
    for i in range(60):
        img, lab = batch(i)
        params, m = step(params, img, lab)
        accs.append(float(m["acc"]))
    emit(
        "fig3.xception_analog",
        0.0,
        f"acc_first10={np.mean(accs[:10]):.3f};acc_last10={np.mean(accs[-10:]):.3f}",
    )


def main() -> None:
    lm_profile("small_batch_cpu_profile", batch=2, steps=40)
    lm_profile("large_batch_accel_profile", batch=8, steps=40)
    xception_train()


if __name__ == "__main__":
    main()
