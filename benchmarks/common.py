"""Shared benchmark helpers: CSV row emission per the harness convention."""
from __future__ import annotations

import time
from typing import List

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def timeit_us(fn, n: int = 100, warmup: int = 3) -> float:
    for _ in range(warmup):
        fn()
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6
