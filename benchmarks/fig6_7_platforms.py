"""Paper Figs 6-7: failure-rate comparison across compute platforms as the
session count grows — serverless degrades gracefully, fixed tiers collapse."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import SimConfig, Simulation, StaticPolicy, StraightLinePolicy, Tier
from repro.core.testbed import paper_tiers
from repro.core.workload import ramp

LOADS = [500, 1300, 2500, 4000, 6000]


def main() -> None:
    policies = [
        ("flask", StaticPolicy(Tier.FLASK), "3GB"),
        ("docker", StaticPolicy(Tier.DOCKER), "3GB"),
        ("serverless2GB", StaticPolicy(Tier.SERVERLESS), "2GB"),
        ("serverless3GB", StaticPolicy(Tier.SERVERLESS), "3GB"),
        ("straightline", StraightLinePolicy(), "3GB"),
    ]
    for load in LOADS:
        for name, pol, mem in policies:
            sim = Simulation(pol, paper_tiers(seed=1, elastic_mem=mem), SimConfig())
            s = sim.run(ramp(load, seed=load)).summary()
            emit(
                f"fig6_7.{name}.load{load}",
                s["median_response_s"] * 1e6,
                f"fail_rate={s['failure_rate']:.3f}",
            )


if __name__ == "__main__":
    main()
