"""Benchmark harness: one module per paper table/figure + roofline + micro.
Prints ``name,us_per_call,derived`` CSV rows (benchmarks.common.emit)."""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    from benchmarks import (
        fig3_training,
        fig4_interactive,
        fig5_elastic,
        fig6_7_platforms,
        fig8_response,
        microbench,
        paged_decode,
        placement,
        roofline,
    )

    modules = [
        ("fig4_interactive", fig4_interactive),
        ("fig5_elastic", fig5_elastic),
        ("fig6_7_platforms", fig6_7_platforms),
        ("fig8_response", fig8_response),
        ("placement", placement),
        ("fig3_training", fig3_training),
        ("roofline", roofline),
        ("microbench", microbench),
        ("paged_decode", paged_decode),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in modules:
        t0 = time.time()
        try:
            mod.main()
        except Exception:
            failures += 1
            print(f"{name},0.0,ERROR:{traceback.format_exc().splitlines()[-1][:120]}")
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
