"""Chunked prefill vs whole-prompt prefill: inter-token decode latency
while a long prompt lands on a busy engine.

The PR 4 step loop had a head-of-line blocking bug (ROADMAP "Open items"):
admitting a sequence ran its ENTIRE prompt prefill inside ``step()``, so
every decoding slot stalled for the full prefill — a max-length prompt
arriving on an interactive tier spiked that tier's inter-token latency by
orders of magnitude, exactly the latency objective StraightLine's placer is
supposed to protect. Chunked prefill (``chunk_tokens``) absorbs the prompt
over many iterations under a per-step token budget, so decoding slots keep
emitting a token every iteration and the worst-case gap is bounded by ~one
chunk of prefill work.

Scenario (per engine kind, dense and paged): one short interactive request
is mid-decode when a max-length prompt is submitted. We drive ``step()``
directly and wall-time every step in which the interactive sequence was
decoding; the max step time IS its max inter-token gap. Both engines must
produce the exact greedy tokens of the serialized baseline (chunking must
not change outputs) with zero failures.

    PYTHONPATH=src:. python benchmarks/chunked_prefill.py [--fast]

``--fast`` (CI smoke) shrinks the workload and asserts the bound — the
chunked max gap must improve on the unchunked one by >= the same 2x bar —
so chunking cannot silently regress to whole-prompt prefill.
"""
from __future__ import annotations

import argparse
import gc
import time

from benchmarks.common import emit

IMPROVE = 2.0        # acceptance bar: max inter-token gap improves >= 2x
REPS = 3             # min-of-max across reps: a STRUCTURAL stall (the whole-
                     # prompt prefill step) recurs every rep; a one-off GC /
                     # scheduler spike does not and must not decide the gap


def build(kind, cfg, params, maxlen, ps, new_tok, chunk):
    from repro.serving.engine import (
        EngineConfig,
        InferenceEngine,
        PagedEngineConfig,
        PagedInferenceEngine,
    )

    if kind == "dense":
        return InferenceEngine(
            cfg,
            EngineConfig(max_slots=2, max_len=maxlen, max_new_tokens=new_tok,
                         bucket_unit=ps, chunk_tokens=chunk),
            params=params,
        )
    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=ps, num_pages=1 + 2 * maxlen // ps, max_slots=2,
                          max_seq_len=maxlen, max_new_tokens=new_tok, chunk_tokens=chunk),
        params=params,
    )


def interactive_gaps(eng, short, long_prompt):
    """Serve ``short`` (decoding) with ``long_prompt`` landing mid-flight;
    returns (max inter-token gap of the short sequence, outs by sid). GC is
    paused around the stepping so a collection pause cannot masquerade as a
    prefill stall."""
    done = {}
    sid_s = eng.submit(short)
    # bring the interactive sequence into steady-state decode
    for _ in range(2):
        for s in eng.step():
            done[s.sid] = s
    seq_s = next(s for s in eng.slot_seq if s is not None and s.sid == sid_s)
    sid_l = eng.submit(long_prompt)
    max_gap = 0.0
    gc.collect()
    gc.disable()
    try:
        for _ in range(10000):
            n_before = len(seq_s.out)
            t0 = time.perf_counter()
            for s in eng.step():
                done[s.sid] = s
            dt = time.perf_counter() - t0
            if len(seq_s.out) > n_before:
                max_gap = max(max_gap, dt)  # a step the interactive seq waited on
            if len(done) == 2:
                return max_gap, {sid_s: done[sid_s].out, sid_l: done[sid_l].out}
    finally:
        gc.enable()
    raise AssertionError("sequences did not finish")


def run_kind(kind, cfg, params, maxlen, ps, new_tok, chunk, short, long_prompt):
    results = {}
    outs = {}
    for label, ct in (("unchunked", 0), ("chunked", chunk)):
        eng = build(kind, cfg, params, maxlen, ps, new_tok, ct)
        params = eng.params
        eng.prewarm()
        # warm the decode + (for chunked) the carry-install path so the
        # measured gaps are steady-state, not first-call compiles
        eng.generate([short[:3]])
        gaps = []
        for _ in range(REPS):
            gap, out = interactive_gaps(eng, short, long_prompt)
            gaps.append(gap)
        results[label] = min(gaps)
        outs[label] = sorted(out.items())
        emit(f"chunked_prefill.{kind}.{label}", results[label] * 1e3,
             f"max_intertoken_gap_ms;chunk={ct};reps={REPS}")
    assert outs["chunked"] == outs["unchunked"], (
        f"{kind}: chunked greedy outputs diverge from whole-prompt prefill"
    )
    for sid, out in outs["chunked"]:
        assert len(out) == new_tok, f"{kind}: sid {sid} stopped short ({len(out)} tokens)"
    improve = results["unchunked"] / max(results["chunked"], 1e-9)
    emit(f"chunked_prefill.{kind}.improvement", 0.0,
         f"x{improve:.1f}_max_gap;identical_outputs=True")
    print(
        f"{kind}: max inter-token gap {results['unchunked']*1e3:.1f}ms -> "
        f"{results['chunked']*1e3:.1f}ms ({improve:.1f}x) with chunk={chunk}, "
        f"identical greedy outputs"
    )
    return improve, params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller workload, same >=2x gap bound")
    args = ap.parse_args()

    import numpy as np

    from repro.configs.registry import get_config

    maxlen = 512 if args.fast else 1024
    ps, chunk, new_tok = 16, 32, 12
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    short = list(np.random.default_rng(0).integers(1, cfg.vocab_size, 5))
    long_prompt = list(
        np.random.default_rng(1).integers(1, cfg.vocab_size, maxlen - new_tok - 1)
    )

    params = None
    improvements = {}
    for kind in ("dense", "paged"):
        improvements[kind], params = run_kind(
            kind, cfg, params, maxlen, ps, new_tok, chunk, short, long_prompt
        )
    for kind, improve in improvements.items():
        assert improve >= IMPROVE, (
            f"{kind}: chunked prefill must improve the max inter-token decode gap "
            f">= {IMPROVE}x while a max-length prompt prefills, got {improve:.2f}x"
        )
    print(
        f"OK — long prompts are absorbed chunk-by-chunk: worst inter-token decode "
        f"gap improved >= {IMPROVE}x on both engines, outputs identical, zero failures"
    )


if __name__ == "__main__":
    main()
