"""The scheduler table: StraightLine (Algorithm 1) vs static / round-robin /
random / SLO-aware / adaptive-thresholds under the mixed bimodal ramp."""
from __future__ import annotations

from benchmarks.common import emit, timeit_us
from repro.core import (
    RandomPolicy,
    Request,
    RoundRobinPolicy,
    SimConfig,
    Simulation,
    SLOAwarePolicy,
    StaticPolicy,
    StraightLinePolicy,
    Thresholds,
    Tier,
)
from repro.core.estimator import LatencyEstimator, transfer_time
from repro.core.testbed import paper_tiers
from repro.core.workload import ramp

LOADS = [1000, 3000, 6000]


def slo_policy(tiers):
    models = {
        t: (lambda sim: (lambda r, f: LatencyEstimator.service_time(sim.app, r.work_units, sim.cfg.slice_)
             + transfer_time(r.data_size, sim.cfg.net_bw) + sim.cfg.activation_s))(sim)
        for t, sim in tiers.items()
    }
    return SLOAwarePolicy(models)


def main() -> None:
    for load in LOADS:
        tiers0 = paper_tiers(seed=1)
        policies = [
            StraightLinePolicy(),
            StaticPolicy(Tier.FLASK),
            StaticPolicy(Tier.DOCKER),
            StaticPolicy(Tier.SERVERLESS),
            RoundRobinPolicy(),
            RandomPolicy(),
            slo_policy(tiers0),
        ]
        for pol in policies:
            sim = Simulation(pol, paper_tiers(seed=1), SimConfig())
            s = sim.run(ramp(load, dist="bimodal", seed=load)).summary()
            emit(
                f"placement.{pol.name}.load{load}",
                s["median_response_s"] * 1e6,
                f"fail_rate={s['failure_rate']:.3f};p95_s={s['p95_response_s']:.2f}",
            )

    # decision-latency microbenches (router hot path)
    pol = StraightLinePolicy(Thresholds())
    r = Request(rid=0, arrival_t=0.0, data_size=2e5)
    us = timeit_us(lambda: pol.place(r, 900.0, 1, 1), n=5000)
    emit("placement.decide.python", us, "single-request Algorithm 1")

    import jax
    import jax.numpy as jnp

    from repro.core.placing import placing_batch_jax

    sizes = jnp.asarray([1e5] * 1024, jnp.float32)
    fn = jax.jit(lambda s: placing_batch_jax(900.0, s, 4, 8, F=1200.0, D=1e6))
    fn(sizes).block_until_ready()
    us = timeit_us(lambda: fn(sizes).block_until_ready(), n=200)
    emit("placement.decide.jax_batch1024", us, f"per_req_ns={us/1024*1000:.1f}")


if __name__ == "__main__":
    main()
