"""Dense vs paged serving at an EQUAL cache-byte budget.

The dense v1 engine reserves a full ``max_len`` KV stripe per slot, so its
concurrency ceiling is ``cache_tokens / max_len`` regardless of how short
the sequences actually are. The paged v2 engine hands out fixed-size pages
on demand, so the same byte budget admits ~``cache_tokens / actual_len``
sequences. This benchmark serves an identical short-request workload
through both engines over the same token budget and reports peak concurrent
sequences, decode steps, and throughput.

    PYTHONPATH=src python benchmarks/paged_decode.py
"""
from __future__ import annotations

import time

from benchmarks.common import emit

MAX_LEN = 128          # dense per-slot reservation (tokens)
CACHE_TOKENS = 256     # shared budget: dense fits 2 slots, paged fits 16 pages
PAGE_SIZE = 16
PROMPT, NEW = 6, 8     # actual request size: ~14 tokens, 1/9th of MAX_LEN
N_REQ = 24


def run_dense(cfg, params):
    from repro.serving.engine import EngineConfig, InferenceEngine

    eng = InferenceEngine(
        cfg,
        EngineConfig(max_slots=CACHE_TOKENS // MAX_LEN, max_len=MAX_LEN, max_new_tokens=NEW),
        params=params,
    )
    return _serve(eng, dense=True), eng


def run_paged(cfg, params):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    eng = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(
            page_size=PAGE_SIZE,
            num_pages=1 + CACHE_TOKENS // PAGE_SIZE,   # +1: reserved null page
            max_slots=CACHE_TOKENS // PAGE_SIZE,
            max_seq_len=MAX_LEN,
            max_new_tokens=NEW,
        ),
        params=params,
    )
    return _serve(eng, dense=False), eng


def _serve(eng, dense: bool):
    import numpy as np

    for i in range(N_REQ):
        eng.submit(list(np.random.default_rng(i).integers(1, eng.cfg.vocab_size, PROMPT)))
    peak = 0
    steps = 0
    done = []
    t0 = time.perf_counter()
    while len(done) < N_REQ and steps < 10_000:
        done.extend(eng.step())
        peak = max(peak, sum(1 for s in eng.slot_seq if s is not None))
        steps += 1
    dt = time.perf_counter() - t0
    toks = sum(len(s.out) for s in done)
    return {
        "peak_concurrent": peak,
        "steps": steps,
        "wall_s": dt,
        "toks_per_s": toks / dt,
        "outs": {s.sid: s.out for s in done},
    }


def main() -> None:
    from repro.configs.registry import get_config

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    paged_res, paged_eng = run_paged(cfg, None)
    dense_res, _ = run_dense(cfg, paged_eng.params)
    assert dense_res["outs"] == paged_res["outs"], "engines disagree on tokens"

    ratio = paged_res["peak_concurrent"] / dense_res["peak_concurrent"]
    for name, r in (("dense", dense_res), ("paged", paged_res)):
        emit(
            f"paged_decode.{name}",
            r["wall_s"] / max(1, r["steps"]) * 1e6,
            f"peak_concurrent={r['peak_concurrent']};steps={r['steps']};toks_per_s={r['toks_per_s']:.0f}",
        )
    emit("paged_decode.concurrency_ratio", 0.0, f"paged_vs_dense={ratio:.1f}x")
    print(
        f"\nequal cache budget ({CACHE_TOKENS} tokens): dense peaks at "
        f"{dense_res['peak_concurrent']} concurrent sequences, paged at "
        f"{paged_res['peak_concurrent']} ({ratio:.1f}x)"
    )
    assert ratio >= 2.0, f"paged engine should serve >=2x concurrent sequences, got {ratio:.1f}x"
    print("OK — identical tokens, >=2x concurrency from the same cache bytes")


if __name__ == "__main__":
    main()
