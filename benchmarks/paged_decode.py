"""Dense vs paged serving at an EQUAL cache-byte budget — plus the int8 leg.

The dense v1 engine reserves a full ``max_len`` KV stripe per slot, so its
concurrency ceiling is ``cache_tokens / max_len`` regardless of how short
the sequences actually are. The paged v2 engine hands out fixed-size pages
on demand, so the same byte budget admits ~``cache_tokens / actual_len``
sequences. This benchmark serves an identical short-request workload
through both engines over the same token budget and reports peak concurrent
sequences, decode steps, and throughput.

The quantized leg repeats the trick one level down: int8 pages (values +
per-(page-slot, head) bf16 scales) cost ~1/3.6 the bytes of f32 pages, so
at an equal BYTE budget the int8 pool holds proportionally more pages —
and therefore more concurrent residents — while greedy outputs must stay
token-identical to the f32 pool. Gated in CI via ``--fast``.

    PYTHONPATH=src python benchmarks/paged_decode.py [--fast]
"""
from __future__ import annotations

import sys
import time

from benchmarks.common import emit

MAX_LEN = 128          # dense per-slot reservation (tokens)
CACHE_TOKENS = 256     # shared budget: dense fits 2 slots, paged fits 16 pages
PAGE_SIZE = 16
PROMPT, NEW = 6, 8     # actual request size: ~14 tokens, 1/9th of MAX_LEN
N_REQ = 24

QUANT_F32_PAGES = 8    # f32 leg's usable pages — the byte budget
QUANT_N_REQ = 40       # enough pending work to fill the int8 pool's extra pages
QUANT_SLOTS = 32
QUANT_SEED = 7000      # the quant leg's own prompt stream: greedy margins on
                       # these prompts exceed the int8 perturbation, so token
                       # match is a real (and reproducible) guarantee


def run_dense(cfg, params):
    from repro.serving.engine import EngineConfig, InferenceEngine

    eng = InferenceEngine(
        cfg,
        EngineConfig(max_slots=CACHE_TOKENS // MAX_LEN, max_len=MAX_LEN, max_new_tokens=NEW),
        params=params,
    )
    return _serve(eng, N_REQ, 0), eng


def run_paged(cfg, params, cache_dtype="", num_pages=None, max_slots=None, n_req=N_REQ,
              seed_base=0):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    eng = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(
            page_size=PAGE_SIZE,
            num_pages=num_pages or 1 + CACHE_TOKENS // PAGE_SIZE,  # +1: null page
            max_slots=max_slots or CACHE_TOKENS // PAGE_SIZE,
            max_seq_len=MAX_LEN,
            max_new_tokens=NEW,
            cache_dtype=cache_dtype,
        ),
        params=params,
    )
    return _serve(eng, n_req, seed_base), eng


def _serve(eng, n_req: int, seed_base: int = 0):
    import numpy as np

    for i in range(n_req):
        eng.submit(
            list(np.random.default_rng(seed_base + i).integers(1, eng.cfg.vocab_size, PROMPT))
        )
    peak = 0
    steps = 0
    done = []
    t0 = time.perf_counter()
    while len(done) < n_req and steps < 10_000:
        done.extend(eng.step())
        peak = max(peak, sum(1 for s in eng.slot_seq if s is not None))
        steps += 1
    dt = time.perf_counter() - t0
    toks = sum(len(s.out) for s in done)
    return {
        "peak_concurrent": peak,
        "steps": steps,
        "wall_s": dt,
        "toks_per_s": toks / dt,
        "outs": {s.sid: s.out for s in done},
    }


def quant_leg(cfg, params) -> None:
    """Int8 vs f32 paged pools at EQUAL cache bytes: size the int8 pool to
    the f32 leg's byte budget using the engines' measured bytes/token, then
    serve the same workload through both and require >= 1.8x peak residents
    with token-identical greedy outputs."""
    f32_res, f32_eng = run_paged(
        cfg, params, "f32", num_pages=1 + QUANT_F32_PAGES,
        max_slots=QUANT_SLOTS, n_req=QUANT_N_REQ, seed_base=QUANT_SEED,
    )
    bpt_f32 = f32_eng.capacity_now()["kv_bytes_per_token"]
    # a 1-usable-page probe is the cheapest way to measure int8 bytes/token
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    probe = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PAGE_SIZE, num_pages=2, max_slots=1,
                          max_seq_len=PAGE_SIZE, max_new_tokens=1, cache_dtype="int8"),
        params=f32_eng.params,
    )
    bpt_int8 = probe.capacity_now()["kv_bytes_per_token"]
    budget_bytes = QUANT_F32_PAGES * PAGE_SIZE * bpt_f32
    int8_pages = int(budget_bytes // (PAGE_SIZE * bpt_int8))
    int8_res, _ = run_paged(
        cfg, f32_eng.params, "int8", num_pages=1 + int8_pages,
        max_slots=QUANT_SLOTS, n_req=QUANT_N_REQ, seed_base=QUANT_SEED,
    )

    assert int8_res["outs"] == f32_res["outs"], "int8 pool changed greedy tokens"
    ratio = int8_res["peak_concurrent"] / f32_res["peak_concurrent"]
    for name, r in (("paged_f32", f32_res), ("paged_int8", int8_res)):
        emit(
            f"paged_decode.{name}",
            r["wall_s"] / max(1, r["steps"]) * 1e6,
            f"peak_concurrent={r['peak_concurrent']};steps={r['steps']};toks_per_s={r['toks_per_s']:.0f}",
        )
    emit(
        "paged_decode.int8_capacity_ratio", 0.0,
        f"int8_vs_f32={ratio:.1f}x;bytes_per_token={bpt_f32:.0f}->{bpt_int8:.0f};"
        f"pages={QUANT_F32_PAGES}->{int8_pages}",
    )
    print(
        f"\nequal cache bytes ({budget_bytes:.0f}): f32 pool peaks at "
        f"{f32_res['peak_concurrent']} concurrent sequences "
        f"({QUANT_F32_PAGES} pages), int8 at {int8_res['peak_concurrent']} "
        f"({int8_pages} pages, {ratio:.1f}x)"
    )
    assert ratio >= 1.8, f"int8 pool should hold >=1.8x concurrent sequences, got {ratio:.1f}x"
    print("OK — identical tokens, >=1.8x concurrency from the same cache bytes")


def main() -> None:
    from repro.configs.registry import get_config

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    paged_res, paged_eng = run_paged(cfg, None)
    dense_res, _ = run_dense(cfg, paged_eng.params)
    assert dense_res["outs"] == paged_res["outs"], "engines disagree on tokens"

    ratio = paged_res["peak_concurrent"] / dense_res["peak_concurrent"]
    for name, r in (("dense", dense_res), ("paged", paged_res)):
        emit(
            f"paged_decode.{name}",
            r["wall_s"] / max(1, r["steps"]) * 1e6,
            f"peak_concurrent={r['peak_concurrent']};steps={r['steps']};toks_per_s={r['toks_per_s']:.0f}",
        )
    emit("paged_decode.concurrency_ratio", 0.0, f"paged_vs_dense={ratio:.1f}x")
    print(
        f"\nequal cache budget ({CACHE_TOKENS} tokens): dense peaks at "
        f"{dense_res['peak_concurrent']} concurrent sequences, paged at "
        f"{paged_res['peak_concurrent']} ({ratio:.1f}x)"
    )
    assert ratio >= 2.0, f"paged engine should serve >=2x concurrent sequences, got {ratio:.1f}x"
    print("OK — identical tokens, >=2x concurrency from the same cache bytes")

    quant_leg(cfg, paged_eng.params)


if __name__ == "__main__":
    # --fast: same tiny smoke workload — the flag exists for CI-invocation
    # parity with the other serving benchmarks (both legs are already sized
    # for a sub-minute run on the smoke model)
    sys.argv = [a for a in sys.argv if a != "--fast"]
    main()
