"""§Roofline table: read the dry-run records and emit the three-term roofline
per (arch x shape x mesh) — compute/memory/collective seconds, dominant
bound, MODEL_FLOPS ratio, per-device memory."""
from __future__ import annotations

import glob
import json
from pathlib import Path

from benchmarks.common import emit

DRYRUN = Path("benchmarks/results/dryrun")


def rows(mesh_prefix: str = "single"):
    out = []
    for p in sorted(DRYRUN.glob(f"{mesh_prefix}__*.json")):
        r = json.loads(p.read_text())
        out.append(r)
    return out


def main() -> None:
    if not DRYRUN.exists():
        emit("roofline.missing", 0.0, "run scripts/sweep_dryrun.sh first")
        return
    counts = {"compute": 0, "memory": 0, "collective": 0}
    for r in rows("single"):
        cell = f"{r['arch']}.{r['shape']}"
        if r["status"] == "skip":
            emit(f"roofline.{cell}", 0.0, "SKIP:" + r["reason"][:60])
            continue
        if r["status"] != "ok":
            emit(f"roofline.{cell}", 0.0, "ERROR")
            continue
        t = r["roofline"]
        counts[t["bound"]] += 1
        emit(
            f"roofline.{cell}",
            t["step_s_lower_bound"] * 1e6,
            (
                f"bound={t['bound']};c_ms={t['compute_s']*1e3:.2f};"
                f"m_ms={t['memory_s']*1e3:.2f};k_ms={t['collective_s']*1e3:.2f};"
                f"useful={r['useful_compute_ratio']:.2f};"
                f"memGB={r['mem']['per_device_total']/1e9:.1f}"
            ),
        )
    ok_multi = sum(1 for r in rows("multi") if r["status"] == "ok")
    skip_multi = sum(1 for r in rows("multi") if r["status"] == "skip")
    emit(
        "roofline.summary",
        0.0,
        f"bounds={counts};multi_pod_ok={ok_multi};multi_pod_skip={skip_multi}",
    )


if __name__ == "__main__":
    main()
