"""N-gram speculative decoding vs plain batched decode: tokens per step.

PR 8's tentpole: each decoding slot proposes up to ``spec_tokens`` draft
tokens from its OWN context (prompt-lookup — no draft model, no extra
weights) and the engine verifies the whole draft in one multi-token pass at
the decode frontier, accepting the longest run that matches the greedy
chain. A decode-bound engine is memory-bandwidth-bound, so the verify pass
amortizes one weight sweep over k+1 positions: when the workload is
repetitive (code, templated text, self-repeating generations) the engine
emits several tokens per step instead of one — with BYTE-IDENTICAL output,
because only greedy-matching tokens are ever accepted.

Workload: a small vocabulary makes the smoke model's greedy continuations
settle into short cycles (the degenerate-but-honest stand-in for natural
repetitiveness; the proposer sees only token ids either way). We run the
same prompts through a spec-off and a spec-on paged engine sharing params,
count engine steps to drain, and report tokens/step = tokens_emitted /
steps for each. The acceptance bar is the RATIO of the two.

    PYTHONPATH=src:. python benchmarks/speculative_decode.py [--fast]

``--fast`` (CI smoke) shrinks the workload and asserts the bar — spec-on
must emit >= 1.5x the tokens per step of spec-off at byte-identical
outputs, so speculation cannot silently regress to plain decode (a
never-accepting proposer fails the bar; a token-changing one fails the
parity assert).
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit

IMPROVE = 1.5        # acceptance bar: tokens/step improves >= 1.5x
REPS = 3             # max-of-reps tokens/step per engine: acceptance is a
                     # property of the token streams (deterministic), reps
                     # only absorb scheduling noise in the step loop


def build(cfg, params, maxlen, ps, new_tok, spec):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=ps, num_pages=1 + 4 * maxlen // ps,
                          max_slots=4, max_seq_len=maxlen,
                          max_new_tokens=new_tok, spec_tokens=spec),
        params=params,
    )


def drain(eng, prompts):
    """Submit all prompts, step to drain; returns (outs, steps, tokens)."""
    t0 = eng.tokens_emitted
    done = {}
    sids = [eng.submit(p) for p in prompts]
    steps = 0
    while eng.waiting or any(s is not None for s in eng.slot_seq):
        for s in eng.step():
            done[s.sid] = s
        steps += 1
        assert steps < 100_000
    return [list(done[sid].out) for sid in sids], steps, eng.tokens_emitted - t0


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller workload, same >=1.5x bar")
    args = ap.parse_args()

    from repro.configs.registry import get_config

    new_tok = 48 if args.fast else 160
    maxlen = 256 if args.fast else 512
    ps, spec = 8, 4
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64, vocab_size=24)
    prompts = [
        [1, 2, 3, 4, 5, 1, 2, 3, 4, 5],
        [7, 8, 9, 7, 8, 9],
        [3, 1, 4, 1, 5, 9, 2, 6],
        [2, 4, 2, 4, 2, 4, 2, 4],
    ]

    results = {}
    outs = {}
    params = None
    for label, k in (("spec_off", 0), ("spec_on", spec)):
        eng = build(cfg, params, maxlen, ps, new_tok, k)
        params = eng.params
        eng.prewarm()
        best = 0.0
        for _ in range(REPS):
            out, steps, tokens = drain(eng, prompts)
            best = max(best, tokens / steps)
        results[label] = best
        outs[label] = out
        extra = ""
        if k:
            rate = eng.spec_accepted / max(1, eng.spec_proposed)
            extra = f";accept_rate={rate:.2f};proposed={eng.spec_proposed}"
            assert eng.spec_accepted > 0, "the proposer never had a draft accepted"
        eng.allocator.check_invariants()
        assert eng.allocator.used_pages == 0, "pages leaked after drain"
        emit(f"speculative_decode.paged.{label}", results[label],
             f"tokens_per_step;k={k};reps={REPS}{extra}")

    assert outs["spec_on"] == outs["spec_off"], (
        "speculative decoding changed the greedy token stream"
    )
    improve = results["spec_on"] / max(results["spec_off"], 1e-9)
    emit("speculative_decode.paged.improvement", 0.0,
         f"x{improve:.2f}_tokens_per_step;identical_outputs=True")
    print(
        f"paged: {results['spec_off']:.2f} -> {results['spec_on']:.2f} tokens/step "
        f"({improve:.2f}x) with k={spec}, byte-identical greedy outputs"
    )
    assert improve >= IMPROVE, (
        f"speculative decoding must emit >= {IMPROVE}x tokens per step on the "
        f"repetitive workload at identical outputs, got {improve:.2f}x"
    )
    print(
        f"OK — drafts verified in one multi-token pass: >= {IMPROVE}x tokens/step "
        f"at byte-identical outputs, pages fully reclaimed"
    )


if __name__ == "__main__":
    main()
