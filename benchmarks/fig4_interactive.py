"""Paper Fig 4: Flask (interactive tier) failure rate + session length under
a 10 -> 2000 sessions/180 s ramp. Claim: knee at ~1200-1300."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import SimConfig, Simulation, StaticPolicy, Tier
from repro.core.telemetry import percentile
from repro.core.testbed import paper_tiers
from repro.core.workload import ramp

LOADS = [10, 200, 600, 1000, 1200, 1300, 1400, 1700, 2000]


def main() -> None:
    for load in LOADS:
        sim = Simulation(StaticPolicy(Tier.FLASK), paper_tiers(seed=1), SimConfig())
        m = sim.run(ramp(load, seed=load))
        s = m.summary()
        session_p95 = percentile(m.response_times(), 95) if m.completed else float("nan")
        emit(
            f"fig4.interactive.load{load}",
            s["median_response_s"] * 1e6,
            f"fail_rate={s['failure_rate']:.3f};session_p95_s={session_p95:.2f}",
        )


if __name__ == "__main__":
    main()
