"""Observability overhead: tracing must be free when off, cheap when on.

The tentpole claim of the tracing/metrics subsystem is *zero-cost when
disabled*: every instrumentation site in the router/scheduler/engines is a
single ``if trace is not None`` branch, and a disabled ``Tracer`` returns
None from ``begin()``. This benchmark gates that claim on the hottest real
path — N concurrent requests through a paged engine's ``EngineLoop`` — in
three modes over identical workloads:

  off   — no tracer attached (``trace=None`` everywhere): the production
          default and the baseline wall time;
  null  — a ``Tracer(enabled=False)`` is consulted per request (the router
          path when tracing is configured off): must be indistinguishable
          from ``off``;
  on    — a live ``Trace`` per request PLUS a ``MonitorSampler`` sweeping
          the engine's ``capacity_now`` probe at 10 ms: bounded overhead.

Each mode runs R times interleaved (cancels thermal/jit drift) and the
best wall per mode is compared; host-side primitive costs (span/event
append, histogram observe) are emitted as microbenchmarks alongside.

    PYTHONPATH=src:. python benchmarks/observability_overhead.py [--fast]

Gates: null >= 0.90x off-throughput (≈0 disabled overhead) and
on >= 0.80x off-throughput (--fast; 0.85x full) — thresholds are lenient
against shared-runner timing noise, the expected gap is low single-digit
percent.
"""
from __future__ import annotations

import argparse
import threading
import time

from benchmarks.common import emit, timeit_us


def run_workload(engine, loop, prompts, tracer=None, sampler=None, timeout=600.0):
    """N threads submitting into one shared step loop; returns wall seconds.
    With a tracer, each request begins/finishes its own trace (the router's
    role in real serving)."""
    outs = [None] * len(prompts)

    def worker(i):
        trace = tracer.begin(i, bench=True) if tracer is not None else None
        seq = loop.wait(loop.submit(prompts[i], trace=trace), timeout)
        outs[i] = seq.out
        if tracer is not None:
            tracer.finish(trace, n_out=len(seq.out))

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(prompts))]
    if sampler is not None:
        sampler.start()
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    if sampler is not None:
        sampler.stop()
    return wall, outs


def microbench():
    """Host-side primitive costs — what one instrumented site pays."""
    from repro.core.telemetry import Histogram, MetricsRegistry
    from repro.core.tracing import Trace, Tracer, trace_now

    trace = Trace(0)
    emit("observability.span_append_us",
         timeit_us(lambda: trace.add_span("s", 0.0, 1.0, lane="x", a=1), n=2000))
    emit("observability.event_append_us",
         timeit_us(lambda: trace.event("e", lane="x"), n=2000))
    hist = Histogram()
    emit("observability.hist_observe_us", timeit_us(lambda: hist.observe(0.01), n=5000))
    reg = MetricsRegistry()
    emit("observability.registry_counter_us",
         timeit_us(lambda: reg.counter("c", {"tier": "flask"}).inc(), n=5000))
    null = Tracer(enabled=False)
    emit("observability.null_begin_us", timeit_us(lambda: null.begin(0), n=10000))
    emit("observability.clock_us", timeit_us(trace_now, n=10000))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: tiny workload, lenient gates")
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    ap.add_argument("--repeats", type=int, default=3)
    args = ap.parse_args()

    import numpy as np

    from repro.configs.registry import get_config
    from repro.core.telemetry import CapacityGauge, MetricsRegistry, MonitorSampler
    from repro.core.tracing import Tracer
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine
    from repro.serving.scheduler import EngineLoop

    microbench()

    n_conc = 6 if args.fast else args.concurrency
    new_tok = 12 if args.fast else args.new_tokens
    repeats = 2 if args.fast else args.repeats
    prompt_len, maxlen, ps = 6, 128, 16

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    registry = MetricsRegistry()
    engine = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=ps, num_pages=1 + n_conc * maxlen // ps,
                          max_slots=n_conc, max_seq_len=maxlen, max_new_tokens=new_tok),
    )
    prompts = [
        list(np.random.default_rng(i).integers(1, cfg.vocab_size, prompt_len))
        for i in range(n_conc)
    ]
    engine.prewarm()
    engine.generate([prompts[0]])           # compile the decode step too

    gauge = CapacityGauge()
    gauge.register_stats("bench", engine.capacity_now)

    null_tracer = Tracer(enabled=False)
    walls = {"off": [], "null": [], "on": []}
    outs_by_mode = {}
    with EngineLoop(engine, name="bench", registry=registry) as loop:
        for _ in range(repeats):            # interleave modes: cancels drift
            for mode in ("off", "null", "on"):
                tracer = {"off": None, "null": null_tracer, "on": Tracer()}[mode]
                sampler = (
                    MonitorSampler(gauge, interval_s=0.01, registry=registry)
                    if mode == "on" else None
                )
                wall, outs = run_workload(engine, loop, prompts, tracer, sampler)
                walls[mode].append(wall)
                outs_by_mode[mode] = outs

    assert outs_by_mode["off"] == outs_by_mode["null"] == outs_by_mode["on"], (
        "observability changed generated tokens"
    )
    n_tok = n_conc * new_tok
    best = {m: min(w) for m, w in walls.items()}
    for mode in ("off", "null", "on"):
        emit(f"observability_overhead.{mode}", best[mode] / n_tok * 1e6,
             f"thr={n_tok/best[mode]:.1f}tok/s")
    null_ratio = best["off"] / best["null"]      # >1 means null was FASTER
    on_ratio = best["off"] / best["on"]
    emit("observability_overhead.null_vs_off", 0.0, f"x{null_ratio:.3f}")
    emit("observability_overhead.on_vs_off", 0.0, f"x{on_ratio:.3f}")
    print(
        f"\n{n_conc} concurrent x {new_tok} tokens, best of {repeats}: "
        f"off {best['off']:.3f}s, disabled-tracer {best['null']:.3f}s "
        f"({null_ratio:.3f}x), tracing+sampler {best['on']:.3f}s ({on_ratio:.3f}x)"
    )

    null_floor, on_floor = (0.90, 0.80) if args.fast else (0.90, 0.85)
    assert null_ratio >= null_floor, (
        f"disabled tracer costs {(1-null_ratio)*100:.1f}% throughput "
        f"(floor {null_floor}x) — the zero-cost-when-disabled claim is broken"
    )
    assert on_ratio >= on_floor, (
        f"enabled tracing+sampling costs {(1-on_ratio)*100:.1f}% throughput "
        f"(floor {on_floor}x)"
    )
    print(f"OK — disabled tracing ≈ free ({null_ratio:.3f}x), enabled bounded "
          f"({on_ratio:.3f}x ≥ {on_floor}x)")


if __name__ == "__main__":
    main()
