"""Concurrent router runtime vs the serial poll loop.

The serial loop runs backends synchronously inside ``poll()``: every
request's service time is paid on the one dispatching thread, so tier
throughput is 1/service_time regardless of how much concurrency the tiers
could absorb. The worker-pool runtime overlaps service across min(workers,
capacity) threads per tier — I/O-bound backends (network hops to Flask /
Docker / Lambda in the paper's testbed, modelled here as sleeps) scale
nearly linearly until capacity binds.

Measures end-to-end throughput and p99 response time for the same workload
through the serial loop and through pools of 1 / 4 / 16 workers per tier,
at equal (zero) failure rate.

    PYTHONPATH=src:. python benchmarks/router_concurrency.py
"""
from __future__ import annotations

import time

from benchmarks.common import emit

N_REQ = 160
SERVICE_S = 0.004          # per-request service time (I/O-bound sleep)
CAPACITY = {0: 16, 1: 16, 2: 64}   # FLASK, DOCKER, SERVERLESS


def build_router():
    from repro.core import StraightLinePolicy, Thresholds, Tier
    from repro.core.router import Backend, StraightLineRouter

    def mk(name):
        def run(req):
            time.sleep(SERVICE_S)
            return f"{name}:{req.rid}"
        return run

    return StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, mk("f"), capacity=CAPACITY[0], queue_cap=N_REQ),
            Tier.DOCKER: Backend(Tier.DOCKER, mk("d"), capacity=CAPACITY[1], queue_cap=N_REQ),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, mk("s"), capacity=CAPACITY[2], queue_cap=N_REQ),
        },
        policy=StraightLinePolicy(Thresholds(F=1e9, D=1e6)),
        results_cap=N_REQ,
    )


def run_once(workers: int) -> dict:
    """workers=0: serial poll loop; else the concurrent runtime."""
    from repro.core.request import Request
    from repro.core.telemetry import percentile

    router = build_router()
    if workers > 0:
        router.start(workers)
    t0 = time.perf_counter()
    for i in range(N_REQ):
        router.submit(Request(rid=i, arrival_t=0.0, data_size=100.0, timeout_s=300.0))
    router.drain()
    wall = time.perf_counter() - t0
    if workers > 0:
        router.stop()
    m = router.metrics
    rts = m.response_times()
    return {
        "wall_s": wall,
        "throughput_rps": m.total / wall,
        "p99_response_s": percentile(rts, 99),
        "failure_rate": m.failure_rate,
        "total": m.total,
    }


def main() -> None:
    results = {}
    for workers in (0, 1, 4, 16):
        r = run_once(workers)
        results[workers] = r
        name = "serial" if workers == 0 else f"workers{workers}"
        emit(
            f"router_concurrency.{name}",
            r["wall_s"] / r["total"] * 1e6,
            f"thr={r['throughput_rps']:.0f}rps;p99={r['p99_response_s']*1e3:.1f}ms;"
            f"fail={r['failure_rate']:.3f}",
        )

    base = results[0]
    speedup4 = results[4]["throughput_rps"] / base["throughput_rps"]
    speedup16 = results[16]["throughput_rps"] / base["throughput_rps"]
    emit("router_concurrency.speedup", 0.0,
         f"workers4_vs_serial={speedup4:.1f}x;workers16_vs_serial={speedup16:.1f}x")
    print(
        f"\n{N_REQ} requests, {SERVICE_S*1e3:.0f}ms service: serial "
        f"{base['throughput_rps']:.0f} rps -> 4 workers "
        f"{results[4]['throughput_rps']:.0f} rps ({speedup4:.1f}x), 16 workers "
        f"{results[16]['throughput_rps']:.0f} rps ({speedup16:.1f}x)"
    )
    assert all(r["total"] == N_REQ for r in results.values()), "lost requests"
    assert all(r["failure_rate"] == base["failure_rate"] for r in results.values()), (
        "failure rates diverge — speedup not at equal failure rate"
    )
    assert speedup4 >= 2.0, f"4 workers should give >=2x over the serial loop, got {speedup4:.1f}x"
    print("OK — >=2x throughput at 4 workers/tier, equal failure rate, p99 down")


if __name__ == "__main__":
    main()
