"""Cross-request prefix caching: multi-turn chat TTFT, token parity, and
the cache-off overhead bound.

PR 7 added an engine-level prefix cache (ROADMAP "Open items"): a radix
tree over the paged KV pool retires finished sequences' full pages and
re-attaches them to later prompts sharing the prefix, so prefill runs only
on the unmatched tail. The workload that motivates it is multi-turn chat:
every turn re-submits the whole conversation so far plus a short new user
message, so without the cache prefill cost grows linearly with history —
exactly the TTFT the StraightLine placer tries to protect on interactive
tiers.

Scenario: one conversation, ``TURNS`` turns. Turn k's prompt is the full
history (system prompt + every prior turn's prompt tail + generated reply)
plus a fresh user message; the engine generates a fixed-length reply that
is appended to the history. The cold engine (``prefix_cache=False``)
prefills the whole prompt every turn; the warm engine matches the history
in the tree and prefills only the new tail. TTFT is measured per turn as
``seq.token_times[0] - seq.submit_t`` driving ``step()`` directly; the
gate compares the median over turns >= 2 (turn 1 is a miss for both).
Outputs must be byte-identical — the cache must never change what the
model computes, only skip recomputing it.

The overhead leg re-runs a unique-prompt workload (zero hits possible) on
both engines: cache-on pays hashing + tree insert on every release, and
that must stay within 5% of cache-off throughput.

    PYTHONPATH=src:. python benchmarks/prefix_cache.py [--fast]

``--fast`` (CI smoke) shrinks the conversation and asserts the same
bounds — warm TTFT must improve >= 3x and the no-hit overhead must stay
<= 5% — so the cache cannot silently regress to full prefill or tax
workloads that never hit it.
"""
from __future__ import annotations

import argparse
import gc
import statistics
import time

from benchmarks.common import emit

IMPROVE = 3.0        # acceptance bar: median warm TTFT improves >= 3x
OVERHEAD = 0.95      # acceptance bar: no-hit cache-on throughput >= 0.95x off
REPS = 3             # min-of-median across reps: the cache's prefill skip is
                     # STRUCTURAL and recurs every rep; GC / scheduler spikes
                     # do not and must not decide the medians


def build(cfg, params, maxlen, ps, new_tok, chunk, cache):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=ps, num_pages=1 + 4 * maxlen // ps, max_slots=2,
                          max_seq_len=maxlen, max_new_tokens=new_tok,
                          chunk_tokens=chunk, prefix_cache=cache),
        params=params,
    )


def run_turn(eng, prompt):
    """Submit one turn and step it to completion; returns (ttft_s, out)."""
    sid = eng.submit(prompt)
    for _ in range(10000):
        for seq in eng.step():
            if seq.sid == sid:
                return seq.token_times[0] - seq.submit_t, list(seq.out)
    raise AssertionError("turn did not finish")


def conversation(eng, sys_prompt, user_msgs):
    """Play the multi-turn chat; returns (per-turn TTFTs, per-turn outputs)."""
    history = list(sys_prompt)
    ttfts, outs = [], []
    gc.collect()
    gc.disable()
    try:
        for msg in user_msgs:
            prompt = history + list(msg)
            ttft, out = run_turn(eng, prompt)
            ttfts.append(ttft)
            outs.append(out)
            history = prompt + out
    finally:
        gc.enable()
    return ttfts, outs


def chat_leg(engines, sys_prompt, user_msgs, new_tok):
    """Cold vs warm multi-turn chat; returns the median-TTFT improvement."""
    med = {}
    all_outs = {}
    for label, eng in engines.items():
        meds = []
        for _ in range(REPS):
            if eng.prefix_cache is not None:
                eng.prefix_cache.drop()       # every rep starts from a cold tree
            ttfts, outs = conversation(eng, sys_prompt, user_msgs)
            meds.append(statistics.median(ttfts[1:]))  # turn 1 misses on both
            all_outs[label] = outs
        med[label] = min(meds)
        emit(f"prefix_cache.chat.{label}", med[label] * 1e3,
             f"median_ttft_ms_turns2plus;turns={len(user_msgs)};reps={REPS}")
    assert all_outs["warm"] == all_outs["cold"], (
        "prefix cache changed greedy outputs vs full prefill"
    )
    for out in all_outs["warm"]:
        assert len(out) == new_tok, f"turn stopped short ({len(out)} tokens)"
    pc = engines["warm"].prefix_cache
    improve = med["cold"] / max(med["warm"], 1e-9)
    emit("prefix_cache.chat.improvement", 0.0,
         f"x{improve:.1f}_median_ttft;hit_rate={pc.hit_rate:.2f};"
         f"matched_tokens={pc.matched_tokens_total};identical_outputs=True")
    print(
        f"chat: median TTFT {med['cold']*1e3:.1f}ms -> {med['warm']*1e3:.1f}ms "
        f"({improve:.1f}x) over {len(user_msgs)} turns, hit rate {pc.hit_rate:.2f}, "
        f"identical greedy outputs"
    )
    assert pc.hit_rate > 0.0, "warm engine never hit the cache"
    return improve


def overhead_leg(engines, prompts):
    """Unique prompts (no hits possible): cache-on must stay within the
    overhead bound of cache-off wall time."""
    wall = {}
    for label, eng in engines.items():
        per_prompt = [[] for _ in prompts]    # per-prompt times across reps
        for _ in range(REPS):
            if eng.prefix_cache is not None:
                eng.prefix_cache.drop()       # reps must not hit earlier reps
            gc.collect()
            gc.disable()
            try:
                for i, p in enumerate(prompts):
                    t0 = time.perf_counter()
                    run_turn(eng, p)
                    per_prompt[i].append(time.perf_counter() - t0)
            finally:
                gc.enable()
        # sum of per-prompt minima: a one-off scheduler spike on one prompt
        # in one rep cannot decide the ratio, the structural cost recurs
        wall[label] = sum(min(ts) for ts in per_prompt)
        emit(f"prefix_cache.overhead.{label}", wall[label] * 1e3,
             f"unique_prompt_wall_ms;n={len(prompts)};reps={REPS}")
    ratio = wall["cold"] / max(wall["warm"], 1e-9)   # throughput on / off
    emit("prefix_cache.overhead.ratio", 0.0, f"throughput_on_over_off=x{ratio:.3f}")
    print(
        f"overhead: unique-prompt wall {wall['cold']*1e3:.1f}ms off -> "
        f"{wall['warm']*1e3:.1f}ms on ({ratio:.3f}x throughput)"
    )
    return ratio


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: smaller conversation, same >=3x / <=5% bounds")
    args = ap.parse_args()

    import numpy as np

    from repro.configs.registry import get_config

    turns = 4 if args.fast else 6
    sys_len = 160 if args.fast else 384
    maxlen = 384 if args.fast else 1024
    ps, chunk, new_tok, user_len = 16, 32, 8, 12
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    rng = np.random.default_rng(0)
    sys_prompt = list(rng.integers(1, cfg.vocab_size, sys_len))
    user_msgs = [list(rng.integers(1, cfg.vocab_size, user_len)) for _ in range(turns)]
    unique = [list(rng.integers(1, cfg.vocab_size, 96)) for _ in range(6)]

    params = None
    engines = {}
    for label, cache in (("cold", False), ("warm", True)):
        engines[label] = build(cfg, params, maxlen, ps, new_tok, chunk, cache)
        params = engines[label].params
        engines[label].prewarm()
        # compile the decode + chunk + (warm) cache-attach path before timing
        engines[label].generate([sys_prompt[:40]])

    improve = chat_leg(engines, sys_prompt, user_msgs, new_tok)
    ratio = overhead_leg(engines, unique)

    assert improve >= IMPROVE, (
        f"prefix cache must improve median multi-turn TTFT >= {IMPROVE}x, "
        f"got {improve:.2f}x"
    )
    assert ratio >= OVERHEAD, (
        f"cache-on must keep >= {OVERHEAD}x cache-off throughput on unique "
        f"prompts, got {ratio:.3f}x"
    )
    print(
        f"OK — multi-turn prompts skip cached prefill: median TTFT improved >= "
        f"{IMPROVE}x, outputs identical, no-hit overhead within "
        f"{(1 - OVERHEAD) * 100:.0f}%"
    )


if __name__ == "__main__":
    main()
