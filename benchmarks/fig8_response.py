"""Paper Fig 8: response-time comparison — Flask (local) fastest at low
load; Docker/serverless pay activation overhead."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import SimConfig, Simulation, StaticPolicy, Tier
from repro.core.testbed import paper_tiers
from repro.core.workload import ramp


def main() -> None:
    for name, tier in (("flask", Tier.FLASK), ("docker", Tier.DOCKER), ("serverless", Tier.SERVERLESS)):
        sim = Simulation(StaticPolicy(tier), paper_tiers(seed=1), SimConfig())
        m = sim.run(ramp(400, seed=42))
        s = m.summary()
        emit(
            f"fig8.response.{name}",
            s["median_response_s"] * 1e6,
            f"mean_s={s['mean_response_s']:.3f};p95_s={s['p95_response_s']:.3f}",
        )


if __name__ == "__main__":
    main()
