"""Hot-path microbenchmarks: simulator throughput, telemetry, kernels
(interpret mode — correctness-path cost, not TPU perf)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit, timeit_us


def main() -> None:
    # simulator event throughput
    from repro.core import SimConfig, Simulation, StraightLinePolicy
    from repro.core.testbed import paper_tiers
    from repro.core.workload import ramp

    reqs = ramp(4000, seed=0)
    t0 = time.perf_counter()
    Simulation(StraightLinePolicy(), paper_tiers(seed=0), SimConfig()).run(reqs)
    dt = time.perf_counter() - t0
    emit("micro.simulator", dt / len(reqs) * 1e6, f"requests_per_s={len(reqs)/dt:.0f}")

    from repro.core.telemetry import FrequencyEstimator

    fe = FrequencyEstimator()
    box = [0.0]

    def obs():
        box[0] += 0.01
        fe.observe(box[0])

    emit("micro.telemetry.observe", timeit_us(obs, n=5000), "")

    # engine decode step (reduced model, real JAX execution)
    from repro.configs.registry import get_config
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    eng = InferenceEngine(cfg, EngineConfig(max_slots=4, max_len=128, max_new_tokens=8))
    for p in ([1, 2, 3], [4, 5], [6], [7, 8, 9]):
        eng.submit(list(p))
    eng.step()
    us = timeit_us(lambda: eng.step(), n=20)
    emit("micro.engine.decode_step", us, f"slots=4;toks_per_s={4/(us/1e6):.0f}")

    # optimizer update
    from repro.train.optimizer import OptConfig, adamw_update, init_opt_state

    params = {"w": jnp.zeros((1024, 256))}
    ocfg = OptConfig()
    opt = init_opt_state(params, ocfg)
    g = {"w": jnp.ones((1024, 256)) * 1e-3}
    upd = jax.jit(lambda g, o, p: adamw_update(g, o, p, ocfg))
    upd(g, opt, params)
    emit("micro.adamw.262k_params", timeit_us(lambda: jax.block_until_ready(upd(g, opt, params)), n=50), "")


if __name__ == "__main__":
    main()
