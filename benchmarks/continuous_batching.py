"""Continuous-batching step loop vs the serialized ``generate`` baseline.

One paged engine, N concurrent requests. The baseline is the pre-loop
router-worker behavior: every caller runs ``engine.generate`` which holds
the engine lock end-to-end, so concurrent generations serialize on the
device — N requests cost ~N full generations of decode steps. The
``EngineLoop`` path submits all N into the shared step loop: every decode
step advances EVERY active sequence in one batched device call (the engine's
decode batch is max_slots wide whether 1 or N slots are live), so N
interleaved requests cost ~1 generation's worth of steps plus the serial
prefills.

Measures wall time / throughput for both paths on the SAME engine with
identical greedy outputs required per request — the speedup is real batching,
not lost work. ``--fast`` (CI smoke) shrinks the workload and asserts the
mechanism (requests truly interleave: peak_active > 1, outputs identical)
rather than the full >=4x throughput bar.

    PYTHONPATH=src:. python benchmarks/continuous_batching.py [--fast]
"""
from __future__ import annotations

import argparse
import threading
import time

from benchmarks.common import emit


def run_serialized(engine, prompts):
    """N threads x lock-holding generate: the pre-loop router-worker path."""
    outs = [None] * len(prompts)

    def worker(i):
        outs[i] = engine.generate([prompts[i]])[0].out

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(prompts))]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, outs


def run_batched(engine, prompts, timeout=600.0):
    """N threads submitting into one shared step loop."""
    from repro.serving.scheduler import EngineLoop

    outs = [None] * len(prompts)
    with EngineLoop(engine) as loop:

        def worker(i):
            outs[i] = loop.wait(loop.submit(prompts[i]), timeout).out

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(prompts))]
        t0 = time.perf_counter()
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
    return wall, outs


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: tiny workload, assert interleaving not the 4x bar")
    ap.add_argument("--concurrency", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    import numpy as np

    from repro.configs.registry import get_config
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    n_conc = 6 if args.fast else args.concurrency
    new_tok = 12 if args.fast else args.new_tokens
    prompt_len, maxlen, ps = 6, 128, 16

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    engine = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=ps, num_pages=1 + n_conc * maxlen // ps,
                          max_slots=n_conc, max_seq_len=maxlen, max_new_tokens=new_tok),
    )
    prompts = [
        list(np.random.default_rng(i).integers(1, cfg.vocab_size, prompt_len))
        for i in range(n_conc)
    ]
    engine.prewarm()
    engine.generate([prompts[0]])           # compile the decode step too
    engine.peak_active = 0

    ser_wall, ser_outs = run_serialized(engine, prompts)
    assert engine.peak_active == 1, "serialized baseline unexpectedly interleaved"
    engine.peak_active = 0
    bat_wall, bat_outs = run_batched(engine, prompts)

    assert bat_outs == ser_outs, "batched outputs diverge from serialized baseline"
    assert all(len(o) == new_tok for o in bat_outs), "a request failed / stopped short"
    assert engine.peak_active > 1, (
        "step loop regressed to serialized execution (no interleaving observed)"
    )

    n_tok = n_conc * new_tok
    speedup = ser_wall / bat_wall
    emit("continuous_batching.serialized", ser_wall / n_tok * 1e6,
         f"thr={n_tok/ser_wall:.1f}tok/s")
    emit("continuous_batching.step_loop", bat_wall / n_tok * 1e6,
         f"thr={n_tok/bat_wall:.1f}tok/s;peak_active={engine.peak_active}")
    emit("continuous_batching.speedup", 0.0,
         f"x{speedup:.1f}_at_{n_conc}_concurrent;identical_outputs=True")
    print(
        f"\n{n_conc} concurrent requests x {new_tok} tokens: serialized {ser_wall:.2f}s "
        f"-> step loop {bat_wall:.2f}s ({speedup:.1f}x), peak batch "
        f"{engine.peak_active}/{n_conc}, outputs identical, zero failures"
    )
    if args.fast:
        assert speedup > 1.0, f"step loop slower than serialized baseline ({speedup:.2f}x)"
        print("OK (fast) — requests interleave in one decode batch, outputs identical")
    else:
        assert speedup >= 4.0, (
            f"continuous batching must give >=4x at {n_conc} concurrent, got {speedup:.1f}x"
        )
        print(f"OK — >={4.0}x throughput on one engine at {n_conc} concurrent requests")


if __name__ == "__main__":
    main()
