"""Elastic scaling under a traffic burst: autoscaler pre-warming + adaptive
thresholds (the paper's future-work items, implemented).

    PYTHONPATH=src python examples/elastic_burst.py
"""
from repro.core import SimConfig, Simulation, StraightLinePolicy, Thresholds
from repro.core.autoscaler import Autoscaler
from repro.core.placing import AdaptiveThresholds
from repro.core.testbed import paper_tiers
from repro.core.workload import burst

WL = dict(background_rate=2.0, burst_rate=150.0, burst_at_s=60, burst_len_s=20, seed=11)

print("burst: 2 rps background, 150 rps for 20 s at t=60")
for name, sim_cfg in [
    ("no autoscaler", SimConfig()),
    ("with autoscaler", SimConfig(autoscaler=Autoscaler())),
    ("autoscaler + hedging", SimConfig(autoscaler=Autoscaler(), hedge_after_s=3.0)),
]:
    sim = Simulation(StraightLinePolicy(), paper_tiers(seed=4), sim_cfg)
    s = sim.run(burst(**WL)).summary()
    print(f"  {name:22s} fail={s['failure_rate']:.3f} median={s['median_response_s']:.3f}s p95={s['p95_response_s']:.2f}s")

# adaptive thresholds re-fit F to the interactive tier's measured capacity
at = AdaptiveThresholds(Thresholds(), interactive_capacity_rps=1.0 / 0.15)
th = at.update(interactive_utilization=0.95, docker_service_s=0.8, flask_service_s=0.15)
print(f"\nadaptive thresholds under saturation: F={th.F:.0f} sessions/window, D={th.D/1e6:.1f} MB")
