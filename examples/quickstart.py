"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, list_archs
from repro.core import Request, SimConfig, Simulation, StraightLinePolicy, Thresholds, Tier
from repro.core.testbed import paper_tiers
from repro.core.workload import ramp
from repro.models import get_model

print("assigned architectures:", ", ".join(list_archs()))

# --- 1. any architecture, one API -----------------------------------------
cfg = get_config("glm4-9b", smoke=True)          # structurally-faithful reduction
model = get_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = {
    "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
    "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
}
loss, metrics = model.loss(None, params, batch)
print(f"glm4-9b (smoke) train loss: {float(loss):.3f}")

# --- 2. prefill + decode ----------------------------------------------------
tok, cache = model.prefill(None, params, {"tokens": batch["tokens"]}, cap=24)
tok2, cache = model.decode(None, params, cache, {"token": tok[:, None], "cache_index": jnp.asarray(16)})
print("greedy next tokens:", tok.tolist(), "->", tok2.tolist())

# --- 3. StraightLine: Algorithm 1 ------------------------------------------
pol = StraightLinePolicy(Thresholds(F=1200, D=1e6))
d = pol.place(Request(rid=0, arrival_t=0.0, data_size=2e5), f_t=2000, flask_free=1, docker_free=1)
print(f"burst+small payload -> {d.tier.name}  ({d.reason})")

# --- 4. the hybrid testbed under a paper-style ramp --------------------------
sim = Simulation(pol, paper_tiers(seed=0), SimConfig())
summary = sim.run(ramp(2000, seed=0)).summary()
print("2000-session ramp through StraightLine:", summary)
