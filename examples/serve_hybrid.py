"""End-to-end driver (the paper's kind: serving): batched requests through
the StraightLine router onto three REAL JAX inference backends — with the
placer consuming LIVE capacity from the paged serving engines, every
engine tier fronted by a continuous-batching step loop, and the full
observability stack on: per-request lifecycle traces, the process metrics
registry, and a MonitorSampler time series per tier.

Tiers (DESIGN.md §2):
  interactive — 1-slot paged engine, lowest latency, tiny page pool
  batch       — 8-slot paged engine over a shared KV page pool
  elastic     — engines spun up on demand (cold start = init + weight load)

Each engine is owned by a ``serving.scheduler.EngineLoop``: router workers
submit into the shared step loop and block on per-request futures, so
concurrent requests on one engine interleave inside a single decode batch
(instead of serializing whole generations on the engine lock). Prefill is
CHUNKED (``chunk_tokens=CHUNK``): a long prompt is absorbed a page-multiple
chunk per step under the engines' token budget, so it cannot stall the
interactive tier's decode batch for a whole prefill. Algorithm 1's
S_F/S_D availability checks pull through a CapacityGauge fed by each
engine's ``admission_capacity()`` (free slots bounded by free KV pages), and
the loop's ``capacity_now()`` additionally exports batch occupancy + queue
depth so telemetry sees true interleaved utilization.

Observability (this run asserts all three outputs):
  * every request carries a Trace from submit to settle — hedged copies
    (``hedge_after_s``) share one trace and race on separate lanes; the
    Chrome trace-event export lands at ``$TRACE_OUT`` (Perfetto-loadable);
  * the metrics registry (placement counters, queue-wait / TTFT /
    inter-token histograms) dumps Prometheus text at ``$METRICS_OUT``;
  * a MonitorSampler sweeps each tier's ``capacity_now`` probe into
    per-tier time series while the burst runs.

    PYTHONPATH=src python examples/serve_hybrid.py
"""
import json
import os
import threading
import time

import numpy as np

from repro.core import (
    CapacityGauge,
    MonitorSampler,
    Request,
    StraightLinePolicy,
    Thresholds,
    Tier,
    Tracer,
    default_registry,
)
from repro.configs.registry import get_config
from repro.core.router import Backend, StraightLineRouter
from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine
from repro.serving.scheduler import EngineLoop

CFG = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
MAXLEN, NEW, PROMPT = 96, 8, 8
PS = 16
CHUNK = 32                    # chunked prefill: tokens absorbed per step
TRACE_OUT = os.environ.get("TRACE_OUT", "/tmp/serve_hybrid_trace.json")
METRICS_OUT = os.environ.get("METRICS_OUT", "/tmp/serve_hybrid_metrics.prom")

t0 = time.time()
interactive = PagedInferenceEngine(
    CFG, PagedEngineConfig(page_size=PS, num_pages=1 + MAXLEN // PS, max_slots=1,
                           max_seq_len=MAXLEN, max_new_tokens=NEW, chunk_tokens=CHUNK)
)
batch_tier = PagedInferenceEngine(
    CFG, PagedEngineConfig(page_size=PS, num_pages=1 + 4 * MAXLEN // PS, max_slots=8,
                           max_seq_len=MAXLEN, max_new_tokens=NEW, chunk_tokens=CHUNK),
    params=interactive.params,
)
print(f"tiers ready in {time.time()-t0:.1f}s")

# pre-warm: compile every prefill bucket before traffic arrives, so no
# request pays an XLA compile and the placer sees fully-warm tiers
for eng in (interactive, batch_tier):
    eng.prewarm()
print(f"batch tier: {batch_tier.capacity_now()}")

# one continuous-batching step loop per engine: all device stepping happens
# on the loop thread; submitters (router workers) only enqueue + wait
registry = default_registry()
interactive_loop = EngineLoop(interactive, name="flask").start()
batch_loop = EngineLoop(batch_tier, name="docker").start()

# live capacity feedback: the placer sees each engine's measured admission
# capacity (slots bounded by free pages), not a hardcoded constant — plus
# warm-up state and batch occupancy through the loops' stats probes
gauge = CapacityGauge()
gauge.register("flask", lambda: interactive.admission_capacity(PROMPT + NEW))
gauge.register("docker", lambda: batch_tier.admission_capacity(PROMPT + NEW))
gauge.register_stats("flask", interactive_loop.capacity_now)
gauge.register_stats("docker", batch_loop.capacity_now)

tracer = Tracer()
sampler = MonitorSampler(gauge, interval_s=0.02, registry=registry).start()

elastic_pool = []
elastic_lock = threading.Lock()


def prompt_for(req: Request):
    return list(np.random.default_rng(req.rid).integers(1, CFG.vocab_size, PROMPT))


def elastic_run(req: Request):
    # cold start: spin up a fresh engine + step loop (weights init = load
    # analogue); concurrent elastic requests then batch on it too
    with elastic_lock:               # one cold start even under concurrency
        if not elastic_pool:
            t = time.time()
            eng = PagedInferenceEngine(
                CFG, PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS,
                                       max_slots=4, max_seq_len=MAXLEN, max_new_tokens=NEW,
                                       chunk_tokens=CHUNK),
                params=interactive.params,
            )
            elastic_pool.append(EngineLoop(eng, name="elastic").start())
            gauge.register_stats("elastic", elastic_pool[0].capacity_now)
            print(f"  [elastic cold start: {time.time()-t:.1f}s]")
    loop = elastic_pool[0]
    return loop.wait(loop.submit(prompt_for(req), trace=req.trace), req.timeout_s).out


def loop_backend(tier, loop, capacity, queue_cap):
    return Backend(
        tier,
        run=lambda req: loop.wait(loop.submit(prompt_for(req)), req.timeout_s).out,
        capacity=capacity, queue_cap=queue_cap,
        capacity_fn=lambda: gauge.free("flask" if tier == Tier.FLASK else "docker"),
        stats_fn=lambda: gauge.stats("flask" if tier == Tier.FLASK else "docker"),
        submit_fn=lambda req: loop.submit(prompt_for(req), trace=req.trace),
        wait_fn=lambda sid, timeout: loop.wait(sid, timeout).out,
    )


router = StraightLineRouter(
    {
        Tier.FLASK: loop_backend(Tier.FLASK, interactive_loop, 1, 8),
        Tier.DOCKER: loop_backend(Tier.DOCKER, batch_loop, 8, 64),
        Tier.SERVERLESS: Backend(Tier.SERVERLESS, elastic_run, capacity=16),
    },
    policy=StraightLinePolicy(Thresholds(F=10, D=4096)),   # scaled-down thresholds
    window_s=10.0,
    hedge_after_s=0.25,              # straggler mitigation: slow copies race a
    tracer=tracer,                   # duplicate on the elastic tier
    registry=registry,
)

# worker pools keep the decode batches fed; 16 serverless workers leave
# headroom for hedge clones to race while their primaries still run
router.start(16)
rng = np.random.default_rng(0)
N = 24
# a burst: submit everything at once -> f_t crosses F -> elastic absorbs it
for i in range(N):
    size = float(rng.choice([512.0, 16384.0], p=[0.8, 0.2]))   # bimodal payloads
    router.submit(Request(rid=i, arrival_t=0.0, data_size=size, timeout_s=120.0))
router.drain()
router.stop()

m = router.metrics
print(f"\n{N} requests: {m.summary()}")
by_tier = {t.name: sum(1 for r in m.completed if r.tier == t) for t in Tier}
print("placement:", by_tier)
print("live capacity after drain:", gauge.snapshot())
print("batch tier occupancy gauge:", gauge.occupancy("docker"),
      "steps:", batch_loop.steps,
      "prefill backlog:", gauge.prefill_backlog("docker"))
for loop in [interactive_loop, batch_loop] + elastic_pool:
    loop.stop()
sampler.stop()
assert m.total == N and m.failure_rate == 0.0

# --- observability outputs (the three artifacts this example certifies) ----

# (a) lifecycle traces: every request settled exactly one trace; each shows
# Algorithm 1's placement inputs; hedged requests race on parallel lanes
traces = tracer.traces()
assert len(traces) == N, (len(traces), N)
for t in traces:
    names = [s["name"] for s in t["spans"]]
    assert "placement" in names, t["rid"]
    placement = next(s for s in t["spans"] if s["name"] == "placement")
    assert {"f_t", "flask_free", "docker_free", "tier"} <= set(placement["attrs"])
hedged = [t for t in traces if any(e["name"] == "hedge_fired" for e in t["events"])]
dual = [t for t in hedged
        if sum(1 for s in t["spans"] if s["name"] == "execute") >= 2
        and any(s["name"] == "queue_wait" for s in t["spans"])
        and any(ts for ts in t["tokens"].values())]
print(f"traces: {len(traces)} total, {len(hedged)} hedged, {len(dual)} dual-execution")
assert hedged, "burst produced no hedged request"
assert dual, "no hedged trace shows both racing executions"
tracer.export_chrome(TRACE_OUT)
with open(TRACE_OUT) as f:
    chrome = json.load(f)                      # round-trips: Perfetto-loadable
assert chrome["traceEvents"], "empty Chrome trace"
print(f"wrote {TRACE_OUT} ({len(chrome['traceEvents'])} events)")

# (b) Prometheus text: latency histograms from the engine loops + router
prom = registry.prometheus_text()
with open(METRICS_OUT, "w") as f:
    f.write(prom)
assert "ttft_seconds_bucket" in prom and "itl_seconds_bucket" in prom, prom[:400]
assert "router_requests_total" in prom and "router_queue_wait_seconds_bucket" in prom
print(f"wrote {METRICS_OUT} ({len(prom.splitlines())} lines)")

# (c) MonitorSampler: a time series exists for every tier that served traffic
live_tiers = {"elastic" if name == "SERVERLESS" else name.lower()
              for name, n in by_tier.items() if n > 0}
live_tiers |= {"flask", "docker"}            # stats probes registered up front
assert live_tiers <= set(sampler.tiers()), (live_tiers, sampler.tiers())
for tier in sorted(sampler.tiers()):
    series = sampler.series(tier)
    occ = [s["occupancy"] for s in series if s["occupancy"] is not None]
    print(f"monitor[{tier}]: {len(series)} samples, peak occupancy "
          f"{max(occ) if occ else 0.0:.2f}")

print("OK — all requests served by real JAX paged engines through Algorithm 1,")
print("     batched by shared step loops with chunked (budgeted) prefill,")
print("     with S_F/S_D read live from page pools — and the whole run is")
print("     observable: traces (Perfetto), Prometheus metrics, tier time series")
