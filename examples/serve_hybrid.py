"""End-to-end driver (the paper's kind: serving): batched requests through
the StraightLine router onto three REAL JAX inference backends — with the
placer consuming LIVE capacity from the paged serving engines.

Tiers (DESIGN.md §2):
  interactive — 1-slot paged engine, lowest latency, tiny page pool
  batch       — 8-slot paged engine over a shared KV page pool
  elastic     — engines spun up on demand (cold start = init + weight load)

Algorithm 1's S_F/S_D availability checks pull through a CapacityGauge fed
by each engine's ``admission_capacity()`` (free slots bounded by free KV
pages), not static capacity constants.

    PYTHONPATH=src python examples/serve_hybrid.py
"""
import time

import numpy as np

from repro.configs.registry import get_config
from repro.core import CapacityGauge, Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter
from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

CFG = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
MAXLEN, NEW, PROMPT = 96, 8, 8
PS = 16

t0 = time.time()
interactive = PagedInferenceEngine(
    CFG, PagedEngineConfig(page_size=PS, num_pages=1 + MAXLEN // PS, max_slots=1,
                           max_seq_len=MAXLEN, max_new_tokens=NEW)
)
batch_tier = PagedInferenceEngine(
    CFG, PagedEngineConfig(page_size=PS, num_pages=1 + 4 * MAXLEN // PS, max_slots=8,
                           max_seq_len=MAXLEN, max_new_tokens=NEW),
    params=interactive.params,
)
print(f"tiers ready in {time.time()-t0:.1f}s")

# pre-warm: compile every prefill bucket before traffic arrives, so no
# request pays an XLA compile and the placer sees fully-warm tiers
for eng in (interactive, batch_tier):
    eng.prewarm()
print(f"batch tier: {batch_tier.capacity_now()}")

# live capacity feedback: the placer sees each engine's measured admission
# capacity (slots bounded by free pages), not a hardcoded constant — and
# warm-up state (compile_events/total_buckets) through the stats probes
gauge = CapacityGauge()
gauge.register("flask", lambda: interactive.admission_capacity(PROMPT + NEW))
gauge.register("docker", lambda: batch_tier.admission_capacity(PROMPT + NEW))
gauge.register_stats("flask", interactive.capacity_now)
gauge.register_stats("docker", batch_tier.capacity_now)

elastic_pool = []


def run_on(engine):
    def run(req: Request):
        prompt = list(np.random.default_rng(req.rid).integers(1, CFG.vocab_size, PROMPT))
        seqs = engine.generate([prompt])
        return seqs[0].out
    return run


def elastic_run(req: Request):
    # cold start: spin up a fresh engine (weights init = load analogue)
    if not elastic_pool:
        t = time.time()
        elastic_pool.append(
            PagedInferenceEngine(
                CFG, PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS,
                                       max_slots=4, max_seq_len=MAXLEN, max_new_tokens=NEW),
                params=interactive.params,
            )
        )
        print(f"  [elastic cold start: {time.time()-t:.1f}s]")
    return run_on(elastic_pool[0])(req)


router = StraightLineRouter(
    {
        Tier.FLASK: Backend(Tier.FLASK, run_on(interactive), capacity=1, queue_cap=8,
                            capacity_fn=lambda: gauge.free("flask"),
                            stats_fn=lambda: gauge.stats("flask")),
        Tier.DOCKER: Backend(Tier.DOCKER, run_on(batch_tier), capacity=4, queue_cap=64,
                             capacity_fn=lambda: gauge.free("docker"),
                             stats_fn=lambda: gauge.stats("docker")),
        Tier.SERVERLESS: Backend(Tier.SERVERLESS, elastic_run, capacity=16),
    },
    policy=StraightLinePolicy(Thresholds(F=10, D=4096)),   # scaled-down thresholds
    window_s=10.0,
)

rng = np.random.default_rng(0)
N = 24
# a burst: submit everything at once -> f_t crosses F -> elastic absorbs it
for i in range(N):
    size = float(rng.choice([512.0, 16384.0], p=[0.8, 0.2]))   # bimodal payloads
    router.submit(Request(rid=i, arrival_t=0.0, data_size=size, timeout_s=120.0))
router.drain()

m = router.metrics
print(f"\n{N} requests: {m.summary()}")
by_tier = {t.name: sum(1 for r in m.completed if r.tier == t) for t in Tier}
print("placement:", by_tier)
print("live capacity after drain:", gauge.snapshot())
assert m.total == N and m.failure_rate == 0.0
print("OK — all requests served by real JAX paged engines through Algorithm 1,")
print("     with S_F/S_D read live from engine page pools")
