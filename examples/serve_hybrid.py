"""End-to-end driver (the paper's kind: serving): batched requests through
the StraightLine router onto three REAL JAX inference backends — with the
placer consuming LIVE capacity from the paged serving engines and every
engine tier fronted by a continuous-batching step loop.

Tiers (DESIGN.md §2):
  interactive — 1-slot paged engine, lowest latency, tiny page pool
  batch       — 8-slot paged engine over a shared KV page pool
  elastic     — engines spun up on demand (cold start = init + weight load)

Each engine is owned by a ``serving.scheduler.EngineLoop``: router workers
submit into the shared step loop and block on per-request futures, so
concurrent requests on one engine interleave inside a single decode batch
(instead of serializing whole generations on the engine lock). Prefill is
CHUNKED (``chunk_tokens=CHUNK``): a long prompt is absorbed a page-multiple
chunk per step under the engines' token budget, so it cannot stall the
interactive tier's decode batch for a whole prefill. Algorithm 1's
S_F/S_D availability checks pull through a CapacityGauge fed by each
engine's ``admission_capacity()`` (free slots bounded by free KV pages), and
the loop's ``capacity_now()`` additionally exports batch occupancy + queue
depth so telemetry sees true interleaved utilization.

    PYTHONPATH=src python examples/serve_hybrid.py
"""
import time

import numpy as np

from repro.configs.registry import get_config
from repro.core import CapacityGauge, Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter
from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine
from repro.serving.scheduler import EngineLoop

CFG = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
MAXLEN, NEW, PROMPT = 96, 8, 8
PS = 16
CHUNK = 32                    # chunked prefill: tokens absorbed per step

t0 = time.time()
interactive = PagedInferenceEngine(
    CFG, PagedEngineConfig(page_size=PS, num_pages=1 + MAXLEN // PS, max_slots=1,
                           max_seq_len=MAXLEN, max_new_tokens=NEW, chunk_tokens=CHUNK)
)
batch_tier = PagedInferenceEngine(
    CFG, PagedEngineConfig(page_size=PS, num_pages=1 + 4 * MAXLEN // PS, max_slots=8,
                           max_seq_len=MAXLEN, max_new_tokens=NEW, chunk_tokens=CHUNK),
    params=interactive.params,
)
print(f"tiers ready in {time.time()-t0:.1f}s")

# pre-warm: compile every prefill bucket before traffic arrives, so no
# request pays an XLA compile and the placer sees fully-warm tiers
for eng in (interactive, batch_tier):
    eng.prewarm()
print(f"batch tier: {batch_tier.capacity_now()}")

# one continuous-batching step loop per engine: all device stepping happens
# on the loop thread; submitters (router workers) only enqueue + wait
interactive_loop = EngineLoop(interactive).start()
batch_loop = EngineLoop(batch_tier).start()

# live capacity feedback: the placer sees each engine's measured admission
# capacity (slots bounded by free pages), not a hardcoded constant — plus
# warm-up state and batch occupancy through the loops' stats probes
gauge = CapacityGauge()
gauge.register("flask", lambda: interactive.admission_capacity(PROMPT + NEW))
gauge.register("docker", lambda: batch_tier.admission_capacity(PROMPT + NEW))
gauge.register_stats("flask", interactive_loop.capacity_now)
gauge.register_stats("docker", batch_loop.capacity_now)

elastic_pool = []


def prompt_for(req: Request):
    return list(np.random.default_rng(req.rid).integers(1, CFG.vocab_size, PROMPT))


def elastic_run(req: Request):
    # cold start: spin up a fresh engine + step loop (weights init = load
    # analogue); concurrent elastic requests then batch on it too
    if not elastic_pool:
        t = time.time()
        eng = PagedInferenceEngine(
            CFG, PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS,
                                   max_slots=4, max_seq_len=MAXLEN, max_new_tokens=NEW,
                                   chunk_tokens=CHUNK),
            params=interactive.params,
        )
        elastic_pool.append(EngineLoop(eng).start())
        print(f"  [elastic cold start: {time.time()-t:.1f}s]")
    loop = elastic_pool[0]
    return loop.wait(loop.submit(prompt_for(req)), req.timeout_s).out


def loop_backend(tier, loop, capacity, queue_cap):
    return Backend(
        tier,
        run=lambda req: loop.wait(loop.submit(prompt_for(req)), req.timeout_s).out,
        capacity=capacity, queue_cap=queue_cap,
        capacity_fn=lambda: gauge.free("flask" if tier == Tier.FLASK else "docker"),
        stats_fn=lambda: gauge.stats("flask" if tier == Tier.FLASK else "docker"),
        submit_fn=lambda req: loop.submit(prompt_for(req)),
        wait_fn=lambda sid, timeout: loop.wait(sid, timeout).out,
    )


router = StraightLineRouter(
    {
        Tier.FLASK: loop_backend(Tier.FLASK, interactive_loop, 1, 8),
        Tier.DOCKER: loop_backend(Tier.DOCKER, batch_loop, 8, 64),
        Tier.SERVERLESS: Backend(Tier.SERVERLESS, elastic_run, capacity=16),
    },
    policy=StraightLinePolicy(Thresholds(F=10, D=4096)),   # scaled-down thresholds
    window_s=10.0,
)

router.start(8)                      # worker pools keep the decode batches fed
rng = np.random.default_rng(0)
N = 24
# a burst: submit everything at once -> f_t crosses F -> elastic absorbs it
for i in range(N):
    size = float(rng.choice([512.0, 16384.0], p=[0.8, 0.2]))   # bimodal payloads
    router.submit(Request(rid=i, arrival_t=0.0, data_size=size, timeout_s=120.0))
router.drain()
router.stop()

m = router.metrics
print(f"\n{N} requests: {m.summary()}")
by_tier = {t.name: sum(1 for r in m.completed if r.tier == t) for t in Tier}
print("placement:", by_tier)
print("live capacity after drain:", gauge.snapshot())
print("batch tier occupancy gauge:", gauge.occupancy("docker"),
      "steps:", batch_loop.steps,
      "prefill backlog:", gauge.prefill_backlog("docker"))
for loop in [interactive_loop, batch_loop] + elastic_pool:
    loop.stop()
assert m.total == N and m.failure_rate == 0.0
print("OK — all requests served by real JAX paged engines through Algorithm 1,")
print("     batched by shared step loops with chunked (budgeted) prefill,")
print("     with S_F/S_D read live from page pools")
