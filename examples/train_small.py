"""Train a reduced model end-to-end with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_small.py [--steps 120] [--arch smollm-360m]
"""
import argparse
import shutil
import tempfile

from repro.configs.registry import get_config
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig
from repro.train.optimizer import OptConfig
from repro.train.schedule import WarmupCosine
from repro.train.trainer import TrainConfig, Trainer

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=120)
ap.add_argument("--arch", default="smollm-360m")
ap.add_argument("--ckpt-dir", default=None)
args = ap.parse_args()

ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="straightline_ckpt_")
cfg = get_config(args.arch, smoke=True).replace(attn_chunk=16, ce_chunks=2)
model = get_model(cfg)


def make_trainer(steps):
    return Trainer(
        model, None,
        TrainConfig(steps=steps, ckpt_every=20, ckpt_dir=ckpt_dir, log_every=10,
                    opt=OptConfig(lr=2e-3)),
        DataConfig(batch_size=4, seq_len=64, vocab_size=cfg.vocab_size, seed=7),
        schedule=WarmupCosine(peak_lr=2e-3, warmup_steps=10, total_steps=args.steps),
    )


half = args.steps // 2
print(f"training {args.arch} (smoke) for {half} steps, then simulating a crash...")
r1 = make_trainer(half).run(seed=0)
print(f"  crashed at step {r1['steps_done']}; latest checkpoint: {ckpt.latest_step(ckpt_dir)}")

print("restarting — auto-resume from checkpoint:")
r2 = make_trainer(args.steps).run(seed=0)
hist = r2["history"]
print(f"  resumed and finished at step {r2['steps_done']}")
print(f"  loss: {hist[0]['loss']:.3f} (start) -> {hist[-1]['loss']:.3f} (final)")
assert hist[-1]["loss"] < hist[0]["loss"]
shutil.rmtree(ckpt_dir, ignore_errors=True)
print("OK — checkpoint/restart training complete")
