import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import time

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

D = 512  # d_model
F = 2048  # d_ff
L = 8  # layers
B = 32  # batch


def layer(x, w1, w2):
    h = jnp.einsum("bd,df->bf", x, w1)
    h = jax.nn.gelu(h)
    x = x + jnp.einsum("bf,fd->bd", h, w2)
    return x


def model_scan(x, w1s, w2s):
    def body(x, ws):
        return layer(x, ws[0], ws[1]), None

    x, _ = jax.lax.scan(body, x, (w1s, w2s))
    return x.sum()


def model_unroll(x, w1s, w2s):
    for i in range(L):
        x = layer(x, w1s[i], w2s[i])
    return x.sum()


analytic_flops = L * 2 * B * D * F * 2  # two matmuls per layer
print("analytic flops:", analytic_flops / 1e9, "GF")

mesh = jax.make_mesh((2, 16, 16), ("pod", "data", "model"))
print("mesh ok:", mesh.shape)

xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
w1 = jax.ShapeDtypeStruct((L, D, F), jnp.float32)
w2 = jax.ShapeDtypeStruct((L, F, D), jnp.float32)

sh_x = NamedSharding(mesh, P(("pod", "data"), None))
sh_w1 = NamedSharding(mesh, P(None, None, "model"))
sh_w2 = NamedSharding(mesh, P(None, "model", None))

for name, fn in [("scan", model_scan), ("unroll", model_unroll)]:
    t0 = time.time()
    lowered = jax.jit(fn, in_shardings=(sh_x, sh_w1, sh_w2)).lower(xs, w1, w2)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    ca = compiled.cost_analysis()
    flops = ca.get("flops", -1)
    print(
        f"{name}: lower={t1-t0:.1f}s compile={t2-t1:.1f}s flops={flops/1e9:.3f}GF "
        f"(x512 dev = {flops*512/1e9:.1f}GF) ratio_vs_analytic={flops*512/analytic_flops:.3f}"
    )
    mem = compiled.memory_analysis()
    print(f"  mem: args={mem.argument_size_in_bytes} temp={mem.temp_size_in_bytes}")
    txt = compiled.as_text()
    import re

    colls = re.findall(r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)[^(]*\(", txt)
    from collections import Counter

    print("  collectives:", Counter(c.split("(")[0].strip() for c in colls))
    print("  hlo size:", len(txt))
