#!/usr/bin/env bash
# Tier-1 CI gate: install pinned dev deps (so hypothesis-based modules can't
# silently fail collection again) and run the repo's verify command.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q --retries 1 --timeout 5 -r requirements-dev.txt \
    || echo "ci.sh: pip install failed (offline?); continuing with preinstalled deps" >&2

# Hung-lock detection: the concurrency soak (tests/test_router_concurrency.py)
# must fail fast on a deadlock, not wedge CI. pytest-timeout's thread method
# fires even when worker threads are stuck on a lock; degrade gracefully when
# the plugin could not be installed (offline image).
TIMEOUT_ARGS=()
if python -c "import pytest_timeout" 2>/dev/null; then
    TIMEOUT_ARGS=(--timeout=300 --timeout-method=thread)
fi

# Static-analysis gate (stdlib-only, no model compiles, < 60 s): locklint +
# lockorder + kernelcheck over the serving stack with zero unexplained
# findings, the committed lock-order artifact fresh against the tree, and
# the analyzer/witness test subset green (engine-backed soaks deselected —
# the full pytest run below still exercises them). `scripts/ci.sh analyze`
# runs only this subset and exits, so it can gate before the slow suite.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.analysis \
    --check-graph docs/lock_order.json
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q \
    ${TIMEOUT_ARGS[@]+"${TIMEOUT_ARGS[@]}"} \
    tests/test_analysis.py tests/test_lock_witness.py tests/test_shutdown_safety.py \
    -k "not engine"
if [[ "${1:-}" == "analyze" ]]; then
    echo "ci.sh: analyze subset passed"
    exit 0
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q ${TIMEOUT_ARGS[@]+"${TIMEOUT_ARGS[@]}"} "$@"

# Model-config smoke subset (forward + grad + prefill/decode per family) so
# the script the ROADMAP names is actually exercised in CI; the grad leg
# doubles as a regression gate on the differentiable superblock barrier.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/smoke_models.py dense hybrid xlstm

# Continuous-batching smoke (tiny model, few steps): asserts concurrent
# requests actually interleave in one decode batch with outputs identical to
# the serialized baseline — the step loop cannot silently regress to
# serialized execution.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/continuous_batching.py --fast

# Chunked-prefill smoke: asserts the max inter-token decode gap while a
# max-length prompt prefills concurrently improves >= 2x with chunking on,
# at identical greedy outputs on both engines — chunking cannot silently
# regress to whole-prompt (head-of-line blocking) prefill.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/chunked_prefill.py --fast

# Prefix-cache smoke: asserts a multi-turn chat conversation's median TTFT
# improves >= 3x with the radix-tree prefix cache on (byte-identical greedy
# outputs vs cold prefill) while cache-on throughput on unique prompts — the
# no-hit worst case — stays within 5% of cache-off, so the cache can neither
# silently regress to full prefill nor tax workloads that never hit it.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/prefix_cache.py --fast

# Speculative-decoding smoke: asserts the paged engine emits >= 1.5x the
# tokens per step with n-gram speculation on (k=4) on a repetitive workload,
# at byte-identical greedy outputs and a fully reclaimed page pool — the
# draft/verify path can neither change tokens nor leak speculative pages.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/speculative_decode.py --fast

# Paged-decode + int8 KV smoke: asserts the paged engine serves >= 2x the
# dense engine's concurrent sequences from the same cache budget, and that
# an int8-quantized pool (values + per-page-slot scales) admits >= 1.8x the
# f32 pool's concurrent residents at EQUAL cache bytes — with greedy outputs
# token-identical in both comparisons, so capacity cannot be bought with
# silent output drift.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/paged_decode.py --fast

# Observability overhead gate: disabled tracing must be free (identical
# outputs, ~0 throughput cost) and enabled tracing + MonitorSampler bounded —
# instrumentation cannot silently become a tax on the serving hot path.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/observability_overhead.py --fast

# End-to-end observability smoke: serve_hybrid self-asserts the three
# artifacts (per-request lifecycle traces incl. dual-execution hedges,
# Prometheus text with TTFT/ITL histograms, MonitorSampler per-tier time
# series); re-validate the trace file parses as Chrome trace-event JSON.
OBS_TMP=$(mktemp -d)
TRACE_OUT="$OBS_TMP/trace.json" METRICS_OUT="$OBS_TMP/metrics.prom" \
    PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python examples/serve_hybrid.py
python - "$OBS_TMP" <<'PYEOF'
import json, sys, os
d = sys.argv[1]
doc = json.load(open(os.path.join(d, "trace.json")))
assert doc["traceEvents"], "empty Chrome trace"
prom = open(os.path.join(d, "metrics.prom")).read()
assert "ttft_seconds_bucket" in prom and "router_requests_total" in prom
print(f"observability smoke: {len(doc['traceEvents'])} trace events, "
      f"{len(prom.splitlines())} metric lines")
PYEOF
rm -rf "$OBS_TMP"
