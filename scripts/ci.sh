#!/usr/bin/env bash
# Tier-1 CI gate: install pinned dev deps (so hypothesis-based modules can't
# silently fail collection again) and run the repo's verify command.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q --retries 1 --timeout 5 -r requirements-dev.txt \
    || echo "ci.sh: pip install failed (offline?); continuing with preinstalled deps" >&2

# Hung-lock detection: the concurrency soak (tests/test_router_concurrency.py)
# must fail fast on a deadlock, not wedge CI. pytest-timeout's thread method
# fires even when worker threads are stuck on a lock; degrade gracefully when
# the plugin could not be installed (offline image).
TIMEOUT_ARGS=()
if python -c "import pytest_timeout" 2>/dev/null; then
    TIMEOUT_ARGS=(--timeout=300 --timeout-method=thread)
fi

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q ${TIMEOUT_ARGS[@]+"${TIMEOUT_ARGS[@]}"} "$@"

# Model-config smoke subset (forward + grad + prefill/decode per family) so
# the script the ROADMAP names is actually exercised in CI; the grad leg
# doubles as a regression gate on the differentiable superblock barrier.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/smoke_models.py dense hybrid xlstm

# Continuous-batching smoke (tiny model, few steps): asserts concurrent
# requests actually interleave in one decode batch with outputs identical to
# the serialized baseline — the step loop cannot silently regress to
# serialized execution.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/continuous_batching.py --fast

# Chunked-prefill smoke: asserts the max inter-token decode gap while a
# max-length prompt prefills concurrently improves >= 2x with chunking on,
# at identical greedy outputs on both engines — chunking cannot silently
# regress to whole-prompt (head-of-line blocking) prefill.
PYTHONPATH=src:.${PYTHONPATH:+:$PYTHONPATH} python benchmarks/chunked_prefill.py --fast
