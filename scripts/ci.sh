#!/usr/bin/env bash
# Tier-1 CI gate: install pinned dev deps (so hypothesis-based modules can't
# silently fail collection again) and run the repo's verify command.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q --retries 1 --timeout 5 -r requirements-dev.txt \
    || echo "ci.sh: pip install failed (offline?); continuing with preinstalled deps" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"

# Model-config smoke subset (forward + grad + prefill/decode per family) so
# the script the ROADMAP names is actually exercised in CI; the grad leg
# doubles as a regression gate on the differentiable superblock barrier.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python scripts/smoke_models.py dense hybrid xlstm
