#!/usr/bin/env bash
# Tier-1 CI gate: install pinned dev deps (so hypothesis-based modules can't
# silently fail collection again) and run the repo's verify command.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m pip install -q --retries 1 --timeout 5 -r requirements-dev.txt \
    || echo "ci.sh: pip install failed (offline?); continuing with preinstalled deps" >&2

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
