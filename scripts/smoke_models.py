"""Fast dev smoke: every family forward + grad + prefill/decode on CPU.

    python scripts/smoke_models.py              # all families
    python scripts/smoke_models.py dense xlstm  # named subset (CI runs one)
"""
import sys

import jax
import jax.numpy as jnp

from repro.models import EncoderCfg, MambaCfg, MoECfg, ModelConfig, ShapeSpec, XLSTMCfg, get_model

jnp_f32 = jnp.float32


def check(name, cfg, extra_batch=None):
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size), "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}
    if cfg.inputs == "embeds":
        batch = {
            "inputs_embeds": jax.random.normal(rng, (B, S, cfg.d_model), jnp_f32),
            "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S)).copy(),
            "labels": batch["labels"],
        }
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(rng, (B, cfg.encoder.n_ctx, cfg.d_model), jnp_f32)

    def lf(p):
        l, m = model.loss(None, p, batch)
        return l

    loss, grads = jax.value_and_grad(lf)(params)
    gnorm = jax.tree.reduce(lambda a, b: a + jnp.sum(jnp.abs(b.astype(jnp.float32))), grads, 0.0)
    assert jnp.isfinite(loss), (name, loss)
    assert jnp.isfinite(gnorm), (name, "grad")

    # prefill + decode
    pb = dict(batch)
    pb.pop("labels")
    tok, cache = model.prefill(None, params, pb, cap=S + 4)
    assert tok.shape == (B,), tok.shape
    db = {"token": tok[:, None], "cache_index": jnp.asarray(S, jnp.int32)}
    tok2, cache = model.decode(None, params, cache, db)
    assert tok2.shape == (B,)
    print(f"OK {name}: loss={float(loss):.4f}")


base = dict(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
    param_dtype=jnp_f32, compute_dtype=jnp_f32, remat="none", attn_chunk=8, ce_chunks=2,
)

CONFIGS = {
    "dense": ModelConfig(name="dense", family="dense", **base),
    "dense-bias-mha": ModelConfig(name="mha", family="dense", **{**base, "n_kv_heads": 4, "qkv_bias": True}),
    "moe": ModelConfig(name="moe", family="moe", moe=MoECfg(n_experts=4, top_k=2), **base),
    "hybrid": ModelConfig(
        name="hybrid", family="hybrid", block_pattern=("attn", "mamba"),
        mamba=MambaCfg(d_state=4, d_conv=4, expand=2, chunk=8),
        moe=MoECfg(n_experts=4, top_k=2, every_k=2), **base,
    ),
    "xlstm": ModelConfig(
        name="xlstm", family="ssm", block_pattern=("mlstm", "slstm"),
        xlstm=XLSTMCfg(chunk=8), **{**base, "d_ff": 0},
    ),
    "vlm": ModelConfig(name="vlm", family="vlm", inputs="embeds", pos="mrope", mrope_sections=(2, 3, 3), **base),
    "whisper": ModelConfig(
        name="whisper", family="audio", encoder=EncoderCfg(n_layers=2, n_ctx=12, n_heads=4, d_ff=128),
        cross_attn=True, norm="layernorm", act="gelu", gated_mlp=False,
        **{**base, "n_kv_heads": 4},
    ),
    "kvquant": ModelConfig(name="kvq", family="dense", kv_quant=True, **base),
}


def main(names) -> None:
    unknown = set(names) - set(CONFIGS)
    if unknown:
        raise SystemExit(f"unknown smoke config(s) {sorted(unknown)}; have {sorted(CONFIGS)}")
    selected = names or list(CONFIGS)
    for name in selected:
        check(name, CONFIGS[name])
    if names:
        print(f"MODEL SMOKES PASSED: {','.join(selected)}")
    else:
        print("ALL MODEL SMOKES PASSED")


if __name__ == "__main__":
    main(sys.argv[1:])
