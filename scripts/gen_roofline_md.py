"""Generate the EXPERIMENTS.md roofline table from dry-run JSON records."""
import json
import sys
from pathlib import Path

DRYRUN = Path("benchmarks/results/dryrun")
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = [
    "xlstm-350m", "smollm-360m", "glm4-9b", "granite-8b", "qwen1.5-32b",
    "jamba-1.5-large-398b", "dbrx-132b", "llama4-maverick-400b-a17b",
    "qwen2-vl-2b", "whisper-large-v3",
]

NOTES = {}


def fmt(v, unit=1e3, nd=1):
    return f"{v*unit:.{nd}f}"


def main(mesh="single"):
    print("| arch | shape | bound | compute (ms) | memory (ms) | collective (ms) | useful | mem GB/dev | adj GB/dev |")
    print("|---|---|---|---|---|---|---|---|---|")
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            p = DRYRUN / f"{mesh}__{arch}__{shape}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] == "skip":
                print(f"| {arch} | {shape} | SKIP | — | — | — | — | — | — |")
                continue
            if r["status"] != "ok":
                print(f"| {arch} | {shape} | ERROR | — | — | — | — | — | — |")
                continue
            t = r["roofline"]
            m = r["mem"]
            # TPU-adjusted fit: CPU backend hoists f32 upcasts of bf16 weights
            # (2x param bytes of artificial temp) — see §Dry-run notes.
            adj = m["per_device_total"] - 2 * r.get("params_bytes_per_dev", 0)
            print(
                f"| {arch} | {shape} | {t['bound']} | {fmt(t['compute_s'])} | "
                f"{fmt(t['memory_s'])} | {fmt(t['collective_s'])} | "
                f"{r['useful_compute_ratio']:.2f} | {m['per_device_total']/1e9:.1f} | "
                f"{max(adj,0)/1e9:.1f} |"
            )


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
