"""Training loop: jit'd step, checkpoint/restart, preemption handling.

Fault-tolerance contract (exercised by tests + examples):
  * checkpoint every N steps (atomic, manifest'd);
  * on start, auto-resume from the latest checkpoint (exact data-iterator
    state comes from the step counter — SyntheticLM/FileTokens are
    deterministic in (seed, step, shard));
  * SIGTERM/preemption => save-and-exit cleanly (save_on_exit);
  * restart reproduces the loss trajectory bit-for-bit on CPU (test).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig, make_source
from repro.train.optimizer import OptConfig, init_opt_state
from repro.train.schedule import Constant
from repro.launch.steps import make_train_step


@dataclass
class TrainConfig:
    steps: int = 200
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    opt: OptConfig = field(default_factory=OptConfig)


class Trainer:
    def __init__(self, model, ctx, tcfg: TrainConfig, dcfg: DataConfig, schedule=None):
        self.model = model
        self.ctx = ctx
        self.tcfg = tcfg
        self.dcfg = dcfg
        self.schedule = schedule or Constant(tcfg.opt.lr)
        self.source = make_source(dcfg)
        self.history: List[Dict[str, float]] = []
        self._preempted = False

        step_fn = make_train_step(model, ctx, tcfg.opt, schedule=self.schedule)
        self._jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    # -- preemption ---------------------------------------------------------
    def install_preemption_handler(self):
        def handler(signum, frame):
            self._preempted = True

        signal.signal(signal.SIGTERM, handler)

    # -- main loop ------------------------------------------------------------
    def run(self, params: Any = None, seed: int = 0) -> Dict[str, Any]:
        tcfg = self.tcfg
        start_step = 0
        opt_state = None
        if tcfg.ckpt_dir:
            last = ckpt.latest_step(tcfg.ckpt_dir)
            if last is not None:
                params = self.model.init(jax.random.PRNGKey(seed))  # structure
                opt_state = init_opt_state(params, tcfg.opt)
                state = ckpt.restore(tcfg.ckpt_dir, last, {"p": params, "o": opt_state})
                params, opt_state = state["p"], state["o"]
                start_step = last
        if params is None:
            params = self.model.init(jax.random.PRNGKey(seed))
        if opt_state is None:
            opt_state = init_opt_state(params, tcfg.opt)

        t0 = time.time()
        step = start_step
        for step in range(start_step, tcfg.steps):
            batch = {k: jax.numpy.asarray(v) for k, v in self.source.batch_at(step).items()}
            params, opt_state, metrics = self._jit_step(params, opt_state, batch)
            if step % tcfg.log_every == 0 or step == tcfg.steps - 1:
                m = {k: float(v) for k, v in metrics.items() if hasattr(v, "shape") or isinstance(v, (int, float))}
                m["step"] = step
                self.history.append(m)
            if tcfg.ckpt_dir and (step + 1) % tcfg.ckpt_every == 0:
                ckpt.save(tcfg.ckpt_dir, step + 1, {"p": params, "o": opt_state},
                          meta={"data_step": step + 1})
            if self._preempted:
                if tcfg.ckpt_dir:
                    ckpt.save(tcfg.ckpt_dir, step + 1, {"p": params, "o": opt_state},
                              meta={"preempted": True})
                break
        return {
            "params": params,
            "opt_state": opt_state,
            "history": self.history,
            "steps_done": step + 1,
            "wall_s": time.time() - t0,
            "preempted": self._preempted,
        }
