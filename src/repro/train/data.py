"""Token data pipeline: deterministic, shardable, resumable.

Sources:
  * SyntheticLM  — structured pseudo-language (Zipfian unigrams + local
    n-gram structure) so models can actually *learn* during smoke training;
  * FileTokens   — memory-mapped .bin of int32 tokens (production path);
both emit fixed-shape {tokens, labels} batches. The iterator state is a
single integer (step), so checkpoint/restore is exact, and each data-parallel
rank can slice its shard deterministically (shard_id / num_shards).
"""
from __future__ import annotations

from dataclasses import dataclass
from pathlib import Path
from typing import Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    batch_size: int = 8
    seq_len: int = 128
    vocab_size: int = 512
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    path: Optional[str] = None     # set -> FileTokens


class SyntheticLM:
    """Zipf unigrams mixed with a deterministic bigram chain — enough
    structure that cross-entropy drops visibly within ~100 steps."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._succ = rng.integers(0, v, size=v)          # bigram successor table
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.2
        self._p = p / p.sum()

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.num_shards + cfg.shard_id
        )
        B, S = cfg.batch_size, cfg.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab_size, size=B, p=self._p)
        for t in range(1, S + 1):
            follow = rng.random(B) < 0.7                  # 70% bigram-determined
            toks[:, t] = np.where(
                follow, self._succ[toks[:, t - 1]], rng.choice(cfg.vocab_size, size=B, p=self._p)
            )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileTokens:
    """Flat int32 token file; deterministic strided sampling by (step, rank)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")
        self.n = len(self.data) - cfg.seq_len - 1
        assert self.n > 0, "token file too small"

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.num_shards + cfg.shard_id
        )
        starts = rng.integers(0, self.n, size=cfg.batch_size)
        toks = np.stack([self.data[s : s + cfg.seq_len + 1] for s in starts]).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def make_source(cfg: DataConfig):
    return FileTokens(cfg) if cfg.path else SyntheticLM(cfg)
