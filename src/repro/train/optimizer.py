"""AdamW from scratch, with quantized optimizer states and ZeRO-1 sharding.

State dtypes:
  * float32  — default.
  * bfloat16 — halves optimizer HBM (e.g. jamba-398b on 256 chips).
  * int8     — blockwise-absmax quantized m and sqrt(v) (8-bit-Adam style);
               required to fit llama4-maverick's 778B params on the
               single-pod mesh (see EXPERIMENTS.md §Dry-run).

ZeRO-1: optimizer-state PartitionSpecs are derived with
``Rules(ctx, fsdp_params=True)`` so each state tensor additionally shards a
divisible dim over 'data'; XLA inserts the reduce-scatter/all-gather pair.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 128


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: str = "float32"   # float32 | bfloat16 | int8


# ---------------------------------------------------------------------------
# Blockwise int8 quantization of state tensors
# ---------------------------------------------------------------------------


def _block_for(d: int) -> int:
    """Largest block <= QBLOCK dividing the last dim (0 => unquantizable)."""
    b = QBLOCK
    while b > 4 and d % b != 0:
        b //= 2
    return b if d % b == 0 and b > 4 else 0


def quantize_blockwise(x: jax.Array) -> dict:
    """SHAPE-PRESERVING int8: q keeps x's shape (and therefore x's sharding —
    a flat layout forces SPMD resharding/replication storms against the
    param/grad shardings); scales are per last-dim block."""
    d = x.shape[-1] if x.ndim else 1
    b = _block_for(d)
    xf = x.astype(jnp.float32)
    if b == 0:  # tiny/odd leaf: store f32 "scale" as the value itself
        return {"q": jnp.zeros(x.shape, jnp.int8), "scale": xf[..., None] if x.ndim else xf}
    blocks = xf.reshape(*x.shape[:-1], d // b, b)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0            # (..., d//b)
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale[..., None]), -127, 127).astype(jnp.int8)
    return {"q": q.reshape(x.shape), "scale": scale}


def dequantize_blockwise(qs: Mapping, shape, dtype=jnp.float32) -> jax.Array:
    d = shape[-1] if shape else 1
    b = _block_for(d)
    if b == 0:
        return qs["scale"].reshape(shape).astype(dtype)
    q = qs["q"].reshape(*shape[:-1], d // b, b).astype(jnp.float32)
    x = q * qs["scale"][..., None]
    return x.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# State representation
# ---------------------------------------------------------------------------


def _encode_state(x: jax.Array, mode: str, signed: bool):
    if mode == "float32":
        return x.astype(jnp.float32)
    if mode == "bfloat16":
        return x.astype(jnp.bfloat16)
    if mode == "int8":
        # v is non-negative: quantize sqrt(v) to compress dynamic range
        return quantize_blockwise(x if signed else jnp.sqrt(x))
    raise ValueError(mode)


def _decode_state(s: Any, shape, mode: str, signed: bool) -> jax.Array:
    if mode in ("float32", "bfloat16"):
        return s.astype(jnp.float32)
    x = dequantize_blockwise(s, shape)
    return x if signed else x * x


def init_opt_state(params: Any, ocfg: OptConfig) -> dict:
    def z(p):
        return _encode_state(jnp.zeros(p.shape, jnp.float32), ocfg.state_dtype, True)

    def z2(p):
        return _encode_state(jnp.zeros(p.shape, jnp.float32), ocfg.state_dtype, False)

    return {
        "m": jax.tree.map(z, params),
        "v": jax.tree.map(z2, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_shapes(param_shapes: Any, ocfg: OptConfig) -> dict:
    """ShapeDtypeStruct tree matching init_opt_state (dry-run, no alloc)."""

    def enc_shape(p, signed):
        if ocfg.state_dtype == "float32":
            return jax.ShapeDtypeStruct(p.shape, jnp.float32)
        if ocfg.state_dtype == "bfloat16":
            return jax.ShapeDtypeStruct(p.shape, jnp.bfloat16)
        d = p.shape[-1] if p.shape else 1
        b = _block_for(d)
        if b == 0:
            sshape = p.shape + (1,) if p.shape else p.shape
            return {
                "q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
                "scale": jax.ShapeDtypeStruct(sshape, jnp.float32),
            }
        return {
            "q": jax.ShapeDtypeStruct(p.shape, jnp.int8),
            "scale": jax.ShapeDtypeStruct(p.shape[:-1] + (d // b,), jnp.float32),
        }

    return {
        "m": jax.tree.map(lambda p: enc_shape(p, True), param_shapes),
        "v": jax.tree.map(lambda p: enc_shape(p, False), param_shapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Update
# ---------------------------------------------------------------------------


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads: Any,
    opt_state: Mapping,
    params: Any,
    ocfg: OptConfig,
    lr: Optional[jax.Array] = None,
) -> Tuple[Any, dict, dict]:
    """Returns (new_params, new_opt_state, stats)."""
    step = opt_state["step"] + 1
    lr = ocfg.lr if lr is None else lr
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, ocfg.clip_norm / jnp.maximum(gnorm, 1e-12)) if ocfg.clip_norm else 1.0

    bc1 = 1.0 - ocfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - ocfg.b2 ** step.astype(jnp.float32)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_p = treedef.flatten_up_to(params)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])

    new_p, new_m, new_v = [], [], []
    for g, p, m_s, v_s in zip(flat_g, flat_p, flat_m, flat_v):
        g = g.astype(jnp.float32) * scale
        m = _decode_state(m_s, p.shape, ocfg.state_dtype, True)
        v = _decode_state(v_s, p.shape, ocfg.state_dtype, False)
        m = ocfg.b1 * m + (1.0 - ocfg.b1) * g
        v = ocfg.b2 * v + (1.0 - ocfg.b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        upd = mhat / (jnp.sqrt(vhat) + ocfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + ocfg.weight_decay * pf)
        new_p.append(pf.astype(p.dtype))
        new_m.append(_encode_state(m, ocfg.state_dtype, True))
        new_v.append(_encode_state(v, ocfg.state_dtype, False))

    return (
        jax.tree.unflatten(treedef, new_p),
        {"m": jax.tree.unflatten(treedef, new_m), "v": jax.tree.unflatten(treedef, new_v), "step": step},
        {"grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)},
    )
