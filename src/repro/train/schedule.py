"""Learning-rate schedules (pure functions of step)."""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class WarmupCosine:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    final_frac: float = 0.1

    def __call__(self, step):
        step = jnp.asarray(step, jnp.float32)
        warm = self.peak_lr * step / max(1, self.warmup_steps)
        prog = jnp.clip(
            (step - self.warmup_steps) / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0
        )
        cos = self.peak_lr * (self.final_frac + (1 - self.final_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
        return jnp.where(step < self.warmup_steps, warm, cos)


@dataclass(frozen=True)
class Constant:
    lr: float = 3e-4

    def __call__(self, step):
        return jnp.asarray(self.lr, jnp.float32)
