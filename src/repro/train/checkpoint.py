"""Sharded checkpointing with manifest + elastic re-mesh restore.

Layout::

    <dir>/step_000123/
        manifest.json      # tree structure, shapes, dtypes, step, meta
        arrays/<idx>.npy   # one file per leaf (host-gathered)

Every leaf is saved host-side (np.save). Restore is mesh-agnostic: arrays
are re-placed with jax.device_put against whatever shardings the *new* mesh
provides — this is the elastic re-mesh path (train on mesh A, resume on
mesh B), exercised by tests/test_checkpoint.py. Writes are atomic
(tmp-dir + rename) so a preemption mid-save never corrupts the latest
checkpoint; `latest_step` scans completed manifests only.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np


def _np_dtype(name: str) -> np.dtype:
    """np.dtype that understands ml_dtypes names (bfloat16, float8_*)."""
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(ml_dtypes, name))


def _flatten(tree: Any):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(dir_: str, step: int, tree: Any, meta: Optional[dict] = None) -> str:
    base = Path(dir_)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    (tmp / "arrays").mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    records = []
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        np.save(tmp / "arrays" / f"{i}.npy", arr)
        records.append({"idx": i, "shape": list(arr.shape), "dtype": str(arr.dtype)})
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "leaves": records,
        "meta": meta or {},
    }
    (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
    if final.exists():
        shutil.rmtree(final)
    os.replace(tmp, final)  # atomic publish
    return str(final)


def latest_step(dir_: str) -> Optional[int]:
    base = Path(dir_)
    if not base.exists():
        return None
    steps = []
    for p in base.glob("step_*"):
        if (p / "manifest.json").exists():
            steps.append(int(p.name.split("_")[1]))
    return max(steps) if steps else None


def restore(dir_: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Restore into the structure of ``like``; if ``shardings`` is given
    (a matching tree of NamedSharding/None), device_put each leaf with it —
    this is how a checkpoint from one mesh resumes on a different mesh."""
    path = Path(dir_) / f"step_{step:08d}"
    manifest = json.loads((path / "manifest.json").read_text())
    leaves, treedef = _flatten(like)
    assert manifest["n_leaves"] == len(leaves), (
        f"checkpoint has {manifest['n_leaves']} leaves, expected {len(leaves)}"
    )
    sh_leaves = (
        treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
    )
    out = []
    for i, (ref, sh) in enumerate(zip(leaves, sh_leaves)):
        arr = np.load(path / "arrays" / f"{i}.npy")
        if arr.dtype.kind == "V":  # ml_dtypes (bfloat16 etc.) round-trip
            arr = arr.view(_np_dtype(manifest["leaves"][i]["dtype"]))
        expect = tuple(getattr(ref, "shape", arr.shape))
        assert tuple(arr.shape) == expect, f"leaf {i}: {arr.shape} != {expect}"
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def load_meta(dir_: str, step: int) -> dict:
    path = Path(dir_) / f"step_{step:08d}" / "manifest.json"
    return json.loads(path.read_text())["meta"]
