"""Gradient compression for cross-pod data parallelism.

On the multi-pod mesh the gradient all-reduce crosses the (slow) inter-pod
links. ``compressed_psum`` implements an int8 block-quantized all-reduce via
shard_map: quantize locally -> all_gather int8 (+f32 block scales, ~1/128
overhead) -> dequantize+sum locally. Wire bytes drop ~4x vs f32 (2x vs bf16)
at the cost of (g-1)/g-fold gather vs reduce traffic; worthwhile when the
pod axis is small (g=2: gather 1x vs reduce 2x wire => ~4x saving vs f32
ring all-reduce). Error feedback (residual carrying) keeps training unbiased.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.compat import shard_map_nocheck
from repro.train.optimizer import QBLOCK


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array, int]:
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % QBLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, QBLOCK)
    scale = jnp.maximum(jnp.max(jnp.abs(blocks), axis=-1, keepdims=True) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, pad


def _dequantize(q: jax.Array, scale: jax.Array, pad: int, shape) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compressed_allreduce_local(x: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: int8 all-gather + local dequant-sum over axis_name."""
    q, scale, pad = _quantize(x)
    qs = jax.lax.all_gather(q, axis_name)          # (g, nb, QBLOCK) int8
    ss = jax.lax.all_gather(scale, axis_name)      # (g, nb, 1) f32
    g = qs.shape[0]
    total = jnp.zeros(x.shape, jnp.float32)
    for i in range(g):                              # g is small (pod axis)
        total = total + _dequantize(qs[i], ss[i], pad, x.shape)
    return total.astype(x.dtype)


def make_compressed_psum(mesh, axis_name: str, inner_spec):
    """Returns fn(x) = all-reduce of x over ``axis_name`` with int8 wire
    format, leaving other axes untouched. inner_spec: PartitionSpec of x."""

    def fn(x):
        def body(x_l):
            return compressed_allreduce_local(x_l, axis_name)

        return shard_map_nocheck(
            body, mesh=mesh, in_specs=(inner_spec,), out_specs=inner_spec
        )(x)

    return fn


class ErrorFeedback:
    """Residual error feedback for biased compressors: carry the quantization
    error into the next step (Karimireddy et al., 2019)."""

    @staticmethod
    def init(grads: Any) -> Any:
        return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    @staticmethod
    def apply(grads: Any, residual: Any, compress_fn) -> Tuple[Any, Any]:
        def one(g, r):
            corrected = g.astype(jnp.float32) + r
            q, scale, pad = _quantize(corrected)
            sent = _dequantize(q, scale, pad, corrected.shape)
            new_r = corrected - sent
            return compress_fn(sent.astype(g.dtype)), new_r

        pairs = jax.tree.map(one, grads, residual)
        outs = jax.tree.map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
        res = jax.tree.map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
        return outs, res
