"""Batched inference engine: prefill + decode with continuous batching.

One engine instance backs one tier slice. Slots hold independent sequences;
``step()`` admits waiting prompts into free slots (prefill, one at a time)
and advances all active slots together (batched decode) — standard
continuous batching (Orca/vLLM style) on a fixed slot count with a shared
max_len cache.

The jitted functions are built once per engine from the same step builders
the dry-run lowers, so what serves here is what was dry-run there.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_len: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stop early


@dataclass
class Sequence:
    sid: int
    prompt: List[int]
    out: List[int] = field(default_factory=list)
    done: bool = False


class InferenceEngine:
    def __init__(self, cfg, ecfg: EngineConfig, ctx=None, params=None, seed: int = 0):
        self.cfg = cfg
        self.ecfg = ecfg
        self.ctx = ctx
        self.model = get_model(cfg)
        self.params = params if params is not None else self.model.init(jax.random.PRNGKey(seed))
        B, L = ecfg.max_slots, ecfg.max_len
        self.cache = self.model.init_cache(B, L)
        self.slot_len = np.zeros(B, np.int32)        # tokens in cache per slot
        self.slot_seq: List[Optional[Sequence]] = [None] * B
        self.waiting: List[Sequence] = []
        self._sid = 0
        self._build()

    # -- jitted steps ---------------------------------------------------------
    def _build(self):
        model, ctx = self.model, self.ctx
        B, L = self.ecfg.max_slots, self.ecfg.max_len

        def prefill_slot(params, cache, tokens, slot, n_valid):
            """Prefill a single slot with a right-padded prompt of length L_p."""
            tok2 = tokens[None, :]                                   # (1, Lp)
            next_tok, mini = model.prefill(ctx, params, {"tokens": tok2}, cap=L)

            def write(full, part):
                # every cache leaf is (n_sb, B, ...); part has B=1 at axis 1
                return jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), slot, axis=1
                )

            cache = jax.tree.map(write, cache, mini)
            return next_tok[0], cache

        def decode_all(params, cache, last_tokens, lens):
            """One decode step for every slot; per-slot lengths drive the
            cache writes, masks and positions."""
            batch = {"token": last_tokens[:, None], "cache_index": jnp.max(lens), "lengths": lens}
            return model.decode(ctx, params, cache, batch)

        self._prefill = jax.jit(prefill_slot)
        self._decode = jax.jit(decode_all, donate_argnums=(1,))
        self._last = np.zeros(B, np.int32)

    # -- public API -------------------------------------------------------------
    def submit(self, prompt: List[int]) -> int:
        seq = Sequence(self._sid, list(prompt))
        self._sid += 1
        self.waiting.append(seq)
        return seq.sid

    def _admit(self) -> None:
        for i in range(self.ecfg.max_slots):
            if self.slot_seq[i] is None and self.waiting:
                seq = self.waiting.pop(0)
                toks = jnp.asarray(seq.prompt, jnp.int32)
                nxt, self.cache = self._prefill(
                    self.params, self.cache, toks, jnp.asarray(i), jnp.asarray(len(seq.prompt))
                )
                self.slot_seq[i] = seq
                self.slot_len[i] = len(seq.prompt)
                self._last[i] = int(nxt)
                seq.out.append(int(nxt))

    def step(self) -> List[Sequence]:
        """Admit + one decode step; returns sequences finished this step."""
        self._admit()
        active = [i for i in range(self.ecfg.max_slots) if self.slot_seq[i] is not None]
        finished: List[Sequence] = []
        if active:
            lens = jnp.asarray(self.slot_len)
            nxt, self.cache = self._decode(
                self.params, self.cache, jnp.asarray(self._last), lens
            )
            nxt = np.asarray(nxt)
            for i in active:
                seq = self.slot_seq[i]
                self.slot_len[i] += 1
                self._last[i] = nxt[i]
                seq.out.append(int(nxt[i]))
                if (
                    len(seq.out) >= self.ecfg.max_new_tokens
                    or int(nxt[i]) == self.ecfg.eos_id
                    or self.slot_len[i] >= self.ecfg.max_len - 1
                ):
                    seq.done = True
                    finished.append(seq)
                    self.slot_seq[i] = None
                    self.slot_len[i] = 0
        return finished

    def generate(self, prompts: List[List[int]], max_steps: int = 10000) -> List[Sequence]:
        """Synchronous convenience: run until all prompts finish."""
        done: List[Sequence] = []
        for p in prompts:
            self.submit(p)
        for _ in range(max_steps):
            done.extend(self.step())
            if not self.waiting and all(s is None for s in self.slot_seq):
                break
        return sorted(done, key=lambda s: s.sid)
