"""Batched inference engines: bucketed pad-aware prefill + continuous-batch
decode, one execution path from models to both cache layouts.

Two engines back the serving tiers:

* ``InferenceEngine`` (v1, dense): slots hold independent sequences over a
  fixed ``max_slots x max_len`` cache — every admitted sequence reserves a
  full ``max_len`` stripe up front (Orca-style continuous batching).

* ``PagedInferenceEngine`` (v2, paged): the KV cache is a shared pool of
  fixed-size pages (serving/paging.py); sequences own page lists, admission
  is gated on *free pages* rather than free slots, and page exhaustion
  preempts the newest sequence back to the waiting queue (recompute-style
  resume, vLLM-like). Prefill is *truly paged*: attention K/V scatter
  through the sequence's block-table row inside each layer
  (``model.prefill_paged``) — no dense per-length staging cache exists.

Bounded compilation (shared ``_EngineBase`` bucketing): every prompt — and
every preemption-resume context, which otherwise multiplies distinct
lengths — is right-padded to a power-of-two multiple of the page/bucket
unit, capped at the engine's length cap. Prefill therefore compiles at most
``num_buckets(unit, cap)`` = ceil(log2(cap/unit)) + 1 times regardless of
the traffic mix, instead of once per distinct context length; padding is
masked out of attention writes, logits, and the recurrent-state updates of
mamba/xlstm mixers (pad steps are identity), so bucketed serving is
token-for-token identical to unbucketed. ``compile_events`` — the number of
distinct prefill shapes executed — is exported through ``capacity_now()``
so the placer and telemetry can see warm-up state.

Chunked prefill + the per-step token budget: with ``chunk_tokens > 0`` a
prompt is no longer absorbed in one device call. Admission merely reserves
capacity (a slot; for the paged engine also the full context's pages) and
puts the slot in the PREFILLING state — a chunk cursor, the context being
absorbed, and an OFF-CACHE recurrent carry (models/api.py
``prefill_chunk``/``prefill_chunk_paged``). Each ``step()`` then shares one
token budget (``step_token_budget``, auto ``2*chunk_tokens``) between the
decode batch (one token per decoding slot) and at-most-a-few prefill
chunks, served FIFO by admission stamp with a one-chunk-per-step progress
guarantee — so a 4k-token prompt is absorbed over many iterations while
every decoding slot keeps emitting a token EVERY iteration, instead of
stalling behind the whole prefill (and ``_admit`` running up to max_slots
back-to-back full prefills). The final chunk installs the carry into the
decode cache, emits the same greedy token the whole-prompt prefill would,
and flips the slot to decoding under the unchanged stop conditions. With
chunking OFF the same budget still caps full-prefill admissions per step
(the first admission of each step is unconditional so nothing starves). Chunks reuse the bucket geometry capped at ``chunk_tokens``, so
compilation stays bounded (the shape bound only shrinks); PREFILLING slots
and remaining backlog tokens are exported through ``capacity_now()``
(``prefilling_slots`` / ``prefill_backlog_tokens``) so the placer can see a
tier digesting a long prompt.

Warm-up: ``prewarm(buckets)`` compiles the prefill path for every bucket
length (or a chosen subset) before traffic arrives, so the first real
request of each shape pays a warm dispatch instead of an XLA compile.
Pre-warmed shapes count toward ``compile_events``, and ``capacity_now()``
additionally exports ``total_buckets`` so the placer can compute a warm
fraction (``compile_events / total_buckets``) and steer traffic toward
warmed-up tiers while another is still compiling.

Thread-safety contract (loop-owned stepping): each engine owns a reentrant
``lock`` covering ALL state-mutating entry points — ``submit``, ``step``,
``generate``, ``fork``, ``prewarm`` — i.e. the host-side bookkeeping
(waiting queue, slots, page allocator/tables, compile-shape set) **and** the
jitted device calls, which donate their cache buffers and therefore must
never run concurrently. The intended serving topology is *one stepper, many
submitters*: a single ``serving.scheduler.EngineLoop`` background thread
owns all ``step()`` calls, while any number of threads call ``submit`` —
each step admits whatever has been submitted and decodes every active slot
in ONE batched device call, so concurrent requests interleave inside the
decode batch instead of serializing whole generations. There must be at
most ONE stepper at a time: the lock keeps concurrent ``step`` /
``generate`` calls memory-safe, but a ``step()`` returns finished sequences
only to ITS caller — a second stepper (e.g. ``generate`` racing a running
EngineLoop) can pop the other's completions, which then never reach that
stepper's bookkeeping. ``generate`` is the synchronous convenience and the
serialized benchmark baseline for an engine NOT owned by a loop, not the
serving path. The read-only telemetry — ``capacity_now``,
``admission_capacity``, ``free_slots``, ``compile_events`` — is deliberately
lock-free: it returns instantaneous, possibly-stale snapshots. Callers must
NOT assume a capacity probe still holds by the time their request reaches
the engine (admission re-checks under the lock), and must not touch engine
internals (``waiting``, ``slot_seq``, ``allocator``, ``cache``, the
``_chunk*`` PREFILLING state) without holding ``lock``. The chunked-prefill
state machine lives entirely inside ``step()`` under the engine lock — the
EngineLoop needs no new entry points to interleave chunk work with decode.

Warm-up cost: every prefill-shape compile (bucket miss or ``prewarm``) is
wall-timed into ``compile_ema_s``, an EMA exported via ``capacity_now()`` —
the placer weighs warm-up gaps against it (a one-bucket gap on a tiny model
is not worth a tier hop).

Cross-request prefix cache (paged engine, ``prefix_cache=True``): finished
sequences no longer free their pages — they retire them into a radix tree
(serving/prefix_cache.py) keyed by token-id page runs, and admission
matches every prompt against that tree first. The slot lifecycle contract
changes from *release == free* to **release-to-cache vs free**:

* RELEASE-TO-CACHE (the sequence finished normally): the full pages holding
  its prompt + output K/V transfer their allocator reference to the tree
  (duplicates of already-cached prefixes are freed); only the trailing
  partial page returns to the free list. The pages stay warm for the next
  request sharing the prefix.
* FREE (preemption, or cache off): every page reference is dropped as
  before — but pages shared with the tree survive under the tree's own
  reference, so preempting a prefix-hit sequence never invalidates the
  cache it was reading.

On a prefix HIT, admission attaches the matched pages to the front of the
new sequence's ``PageTable`` via the same ref-count machinery ``fork``
uses, pins the matched tree path, and enters the PREFILLING state with the
chunk cursor AT THE MATCH BOUNDARY — prefill runs only for the unmatched
suffix (the match is capped one token short of the context so the final
chunk always has a token to emit logits from). Preemption of a prefix-hit
sequence drops the pin; re-admission re-matches from scratch, so a resume
restarts at the *re-validated* boundary (the tree may have grown or evicted
in between), never blindly at the old one. ``fork`` of a cache-attached
sequence pins the tree path once more, keeping path pins == attached
sequences. Cached pages are "free-ish" capacity, not occupancy: whenever an
allocation would fail, cold (unpinned) tree leaves are evicted LRU-first
BEFORE any live sequence is preempted, and ``capacity_now()`` exports
``cached_pages`` / ``evictable_pages`` / ``prefix_hit_rate`` so the placer
counts evictable cache as reclaimable. The cache requires an attention-only
decoder: recurrent mixers (mamba/xlstm) carry per-slot state that cached
pages cannot restore.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import get_model
from repro.serving.paging import (
    NULL_PAGE,
    BlockAllocator,
    ChainedTables,
    OutOfPages,
    PageTable,
    bucket_lengths,
    bucket_tokens,
    num_buckets,
)
from repro.serving.prefix_cache import PrefixCache


def _apply_cache_dtype(cfg, choice: str):
    """Resolve an engine-level KV-cache storage choice onto the model config:
    "" inherits the model's own settings, "f32"/"bf16" set the non-quantized
    storage dtype, "int8" turns on KV quantization (values + per-token-head
    scales). The engine owns this knob because cache layout is a serving
    decision — the same checkpoint serves at any storage width."""
    if not choice:
        return cfg
    if choice == "int8":
        return cfg.replace(kv_quant=True)
    dt = {"f32": jnp.float32, "bf16": jnp.bfloat16}.get(choice)
    if dt is None:
        raise ValueError(f"cache_dtype must be '', 'f32', 'bf16' or 'int8', got {choice!r}")
    return cfg.replace(kv_quant=False, kv_cache_dtype=dt)


def _kv_dtype_name(cfg) -> str:
    """The KV-cache storage dtype as telemetry sees it."""
    return "int8" if cfg.kv_quant else jnp.dtype(cfg.kv_dtype).name


def _kv_bytes_per_token(cfg, cache, token_slots: int) -> float:
    """KV-cache bytes per cached-token slot across every attention layer —
    values plus scales for int8, so the placer converts free tokens to real
    bytes whatever the storage format. Recurrent-mixer state is per-slot,
    not per-token, and stays out of the ratio."""
    total = 0
    for i, kind in enumerate(cfg.block_pattern):
        if kind != "attn":
            continue
        for leaf in jax.tree.leaves(cache["blocks"][f"l{i}_mixer"]):
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total / max(1, token_slots)


@dataclass
class EngineConfig:
    max_slots: int = 4
    max_len: int = 256
    max_new_tokens: int = 32
    eos_id: int = -1            # -1: never stop early
    bucket_unit: int = 16       # prefill pad quantum (the dense "page unit")
    bucket_prefill: bool = True # False: one prefill compile per distinct length
    chunk_tokens: int = 0       # >0: chunked prefill, tokens per chunk (snapped
                                # to a bucket_unit multiple; must divide max_len)
    step_token_budget: int = 0  # per-step prefill+decode token budget
                                # (0 = auto: 2*chunk_tokens chunked, max_len not)
    spec_tokens: int = 0        # >0: n-gram speculative decoding, proposal
                                # tokens per slot per step (attention-only
                                # decoders; greedy-token-identical)
    spec_ngram: int = 3         # prompt-lookup match length for the proposer
    cache_dtype: str = ""       # KV-cache storage: "" inherit model config,
                                # "f32" | "bf16" | "int8" (int8 = quantized)


@dataclass
class Sequence:
    sid: int
    prompt: List[int]
    out: List[int] = field(default_factory=list)
    done: bool = False
    preemptions: int = 0
    # tokens of this sequence's context served from the prefix cache at its
    # most recent admission (0 = cold prefill / cache off); re-validated on
    # every preemption-resume, recorded into the prefix_matched_tokens
    # histogram by EngineLoop when the sequence finishes
    cached_tokens: int = 0
    # observability: submit timestamp + one monotonic stamp per emitted
    # token (TTFT = token_times[0] - submit_t; inter-token gaps = diffs).
    # Always recorded — one float append per token, noise next to a device
    # step — so latency histograms exist even without a tracer attached.
    submit_t: float = 0.0
    token_times: List[float] = field(default_factory=list)
    # lifecycle trace context (core/tracing.Trace) carried from the router
    # through EngineLoop.submit; None = untraced (zero-cost path)
    trace: Optional[object] = field(default=None, repr=False, compare=False)

    def context_tokens(self) -> List[int]:
        """Tokens that must be in cache to resume decoding (recompute)."""
        return list(self.prompt) + list(self.out)

    @property
    def lane(self) -> str:
        """Trace lane for this sequence's engine-side spans (a hedged
        request's two sids give two parallel lanes in one trace)."""
        return f"engine-sid{self.sid}"


class _EngineBase:
    """Shared continuous-batching scaffolding: submission bookkeeping, the
    stop conditions (applied identically at admission and after decode so
    the dense/paged engines stay token-for-token interchangeable), prefill
    length bucketing with its compile-event accounting, bucket pre-warming,
    the chunked-prefill (PREFILLING) state machine with its per-step token
    budget, and the synchronous generate loop. Subclasses provide ``step()``
    / ``_prewarm_shape()`` / ``_run_chunk_device()`` / ``_release_slot()``
    and set ``_max_new`` / ``_eos`` / ``_len_cap`` / ``_bucket_unit`` /
    ``_bucket_on`` / ``_chunk_tokens`` / ``_step_budget`` plus the per-slot
    chunk state (``_chunking`` / ``_chunk_pos`` / ``_chunk_ctx`` /
    ``_chunk_carry`` / ``_stamp``) and the reentrant ``lock`` (see the
    module docstring for the thread-safety contract)."""

    def free_slots(self) -> int:
        return sum(1 for s in self.slot_seq if s is None)

    def submit(self, prompt: List[int], trace=None) -> int:
        with self.lock:
            seq = Sequence(self._sid, list(prompt), submit_t=time.monotonic(), trace=trace)
            self._sid += 1
            self.waiting.append(seq)
            if trace is not None:
                trace.event("engine_submit", lane=seq.lane, t=seq.submit_t,
                            sid=seq.sid, prompt_tokens=len(prompt))
            return seq.sid

    # -- bucketed prefill shapes ---------------------------------------------
    def _bucket_len(self, n: int, cap: int = 0) -> int:
        if not self._bucket_on:
            return n
        return bucket_tokens(n, self._bucket_unit, cap or self._len_cap)

    def _pad_context(self, ctx_toks: List[int], cap: int = 0):
        """Right-pad a context to its bucket (capped at ``cap`` — the chunk
        size for chunked prefill, the length cap otherwise); returns
        (tokens, n_valid, Lp, fresh) where ``fresh`` marks a shape not
        executed before — the caller wall-times that prefill into the
        compile-cost EMA. Records the shape so ``compile_events`` tracks
        distinct prefill compilations (jit caches per shape, so #shapes ==
        #compiles)."""
        n = len(ctx_toks)
        Lp = self._bucket_len(n, cap)
        fresh = Lp not in self._prefill_shapes
        self._prefill_shapes.add(Lp)
        toks = np.zeros(Lp, np.int32)
        toks[:n] = ctx_toks
        return toks, n, Lp, fresh

    def _note_compile(self, dt_s: float) -> None:
        """Fold one measured compile wall time into the EMA the placer reads
        (``compile_ema_s`` in ``capacity_now()``)."""
        prev = self._compile_ema_s
        self._compile_ema_s = dt_s if prev is None else 0.5 * prev + 0.5 * dt_s

    @property
    def compile_ema_s(self) -> float:
        """EMA of prefill-compile wall time; 0.0 until a compile is measured
        (consumers treat 0 as unknown)."""
        return self._compile_ema_s or 0.0

    @property
    def compile_events(self) -> int:
        """Distinct prefill shapes executed so far — the engine's warm-up
        state. Placer/telemetry read it via ``capacity_now()``."""
        return len(self._prefill_shapes)

    @property
    def _shape_cap(self) -> int:
        """Largest prefill shape this engine executes: the chunk size when
        chunked prefill is on (whole prompts are absorbed chunk by chunk),
        the length cap otherwise."""
        return self._chunk_tokens or self._len_cap

    @property
    def total_buckets(self) -> int:
        """How many distinct prefill shapes bucketing can produce (0 when
        bucketing is off — the shape count is then unbounded, so no warm
        fraction exists). With chunked prefill on, shapes are capped at the
        chunk size, so the bound only shrinks."""
        return num_buckets(self._bucket_unit, self._shape_cap) if self._bucket_on else 0

    @property
    def step_budget(self) -> int:
        """Per-step token budget shared by the decode batch and prefill
        work. Auto (config 0): two chunks' worth when chunked prefill is on
        (one chunk + headroom keeps decode gaps bounded at ~one chunk), one
        max-length prefill's worth otherwise (caps back-to-back full
        prefills per step without deferring moderate admissions)."""
        if self._step_budget:
            return self._step_budget
        return 2 * self._chunk_tokens if self._chunk_tokens else self._len_cap

    # -- chunked prefill state machine -----------------------------------------
    def _resolve_chunking(self, cfg, chunk_tokens: int, unit: int, cap: int,
                          require_divisible: bool) -> int:
        """Validate + snap the chunk size: a positive multiple of the bucket
        unit/page size, capped at the length cap. The dense engine requires
        the cap to be a chunk multiple (its stripe writes would otherwise
        clamp at the edge); the paged engine's tail overruns are absorbed by
        the null page. Chunked prefill is decoder-only."""
        if not chunk_tokens:
            return 0
        if getattr(cfg, "encoder", None) is not None:
            raise ValueError("chunked prefill is decoder-only (no enc-dec support)")
        ct = min(-(-chunk_tokens // unit) * unit, cap)
        if require_divisible and cap % ct != 0:
            raise ValueError(
                f"chunk_tokens={ct} must divide the length cap {cap} "
                f"(dense stripe writes cannot overrun the cache edge)"
            )
        return ct

    def _init_chunk_slots(self, B: int) -> None:
        """Per-slot PREFILLING state: chunk cursor, the full context being
        absorbed, the off-cache recurrent carry (single owner for the field
        group — both engines init and clear through here)."""
        self._chunking = [False] * B
        self._chunk_pos = np.zeros(B, np.int32)
        self._chunk_ctx = [None] * B
        self._chunk_carry = [None] * B

    def _clear_chunk_slot(self, slot: int) -> None:
        self._chunking[slot] = False
        self._chunk_pos[slot] = 0
        self._chunk_ctx[slot] = None
        self._chunk_carry[slot] = None

    def _begin_chunked(self, slot: int, seq: Sequence, start: int = 0) -> None:
        """Move ``seq`` into ``slot`` in the PREFILLING state: no device work
        happens here — the budget-gated chunk phase (``_run_chunks``) absorbs
        the context over the following steps. ``slot_len`` tracks the chunk
        cursor so the batched decode's garbage write for this slot always
        lands on a position the next chunk (or the first decode) rewrites.

        ``start`` > 0 (paged engine, prefix-cache hit) begins the cursor at
        the match boundary: positions below ``start`` are already in cache
        on pages SHARED with the prefix tree, so no chunk may rewrite them —
        and since ``start`` is page-aligned, the garbage decode write at the
        cursor lands on the sequence's first exclusively-owned page."""
        self.slot_seq[slot] = seq
        self.slot_len[slot] = start
        self._chunking[slot] = True
        self._chunk_pos[slot] = start
        self._chunk_ctx[slot] = seq.context_tokens()
        self._chunk_carry[slot] = self.model.init_chunk_state()
        self._stamp[slot] = self._stamp_next
        self._stamp_next += 1
        if seq.trace is not None:
            seq.trace.event(
                "admitted", lane=seq.lane, slot=slot, chunked=True,
                ctx_tokens=len(self._chunk_ctx[slot]), resume=seq.preemptions,
                cached_tokens=start,
            )

    def _prefilling_slots(self) -> List[int]:
        """PREFILLING slots in admission order (FIFO chunk service)."""
        return sorted(
            (i for i in range(len(self.slot_seq)) if self._chunking[i]),
            key=lambda i: self._stamp[i],
        )

    @property
    def _chunk_unit(self) -> int:
        """Tokens absorbed per chunk step: the chunk size, or the full length
        cap when chunked prefill is off but the chunk machinery still runs
        (paged engine with the prefix cache on — a whole unmatched suffix is
        then one "chunk")."""
        return self._chunk_tokens or self._len_cap

    def _next_chunk_cost(self, slot: int) -> int:
        """Padded length of the slot's next chunk (budget accounting)."""
        remaining = len(self._chunk_ctx[slot]) - int(self._chunk_pos[slot])
        return self._bucket_len(min(remaining, self._chunk_unit), self._chunk_unit)

    def _run_chunks(self, spent: int, budget: int) -> int:
        """Budget-gated chunk phase: serve PREFILLING slots in admission
        order, at most ``budget - spent`` further prefill tokens this step —
        but ALWAYS at least one chunk when any slot is mid-prefill, so
        prefill can never starve behind a saturated decode batch (and a
        too-small budget degrades to one chunk per step, the design point:
        decode gaps bounded at ~one chunk of work)."""
        first = True
        for slot in self._prefilling_slots():
            while self._chunking[slot]:
                cost = self._next_chunk_cost(slot)
                if not first and spent + cost > budget:
                    return spent
                spent += cost
                self._chunk_step(slot)
                first = False
        return spent

    def _chunk_step(self, slot: int) -> None:
        """Run ONE prefill chunk for a PREFILLING slot. The final chunk
        installs the recurrent carry into the decode cache, emits the
        prefill token (from the chunk's last valid position — identical to
        the whole-prompt prefill's token) and transitions the slot to
        decoding, applying the same stop conditions as unchunked
        admission."""
        seq = self.slot_seq[slot]
        ctx = self._chunk_ctx[slot]
        pos = int(self._chunk_pos[slot])
        piece = ctx[pos : pos + self._chunk_unit]
        toks, n, _, fresh = self._pad_context(piece, cap=self._chunk_unit)
        tr = seq.trace
        tr0 = time.monotonic() if tr is not None else 0.0
        t0 = time.perf_counter()
        nxt = self._run_chunk_device(slot, toks, pos, n)
        if fresh:
            jax.block_until_ready(nxt)
            self._note_compile(time.perf_counter() - t0)
        if tr is not None:
            tr.add_span("prefill_chunk", tr0, time.monotonic(), lane=seq.lane,
                        offset=pos, tokens=n, fresh_compile=fresh)
        new_pos = pos + n
        self._chunk_pos[slot] = new_pos
        self.slot_len[slot] = new_pos
        if new_pos < len(ctx):
            return                                    # mid-prefill: token is garbage
        self.cache = self._install_carry(self.cache, self._chunk_carry[slot], jnp.asarray(slot))
        self._clear_chunk_slot(slot)              # PREFILLING -> decoding
        tok = int(nxt)
        self._last[slot] = tok
        seq.out.append(tok)
        seq.token_times.append(time.monotonic())  # the prefill-emitted token
        self.tokens_emitted += 1
        if self._stop_hit(seq, tok, int(self.slot_len[slot])):
            # the prefill-emitted token can already cross a stop condition
            seq.done = True
            self._just_finished.append(seq)
            self._release_slot(slot)

    def prefill_backlog_tokens(self) -> int:
        """Tokens of prompt context not yet absorbed: remaining chunk work
        across PREFILLING slots plus queued (unadmitted) contexts. Lock-free
        and possibly stale, like every capacity gauge."""
        backlog = 0
        for i in range(len(self.slot_seq)):
            ctx = self._chunk_ctx[i]             # snapshot: the stepper may
            if ctx is None:                      # null it out concurrently
                continue
            backlog += max(0, len(ctx) - int(self._chunk_pos[i]))
        try:
            backlog += sum(len(s.prompt) + len(s.out) for s in list(self.waiting))
        except RuntimeError:
            pass          # deque mutated mid-iteration: skip the stale part
        return backlog

    def prewarm(self, buckets: Optional[List[int]] = None) -> List[int]:
        """Compile the prefill path for the given bucket lengths (default:
        every bucket this engine can produce) before traffic arrives, so no
        real request pays an XLA compile. Each shape compiles at most once
        and counts toward ``compile_events``. Returns the lengths compiled.

        The warm-up prefill runs a zero prompt through an idle slot (paged:
        an all-null block-table row, so K/V writes land on the reserved
        garbage page); no live sequence state is disturbed. When every slot
        is busy the remaining shapes are skipped — prewarm is a startup
        API, not a mid-traffic one."""
        with self.lock:
            if buckets is None:
                if not self._bucket_on:
                    return []
                buckets = bucket_lengths(self._bucket_unit, self._shape_cap)
            warmed: List[int] = []
            for Lp in sorted({int(b) for b in buckets}):
                Lp = self._bucket_len(max(1, Lp), self._shape_cap)  # snap to a real bucket
                if Lp in self._prefill_shapes:
                    continue
                slot = next((i for i, s in enumerate(self.slot_seq) if s is None), None)
                if slot is None:
                    break
                t0 = time.perf_counter()
                self._prewarm_shape(Lp, slot)
                self._note_compile(time.perf_counter() - t0)
                self._prefill_shapes.add(Lp)
                warmed.append(Lp)
            return warmed

    def _stop_hit(self, seq: Sequence, tok: int, cache_len: int) -> bool:
        return (
            len(seq.out) >= self._max_new
            or tok == self._eos
            or cache_len >= self._len_cap - 1
        )

    # -- speculative decoding (n-gram / prompt-lookup proposer) -----------------
    def _resolve_spec(self, cfg, spec_tokens: int) -> int:
        """Validate the speculative-decoding config. The verify pass re-runs
        k+1 positions statelessly against the KV cache — recurrent mixers
        carry per-slot state a rolled-back verify cannot restore, so (like
        the prefix cache) speculation is attention-only."""
        if not spec_tokens:
            return 0
        if getattr(cfg, "encoder", None) is not None or any(
            kind != "attn" for kind in cfg.block_pattern
        ):
            raise ValueError(
                "spec_tokens requires an attention-only decoder: the verify "
                "pass replays positions statelessly, which recurrent mixers "
                "(mamba/xlstm) and enc-dec models cannot"
            )
        return spec_tokens

    def _init_spec(self) -> None:
        """Speculation + throughput accounting, read lock-free by
        ``capacity_now()`` and drained per step by ``EngineLoop``:
        ``tokens_emitted`` counts EVERY emitted token (prefill-emitted,
        decoded, speculative) so tokens-per-step is a pure delta;
        ``spec_runs`` holds this step's accepted-run lengths (proposal
        tokens accepted per verify, cleared at step start); the cumulative
        ``spec_proposed`` / ``spec_accepted`` give the lifetime acceptance
        rate."""
        self.tokens_emitted = 0
        self.spec_runs: List[int] = []
        self.spec_proposed = 0
        self.spec_accepted = 0

    def _propose(self, seq: Sequence) -> Optional[List[int]]:
        """Prompt-lookup proposal for one decoding slot: match the context's
        last ``spec_ngram`` tokens against their most recent earlier
        occurrence and propose the continuation, padded to ``spec_tokens``
        with 0s (padding is safe — acceptance only ever keeps tokens that
        EQUAL the model's greedy choice, wherever the proposal came from).
        Deterministic in the context alone, so a preempted-and-resumed
        sequence re-proposes identically. Returns None when no match — the
        slot degrades to plain batched decode this step."""
        k, n = self._spec_tokens, self._spec_ngram
        ctx = seq.context_tokens()
        L = len(ctx)
        if L < n + 1:
            return None
        tail = ctx[-n:]
        for i in range(L - n - 1, -1, -1):
            if ctx[i : i + n] == tail:
                cont = ctx[i + n : i + n + k]
                return cont + [0] * (k - len(cont))
        return None

    def _accept_verified(self, slot: int, seq: Sequence, proposal: List[int],
                         toks, k_eff: int):
        """Accept the longest matching run of a verify pass and advance the
        slot's write-head. ``toks[j]`` is the model's greedy token after
        verify position offset+j (position 0 re-ran the pending last token,
        1..k_eff the proposal) — token j+1 is trustworthy iff every proposal
        token before it matched the greedy chain, so we emit tokens until
        the first mismatch, always at least one (the plain-decode token) and
        at most k_eff+1 (all proposals plus the free bonus token). The final
        emitted token becomes the slot's new pending ``_last`` — NOT yet in
        cache, exactly the batched-decode convention — which is what makes
        the cache provably valid: positions L..L+m-1 hold the previous
        pending token plus accepted proposals, all equal to the greedy
        stream. Stop conditions apply per accepted token (EOS mid-run ends
        the run). Returns (m, done): tokens emitted, stop hit."""
        L0 = int(self.slot_len[slot])
        m = 0
        done = False
        tok_t = time.monotonic()          # one stamp per verify pass
        while True:
            tok = int(toks[m])
            m += 1
            seq.out.append(tok)
            seq.token_times.append(tok_t)
            self._last[slot] = tok
            self.tokens_emitted += 1
            if self._stop_hit(seq, tok, L0 + m):
                done = True
                break
            if m > k_eff or proposal[m - 1] != tok:
                break
        self.slot_len[slot] = L0 + m
        accepted = m - 1
        self.spec_proposed += k_eff
        self.spec_accepted += accepted
        self.spec_runs.append(accepted)
        if seq.trace is not None:
            seq.trace.event(
                "spec_accept" if accepted else "spec_reject", lane=seq.lane,
                slot=slot, proposed=k_eff, accepted=accepted,
            )
        return m, done

    def generate(self, prompts: List[List[int]], max_steps: int = 10000) -> List[Sequence]:
        """Synchronous convenience AND the serialized benchmark baseline:
        runs until all prompts finish while holding the engine lock
        end-to-end, so concurrent callers serialize whole generations. The
        serving path is ``serving.scheduler.EngineLoop`` — submit into its
        shared step loop and concurrent requests interleave in one decode
        batch instead (benchmarks/continuous_batching.py measures the gap)."""
        with self.lock:
            done: List[Sequence] = []
            for p in prompts:
                self.submit(p)
            for _ in range(max_steps):
                done.extend(self.step())
                if not self.waiting and all(s is None for s in self.slot_seq):
                    break
            return sorted(done, key=lambda s: s.sid)


class InferenceEngine(_EngineBase):
    def __init__(self, cfg, ecfg: EngineConfig, ctx=None, params=None, seed: int = 0):
        cfg = _apply_cache_dtype(cfg, ecfg.cache_dtype)
        self.cfg = cfg
        self.ecfg = ecfg
        self.ctx = ctx
        self.model = get_model(cfg)
        self.params = params if params is not None else self.model.init(jax.random.PRNGKey(seed))
        self._max_new, self._eos, self._len_cap = ecfg.max_new_tokens, ecfg.eos_id, ecfg.max_len
        self._bucket_unit, self._bucket_on = ecfg.bucket_unit, ecfg.bucket_prefill
        self._chunk_tokens = self._resolve_chunking(
            cfg, ecfg.chunk_tokens, ecfg.bucket_unit, ecfg.max_len, require_divisible=True
        )
        self._spec_tokens = self._resolve_spec(cfg, ecfg.spec_tokens)
        self._spec_ngram = max(1, ecfg.spec_ngram)
        self._init_spec()
        self._step_budget = ecfg.step_token_budget
        self._prefill_shapes = set()
        self._compile_ema_s: Optional[float] = None
        self.lock = threading.RLock()  # locklint: blocking-ok one stepper owns the donated buffers
        B, L = ecfg.max_slots, ecfg.max_len
        self.cache = self.model.init_cache(B, L)
        self._kv_bytes_per_token = _kv_bytes_per_token(cfg, self.cache, B * L)
        self.slot_len = np.zeros(B, np.int32)        # tokens in cache per slot
        self.slot_seq: List[Optional[Sequence]] = [None] * B
        self.waiting: Deque[Sequence] = deque()
        self._sid = 0
        self._just_finished: List[Sequence] = []
        self._init_chunk_slots(B)
        self._stamp = np.zeros(B, np.int64)   # admission order (chunk FIFO)
        self._stamp_next = 1
        self._build()

    # -- jitted steps ---------------------------------------------------------
    def _build(self):
        model, ctx = self.model, self.ctx
        B, L = self.ecfg.max_slots, self.ecfg.max_len

        def prefill_slot(params, cache, tokens, slot, n_valid):
            """Prefill a single slot with a right-padded prompt of length L_p;
            positions >= n_valid are bucket padding, masked out of every
            stateful update and of the emitted logits."""
            tok2 = tokens[None, :]                                   # (1, Lp)
            next_tok, mini = model.prefill(
                ctx, params, {"tokens": tok2, "n_valid": n_valid[None]}, cap=L
            )

            def write(full, part):
                # every cache leaf is (n_sb, B, ...); part has B=1 at axis 1
                return jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), slot, axis=1
                )

            cache = jax.tree.map(write, cache, mini)
            return next_tok[0], cache

        def decode_all(params, cache, last_tokens, lens):
            """One decode step for every slot; per-slot lengths drive the
            cache writes, masks and positions."""
            batch = {"token": last_tokens[:, None], "cache_index": jnp.max(lens), "lengths": lens}
            return model.decode(ctx, params, cache, batch)

        def prefill_chunk_slot(params, cache, tokens, slot, offset, n_valid, carry):
            """One chunked-prefill step against the slot's stripe: slice the
            mini cache out, run the resumable chunk (K/V written at
            ``offset``, recurrent state rides ``carry``), write the stripe
            back. Compiles once per chunk bucket — offset/slot/n_valid are
            all dynamic."""
            mini = jax.tree.map(
                lambda full: jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=1), cache
            )
            batch = {"tokens": tokens[None, :], "n_valid": n_valid[None], "offset": offset}
            nxt, mini, carry = model.prefill_chunk(ctx, params, batch, mini, carry)

            def write(full, part):
                return jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), slot, axis=1
                )

            return nxt[0], jax.tree.map(write, cache, mini), carry

        def verify_slot(params, cache, tokens, slot, offset):
            """Speculative verify against the slot's stripe: slice the mini
            cache out, write all k+1 verify tokens at ``offset`` and read
            the greedy token at EVERY position in one pass (a verify step is
            a chunk — same stripe write + absolute-position masking as
            ``prefill_chunk_slot``, no recurrent carry). Compiles once per
            k_eff (at most spec_tokens shapes)."""
            mini = jax.tree.map(
                lambda full: jax.lax.dynamic_slice_in_dim(full, slot, 1, axis=1), cache
            )
            toks, mini = model.verify(
                ctx, params, {"tokens": tokens[None, :], "offset": offset}, mini
            )

            def write(full, part):
                return jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), slot, axis=1
                )

            return toks[0], jax.tree.map(write, cache, mini)

        self._prefill = jax.jit(prefill_slot)
        self._decode = jax.jit(decode_all, donate_argnums=(1,))
        self._prefill_chunk = jax.jit(prefill_chunk_slot, donate_argnums=(1, 6))
        self._verify = jax.jit(verify_slot, donate_argnums=(1,))
        self._install_carry = jax.jit(model.install_chunk_state, donate_argnums=(0,))
        self._last = np.zeros(B, np.int32)

    def _run_chunk_device(self, slot: int, toks, offset: int, n: int):
        nxt, self.cache, self._chunk_carry[slot] = self._prefill_chunk(
            self.params,
            self.cache,
            jnp.asarray(toks),
            jnp.asarray(slot),
            jnp.asarray(offset),
            jnp.asarray(n),
            self._chunk_carry[slot],
        )
        return nxt

    # -- capacity telemetry ------------------------------------------------------
    def capacity_now(self) -> Dict[str, int]:
        """Live capacity snapshot for the placer (core/telemetry.py gauge).
        The dense engine reserves max_len cache tokens per admitted slot."""
        free = self.free_slots()
        return {
            "free_slots": free,
            "num_slots": self.ecfg.max_slots,
            "free_cache_tokens": free * self.ecfg.max_len,
            "cache_tokens": self.ecfg.max_slots * self.ecfg.max_len,
            "kv_cache_dtype": _kv_dtype_name(self.cfg),
            "kv_bytes_per_token": self._kv_bytes_per_token,
            "waiting": len(self.waiting),
            "compile_events": self.compile_events,
            "total_buckets": self.total_buckets,
            "compile_ema_s": self.compile_ema_s,
            "prefilling_slots": sum(self._chunking),
            "prefill_backlog_tokens": self.prefill_backlog_tokens(),
            "chunk_tokens": self._chunk_tokens,
            "spec_tokens": self._spec_tokens,
            "tokens_emitted": self.tokens_emitted,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
        }

    def admission_capacity(self, est_tokens: int = 0) -> int:
        """How many more requests this engine can admit right now."""
        return self.free_slots()

    # -- public API -------------------------------------------------------------
    def _prewarm_shape(self, Lp: int, slot: int) -> None:
        """Compile (and discard) a prefill at shape ``Lp``. With chunked
        prefill on, traffic runs the CHUNK path, so that is what gets
        compiled — its stray writes land in a free slot's stripe, which is
        causally masked for any future occupant. The plain dense prefill
        does not donate its cache argument, so dropping the returned cache
        leaves engine state untouched."""
        toks = np.zeros(Lp, np.int32)
        if self._chunk_tokens:
            _, self.cache, _ = self._prefill_chunk(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(slot),
                jnp.asarray(0), jnp.asarray(1), self.model.init_chunk_state(),
            )
            return
        self._prefill(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(slot), jnp.asarray(1)
        )

    def _release_slot(self, slot: int) -> None:
        self.slot_seq[slot] = None
        self.slot_len[slot] = 0
        self._clear_chunk_slot(slot)
        self._stamp[slot] = 0

    def _admit(self, spent: int = 0, budget: int = 0) -> int:
        """Budget-gated admission. Chunked: free slots become PREFILLING at
        no device cost (the chunk phase spends the budget). Unchunked: the
        FIRST prefill of a step is always admitted (progress guarantee — a
        single long prompt must not starve behind a busy decode batch), but
        every further one must fit ``budget`` — a queue burst can no longer
        run up to max_slots full back-to-back device prefills in one
        iteration while every active sequence stalls. Returns the updated
        spend. (Called bare — budget 0 — it resolves ``step_budget``.)"""
        budget = budget or self.step_budget
        admitted = False
        for i in range(self.ecfg.max_slots):
            if self.slot_seq[i] is not None or not self.waiting:
                continue
            if self._chunk_tokens:
                self._begin_chunked(i, self.waiting.popleft())
                continue
            Lp = self._bucket_len(len(self.waiting[0].prompt))
            if admitted and spent + Lp > budget:
                break                        # over budget: stays queued
            seq = self.waiting.popleft()
            toks, n, _, fresh = self._pad_context(seq.prompt)
            tr = seq.trace
            tr0 = time.monotonic() if tr is not None else 0.0
            t0 = time.perf_counter()
            nxt, self.cache = self._prefill(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(i), jnp.asarray(n)
            )
            if fresh:
                jax.block_until_ready(nxt)
                self._note_compile(time.perf_counter() - t0)
            if tr is not None:
                tr.add_span("prefill", tr0, time.monotonic(), lane=seq.lane,
                            slot=i, tokens=n, fresh_compile=fresh)
            spent += Lp
            admitted = True
            self.slot_seq[i] = seq
            self.slot_len[i] = n
            self._last[i] = int(nxt)
            seq.out.append(int(nxt))
            seq.token_times.append(time.monotonic())
            self.tokens_emitted += 1
            if self._stop_hit(seq, int(nxt), int(self.slot_len[i])):
                # the prefill-emitted token can already cross a stop
                # condition (max_new_tokens=1, or greedy EOS on prompt)
                seq.done = True
                self._just_finished.append(seq)
                self._release_slot(i)
        return spent

    def _spec_phase(self, active: List[int], spent: int, budget: int):
        """Speculate on decoding slots at the decode frontier: per slot with
        a proposal and budget headroom, one verify pass (k_eff+1 positions)
        replaces this step's plain decode token with the accepted run.
        Rollback is trivial for the dense engine — the write-head
        (``slot_len``) simply stops at the accepted length; rejected stripe
        positions are hidden by the length masks and overwritten by the
        next write at that position. Returns (speculated slots, spent)."""
        sped: List[int] = []
        for slot in active:
            seq = self.slot_seq[slot]
            L = int(self.slot_len[slot])
            k_eff = min(self._spec_tokens, self._len_cap - 1 - L)
            if k_eff < 1 or spent + k_eff > budget:
                continue
            proposal = self._propose(seq)
            if proposal is None:
                continue
            toks, self.cache = self._verify(
                self.params,
                self.cache,
                jnp.asarray(
                    np.asarray([int(self._last[slot])] + proposal[:k_eff], np.int32)
                ),
                jnp.asarray(slot),
                jnp.asarray(L),
            )
            spent += k_eff
            _, done = self._accept_verified(slot, seq, proposal, np.asarray(toks), k_eff)
            sped.append(slot)
            if done:
                seq.done = True
                self._just_finished.append(seq)
                self._release_slot(slot)
        return sped, spent

    def step(self) -> List[Sequence]:
        """Admit (budget-gated) + chunk work + speculation + one decode
        step; returns sequences finished this step. PREFILLING slots are
        excluded from the host-side decode bookkeeping — the batched device
        decode still sweeps them, but its writes land on the chunk cursor
        (rewritten by the next chunk) and the authoritative recurrent state
        rides the off-cache carry until install. Speculated slots are
        likewise excluded: the sweep's write of their pending token at the
        new write-head is idempotent with the next step's decode write
        (same token, same position), so only the host bookkeeping skips
        them."""
        with self.lock:
            budget = self.step_budget
            self.spec_runs = []
            spent = sum(
                1 for i, s in enumerate(self.slot_seq)
                if s is not None and not self._chunking[i]
            )
            spent = self._admit(spent, budget)
            if self._chunk_tokens:
                spent = self._run_chunks(spent, budget)
            active = [
                i for i in range(self.ecfg.max_slots)
                if self.slot_seq[i] is not None and not self._chunking[i]
            ]
            if self._spec_tokens and active:
                sped, spent = self._spec_phase(active, spent, budget)
                active = [i for i in active if i not in set(sped)]
            finished, self._just_finished = self._just_finished, []
            if active:
                lens = jnp.asarray(self.slot_len)
                nxt, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(self._last), lens
                )
                nxt = np.asarray(nxt)
                tok_t = time.monotonic()      # one stamp per batched decode step
                for i in active:
                    seq = self.slot_seq[i]
                    self.slot_len[i] += 1
                    self._last[i] = nxt[i]
                    seq.out.append(int(nxt[i]))
                    seq.token_times.append(tok_t)
                    self.tokens_emitted += 1
                    if self._stop_hit(seq, int(nxt[i]), int(self.slot_len[i])):
                        seq.done = True
                        finished.append(seq)
                        self._release_slot(i)
            return finished


# ---------------------------------------------------------------------------
# Paged engine (v2)
# ---------------------------------------------------------------------------


@dataclass
class PagedEngineConfig:
    page_size: int = 16
    num_pages: int = 64          # pool size, incl. the reserved null page 0
    max_slots: int = 8           # decode batch width
    max_seq_len: int = 256       # block-table width = ceil(max_seq_len / page_size)
    max_new_tokens: int = 32
    eos_id: int = -1
    bucket_prefill: bool = True  # pad prefill to power-of-two page buckets
    chunk_tokens: int = 0        # >0: chunked prefill, tokens per chunk
                                 # (snapped to a page multiple)
    step_token_budget: int = 0   # per-step prefill+decode token budget
                                 # (0 = auto: 2*chunk_tokens chunked, cap not)
    prefix_cache: bool = False   # cross-request prefix cache: finished
                                 # sequences retire their pages into a radix
                                 # tree; new prompts skip prefill for cached
                                 # prefixes (attention-only decoders). Off by
                                 # default: release-to-cache retains pages, a
                                 # semantic change callers must opt into.
    spec_tokens: int = 0         # >0: n-gram speculative decoding, proposal
                                 # tokens per slot per step (attention-only
                                 # decoders; greedy-token-identical)
    spec_ngram: int = 3          # prompt-lookup match length for the proposer
    cache_dtype: str = ""        # KV-pool storage: "" inherit model config,
                                 # "f32" | "bf16" | "int8" (int8 = quantized
                                 # pool + per-(page-slot, head) scales)
    chained_tables: bool = False # two-level block tables: per-slot first-level
                                 # rows of table-page ids resolve through a
                                 # shared second-level pool — lifts the
                                 # num_pages >= table_width coupling, so
                                 # max_seq_len can exceed what a flat row
                                 # over this pool could address
    table_page_entries: int = 0  # chained: physical pages per second-level
                                 # row (0 = page_size)

    @property
    def table_width(self) -> int:
        return -(-self.max_seq_len // self.page_size)

    @property
    def cache_tokens(self) -> int:
        """Usable cache budget in tokens (null page excluded)."""
        return (self.num_pages - 1) * self.page_size


class PagedInferenceEngine(_EngineBase):
    """Continuous batching over a paged KV cache.

    Differences from the dense engine:
      * a sequence holds ceil(len/page_size) pages, not a max_len stripe —
        short sequences leave the rest of the pool for others;
      * admission is gated on the free list (pages for prompt + 1 token);
      * when a growing sequence needs a page and the pool is dry, the newest
        admitted sequence is preempted back to the waiting queue; on
        re-admission its full context (prompt + generated tokens) is
        re-prefilled, which under greedy decoding reproduces the identical
        continuation;
      * ``fork()`` clones a running sequence sharing its full prefix pages
        (ref-counted) — only the trailing partial page is copied.
    """

    def __init__(self, cfg, pcfg: PagedEngineConfig, ctx=None, params=None, seed: int = 0):
        cfg = _apply_cache_dtype(cfg, pcfg.cache_dtype)
        self.cfg = cfg
        self.pcfg = pcfg
        self.ctx = ctx
        if not pcfg.chained_tables and pcfg.num_pages - 1 < pcfg.table_width:
            # one max-length sequence must always fit, else admission can
            # stall forever and the sole active sequence can never grow.
            # Chained tables drop this coupling: the admission cap is
            # re-derived from pool capacity instead (see _len_cap below).
            raise ValueError(
                f"num_pages={pcfg.num_pages} cannot hold one max_seq_len={pcfg.max_seq_len} "
                f"sequence ({pcfg.table_width} pages + reserved null page)"
            )
        if pcfg.prefix_cache and (
            any(kind != "attn" for kind in cfg.block_pattern)
            or getattr(cfg, "encoder", None) is not None
        ):
            raise ValueError(
                "prefix_cache requires an attention-only decoder: recurrent "
                "mixers carry per-slot state that cached pages cannot restore"
            )
        self.model = get_model(cfg)
        self.params = params if params is not None else self.model.init(jax.random.PRNGKey(seed))
        self._max_new, self._eos = pcfg.max_new_tokens, pcfg.eos_id
        # Chained tables decouple max_seq_len from the pool: the admission
        # length cap is then whatever the POOL can hold (one sequence can
        # never exceed cache_tokens without self-deadlocking on growth) —
        # with flat tables the constructor check above already guarantees
        # max_seq_len <= cache_tokens.
        self._len_cap = (
            min(pcfg.max_seq_len, pcfg.cache_tokens)
            if pcfg.chained_tables else pcfg.max_seq_len
        )
        self._bucket_unit, self._bucket_on = pcfg.page_size, pcfg.bucket_prefill
        self._chunk_tokens = self._resolve_chunking(
            cfg, pcfg.chunk_tokens, pcfg.page_size, self._len_cap,
            require_divisible=False,   # tail overruns land on the null page
        )
        self._spec_tokens = self._resolve_spec(cfg, pcfg.spec_tokens)
        self._spec_ngram = max(1, pcfg.spec_ngram)
        self._init_spec()
        self._step_budget = pcfg.step_token_budget
        self._prefill_shapes = set()
        self._compile_ema_s: Optional[float] = None
        self.lock = threading.RLock()  # locklint: blocking-ok one stepper owns the donated buffers
        B = pcfg.max_slots
        if pcfg.chained_tables:
            # Second-level geometry: a sequence can hold at most
            # min(table_width, num_pages - 1) data pages, so the flat row a
            # chain encodes is that many entries rounded up to whole table
            # pages. The flat ``block_tab`` is STILL maintained (write-side
            # paths — prefill scatter, context gather, verify — take
            # host-flattened rows); only the batched decode walks the chain.
            tpp = pcfg.table_page_entries or pcfg.page_size
            max_pages = min(pcfg.table_width, pcfg.num_pages - 1)
            self.chain: Optional[ChainedTables] = ChainedTables(B, -(-max_pages // tpp), tpp)
            self._row_width = self.chain.width1 * tpp
        else:
            self.chain = None
            self._row_width = pcfg.table_width
        self.cache = self.model.init_paged_cache(B, pcfg.num_pages, pcfg.page_size)
        self._kv_bytes_per_token = _kv_bytes_per_token(
            cfg, self.cache, pcfg.num_pages * pcfg.page_size
        )
        self.allocator = BlockAllocator(pcfg.num_pages, pcfg.page_size)
        self.prefix_cache: Optional[PrefixCache] = (
            PrefixCache(self.allocator, pcfg.page_size) if pcfg.prefix_cache else None
        )
        self._cache_nodes: List[Optional[object]] = [None] * B  # pinned tree path per slot
        self.tables: List[Optional[PageTable]] = [None] * B
        self.slot_len = np.zeros(B, np.int32)
        self.slot_seq: List[Optional[Sequence]] = [None] * B
        self.block_tab = np.full((B, self._row_width), NULL_PAGE, np.int32)
        self.waiting: Deque[Sequence] = deque()
        self.preemptions = 0
        self.peak_active = 0
        self._sid = 0
        self._stamp = np.zeros(B, np.int64)   # admission order, newest = max
        self._stamp_next = 1
        self._just_finished: List[Sequence] = []
        self._init_chunk_slots(B)
        self._build()

    # -- jitted steps ---------------------------------------------------------
    def _build(self):
        model, ctx, cfg = self.model, self.ctx, self.cfg

        def prefill_paged(params, cache, tokens, tab_row, slot, n_valid):
            """Prefill one bucket-padded sequence through the model's paged
            path: attention K/V scatter through the block-table row inside
            each layer (pads land on the null page), recurrent mixers run
            from zero state into ``slot`` — no dense staging cache."""
            batch = {
                "tokens": tokens[None, :],                            # (1, Lp)
                "n_valid": n_valid[None],
                "tab_row": tab_row,
                "slot": slot,
            }
            next_tok, cache = model.prefill_paged(ctx, params, batch, cache)
            return next_tok[0], cache

        if self.chain is not None:
            def decode_all(params, cache, last_tokens, lens, tab, l2):
                # chained decode: tab is the (B, W1) first-level table, l2
                # the shared second-level pool — the kernel resolves pages
                # through both scalar-prefetched levels.
                batch = {
                    "token": last_tokens[:, None], "lengths": lens,
                    "block_tab": tab, "l2_tab": l2,
                }
                return model.decode(ctx, params, cache, batch)
        else:
            def decode_all(params, cache, last_tokens, lens, tab):
                batch = {"token": last_tokens[:, None], "lengths": lens, "block_tab": tab}
                return model.decode(ctx, params, cache, batch)

        def copy_fork(cache, src_pages, dst_pages, src_slot, dst_slot):
            """Device-side copy-on-write for fork(): duplicate the trailing
            partial pages and the per-slot recurrent state."""
            out_blocks = dict(cache["blocks"])
            for i, kind in enumerate(cfg.block_pattern):
                key = f"l{i}_mixer"
                if kind == "attn":
                    out_blocks[key] = jax.tree.map(
                        lambda pool: pool.at[:, dst_pages].set(pool[:, src_pages]),
                        cache["blocks"][key],
                    )
                else:
                    def copy_slot(leaf):
                        row = jax.lax.dynamic_slice_in_dim(leaf, src_slot, 1, axis=1)
                        return jax.lax.dynamic_update_slice_in_dim(leaf, row, dst_slot, axis=1)

                    out_blocks[key] = jax.tree.map(copy_slot, cache["blocks"][key])
            return {"blocks": out_blocks}

        def prefill_chunk_paged(params, cache, tokens, tab_row, slot, offset, n_valid, carry):
            """One chunked-prefill step straight into the page pool: the
            chunk's K/V scatters through the row at its page-aligned offset
            and the recurrent state rides ``carry``. Compiles once per chunk
            bucket — tab_row/slot/offset/n_valid are all dynamic."""
            batch = {
                "tokens": tokens[None, :],
                "n_valid": n_valid[None],
                "tab_row": tab_row,
                "slot": slot,
                "offset": offset,
            }
            nxt, cache, carry = model.prefill_chunk_paged(ctx, params, batch, cache, carry)
            return nxt[0], cache, carry

        def verify_paged(params, cache, tokens, tab_row, offset):
            """Speculative verify straight against the page pool: the k+1
            verify tokens scatter through the row at the (mid-page)
            write-head and the greedy token is read at every position —
            ``prefill_chunk_paged``'s scatter+gather+absolute-mask shape
            with per-token page indexing instead of a page-shifted row.
            Compiles once per k_eff (at most spec_tokens shapes)."""
            batch = {"tokens": tokens[None, :], "tab_row": tab_row, "offset": offset}
            toks, cache = model.verify_paged(ctx, params, batch, cache)
            return toks[0], cache

        self._prefill = jax.jit(prefill_paged, donate_argnums=(1,))
        self._decode = jax.jit(decode_all, donate_argnums=(1,))
        self._copy_fork = jax.jit(copy_fork, donate_argnums=(0,))
        self._prefill_chunk = jax.jit(prefill_chunk_paged, donate_argnums=(1, 7))
        self._verify = jax.jit(verify_paged, donate_argnums=(1,))
        self._install_carry = jax.jit(model.install_chunk_state, donate_argnums=(0,))
        self._last = np.zeros(self.pcfg.max_slots, np.int32)

    def _run_chunk_device(self, slot: int, toks, offset: int, n: int):
        nxt, self.cache, self._chunk_carry[slot] = self._prefill_chunk(
            self.params,
            self.cache,
            jnp.asarray(toks),
            jnp.asarray(self.block_tab[slot]),
            jnp.asarray(slot),
            jnp.asarray(offset),
            jnp.asarray(n),
            self._chunk_carry[slot],
        )
        return nxt

    # -- capacity telemetry ------------------------------------------------------
    def free_pages(self) -> int:
        return self.allocator.free_pages

    def capacity_now(self) -> Dict[str, int]:
        """Live capacity snapshot: what the StraightLine placer consumes
        instead of a static ``capacity`` constant. With the prefix cache on
        it additionally exports ``cached_pages`` / ``evictable_pages`` /
        ``prefix_hit_rate`` / ``prefix_cached_tokens`` — evictable cache is
        reclaimable capacity the placer may count as free-ish (the keys are
        absent when the cache is off, and StraightLinePolicy stays
        byte-faithful to Algorithm 1 without them)."""
        snap = {
            "free_slots": self.free_slots(),
            "num_slots": self.pcfg.max_slots,
            "free_pages": self.allocator.free_pages,
            "num_pages": self.pcfg.num_pages - 1,
            "free_cache_tokens": self.allocator.free_pages * self.pcfg.page_size,
            "cache_tokens": self.pcfg.cache_tokens,
            "kv_cache_dtype": _kv_dtype_name(self.cfg),
            "kv_bytes_per_token": self._kv_bytes_per_token,
            "waiting": len(self.waiting),
            "compile_events": self.compile_events,
            "total_buckets": self.total_buckets,
            "compile_ema_s": self.compile_ema_s,
            "prefilling_slots": sum(self._chunking),
            "prefill_backlog_tokens": self.prefill_backlog_tokens(),
            "chunk_tokens": self._chunk_tokens,
            "spec_tokens": self._spec_tokens,
            "tokens_emitted": self.tokens_emitted,
            "spec_proposed": self.spec_proposed,
            "spec_accepted": self.spec_accepted,
        }
        pc = self.prefix_cache
        if pc is not None:
            snap["cached_pages"] = pc.cached_pages
            snap["evictable_pages"] = pc.evictable_pages()
            snap["prefix_hit_rate"] = pc.hit_rate
            snap["prefix_cached_tokens"] = pc.matched_tokens_total
        return snap

    def admission_capacity(self, est_tokens: int = 0) -> int:
        """How many requests of ~est_tokens context the engine can admit now
        (page- and slot-bounded). est_tokens=0 assumes a one-page sequence.
        Evictable prefix-cache pages count as free: admission reclaims them
        before it would ever report the pool full."""
        est = max(1, est_tokens)
        per_seq = PageTable.pages_needed(est + 1, self.pcfg.page_size)
        pages = self.allocator.free_pages
        if self.prefix_cache is not None:
            pages += self.prefix_cache.evictable_pages()
        return min(self.free_slots(), pages // per_seq)

    # -- public API -------------------------------------------------------------
    def _prewarm_shape(self, Lp: int, slot: int) -> None:
        """Compile a paged prefill at shape ``Lp`` through an all-null
        block-table row: K/V writes land on the reserved null page (garbage
        by design) and the idle slot's recurrent state is rewritten from
        zero on any real install. The cache is reassigned because the paged
        prefill donates its buffer. With chunked prefill on — or the prefix
        cache, whose admissions all ride the chunk machinery — the CHUNK
        path is what traffic runs, so that is what gets compiled."""
        toks = np.zeros(Lp, np.int32)
        row = np.full(self._row_width, NULL_PAGE, np.int32)
        if self._chunk_tokens or self.prefix_cache is not None:
            _, self.cache, _ = self._prefill_chunk(
                self.params, self.cache, jnp.asarray(toks), jnp.asarray(row),
                jnp.asarray(slot), jnp.asarray(0), jnp.asarray(1),
                self.model.init_chunk_state(),
            )
            return
        _, self.cache = self._prefill(
            self.params,
            self.cache,
            jnp.asarray(toks),
            jnp.asarray(row),
            jnp.asarray(slot),
            jnp.asarray(1),
        )

    def submit(self, prompt: List[int], trace=None) -> int:
        # Gate on the engine's RESOLVED length cap, not raw max_seq_len: in
        # chained mode the cap is re-derived from pool capacity (a prompt the
        # pool can hold is admissible however max_seq_len relates to the flat
        # table geometry), and in flat mode the two are identical anyway.
        if len(prompt) + self.pcfg.max_new_tokens > self._len_cap:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens exceeds the length "
                f"cap {self._len_cap} (max_seq_len={self.pcfg.max_seq_len}, "
                f"pool={self.pcfg.cache_tokens} tokens)"
            )
        return super().submit(prompt, trace=trace)

    def _free_slot(self) -> Optional[int]:
        for i in range(self.pcfg.max_slots):
            if self.slot_seq[i] is None:
                return i
        return None

    def _sync_row(self, slot: int) -> None:
        """Single owner of the host block-table views after ANY page-list
        change (install, growth, spec grow/trim, fork, release): rewrites the
        slot's flat row and, in chained mode, re-chains its first/second
        -level entries — so the two views can never disagree."""
        table = self.tables[slot]
        pages = table.pages if table is not None else []
        self.block_tab[slot, :] = (
            table.row(self._row_width) if pages else NULL_PAGE
        )
        if self.chain is not None:
            self.chain.set_row(slot, pages)

    def _install(self, slot: int, seq: Sequence, table: PageTable) -> int:
        """Prefill seq's full context (bucket-padded) through ``table`` into
        slot; returns the emitted next token. Pad positions past the
        allocated pages map to the null page via the padded table row."""
        ctx_toks = seq.context_tokens()
        table.num_tokens = len(ctx_toks)
        self.tables[slot] = table
        self._sync_row(slot)
        toks, n, _, fresh = self._pad_context(ctx_toks)
        tr = seq.trace
        tr0 = time.monotonic() if tr is not None else 0.0
        t0 = time.perf_counter()
        nxt, self.cache = self._prefill(
            self.params,
            self.cache,
            jnp.asarray(toks),
            jnp.asarray(self.block_tab[slot]),
            jnp.asarray(slot),
            jnp.asarray(n),
        )
        if fresh:
            jax.block_until_ready(nxt)
            self._note_compile(time.perf_counter() - t0)
        if tr is not None:
            tr.add_span("prefill", tr0, time.monotonic(), lane=seq.lane,
                        slot=slot, tokens=n, fresh_compile=fresh,
                        resume=seq.preemptions)
        self.slot_seq[slot] = seq
        self.slot_len[slot] = n
        self._last[slot] = int(nxt)
        self._stamp[slot] = self._stamp_next
        self._stamp_next += 1
        return int(nxt)

    def _reserve_pages(self, n: int, seq: Optional[Sequence] = None) -> bool:
        """Make ``n`` pages allocatable, reclaiming cold prefix-cache leaves
        (LRU-first) before the caller has to preempt any live sequence —
        cached pages are reclaimable capacity, not occupancy. Returns whether
        ``alloc(n)`` can now succeed."""
        if self.allocator.can_alloc(n):
            return True
        if self.prefix_cache is not None:
            freed = self.prefix_cache.evict(n - self.allocator.free_pages)
            if freed and seq is not None and seq.trace is not None:
                seq.trace.event("prefix_evict", lane=seq.lane,
                                freed_pages=freed, need_pages=n)
        return self.allocator.can_alloc(n)

    def _release(self, slot: int, to_cache: bool = True) -> None:
        """Tear down a slot. With the prefix cache on and ``to_cache`` (the
        sequence FINISHED — not preempted), its full pages retire into the
        radix tree instead of the free list: the tree either adopts the
        sequence's page reference or, for prefixes it already holds, frees
        the duplicate. Only the trailing partial page is actually freed. A
        preemption (``to_cache=False``) drops every reference as before —
        pages shared with the tree survive under the tree's own reference."""
        seq = self.slot_seq[slot]
        table = self.tables[slot]
        node = self._cache_nodes[slot]
        self._cache_nodes[slot] = None
        if node is not None:
            self.prefix_cache.release(node)   # unpin the matched path
        if (self.prefix_cache is not None and to_cache
                and seq is not None and not self._chunking[slot]):
            toks = seq.context_tokens()[: int(self.slot_len[slot])]
            n_full = len(toks) // self.pcfg.page_size
            self.prefix_cache.insert(toks, table.pages[:n_full])
            self.allocator.free(table.pages[n_full:])    # partial tail only
            table.pages = []
            table.num_tokens = 0
        else:
            table.release(self.allocator)
        self.tables[slot] = None
        self.slot_seq[slot] = None
        self.slot_len[slot] = 0
        self._sync_row(slot)
        self._stamp[slot] = 0
        # a preempted PREFILLING slot drops its chunk progress: re-admission
        # restarts the chunked prefill from scratch with a fresh zero carry
        # (and re-matches the prefix cache, re-validating the boundary)
        self._clear_chunk_slot(slot)

    _release_slot = _release          # shared _chunk_step hook (see _EngineBase)

    def _admit(self, spent: int = 0, budget: int = 0) -> int:
        """Budget-gated page-gated admission (see the dense engine's
        ``_admit`` for the budget contract — called bare, budget 0 resolves
        ``step_budget``). Chunked: the new sequence's FULL context pages are
        reserved up front (the growth-before-admission invariant still
        holds — a decode token mid-prefill always lands on an allocated
        page) and the slot enters PREFILLING; the chunk phase spends the
        budget. With the prefix cache on, the context is matched against the
        radix tree BEFORE chunking: matched pages go to the front of the
        page table (one extra allocator reference each), only the suffix is
        freshly allocated, and the chunk cursor starts at the match boundary
        — every admission then rides the chunk machinery (a whole unmatched
        suffix is one chunk when chunking is off). Returns the updated
        spend."""
        budget = budget or self.step_budget
        admitted = False
        while self.waiting:
            slot = self._free_slot()
            if slot is None:
                break
            seq = self.waiting[0]
            ctx_toks = seq.context_tokens()
            ctx_len = len(ctx_toks)
            need = PageTable.pages_needed(ctx_len + 1, self.pcfg.page_size)
            if self.prefix_cache is not None:
                hit_pages, hit_node, hit_tokens = self.prefix_cache.acquire(ctx_toks)
                if not self._reserve_pages(need - len(hit_pages), seq):
                    self.prefix_cache.cancel(hit_pages, hit_node)
                    break                                # page-gated admission
                self.waiting.popleft()
                if seq.trace is not None:
                    seq.trace.event(
                        "prefix_hit" if hit_tokens else "prefix_miss",
                        lane=seq.lane, matched_tokens=hit_tokens,
                        ctx_tokens=ctx_len,
                    )
                seq.cached_tokens = hit_tokens
                table = PageTable(
                    self.pcfg.page_size,
                    hit_pages + self.allocator.alloc(need - len(hit_pages)),
                )
                table.num_tokens = ctx_len
                self.tables[slot] = table
                self._sync_row(slot)
                self._cache_nodes[slot] = hit_node
                self._begin_chunked(slot, seq, start=hit_tokens)
                continue
            if not self.allocator.can_alloc(need):
                break                                    # page-gated admission
            if self._chunk_tokens:
                self.waiting.popleft()
                table = PageTable(self.pcfg.page_size, self.allocator.alloc(need))
                table.num_tokens = ctx_len
                self.tables[slot] = table
                self._sync_row(slot)
                self._begin_chunked(slot, seq)
                continue
            Lp = self._bucket_len(ctx_len)
            if admitted and spent + Lp > budget:
                break                                    # over budget: stays queued
            self.waiting.popleft()
            table = PageTable(self.pcfg.page_size, self.allocator.alloc(need))
            nxt = self._install(slot, seq, table)
            spent += Lp
            admitted = True
            seq.out.append(nxt)
            seq.token_times.append(time.monotonic())
            self.tokens_emitted += 1
            if self._stop_hit(seq, nxt, int(self.slot_len[slot])):
                # the (re-)prefill-emitted token can already cross a stop
                # condition: a resumed sequence near max_new_tokens, or a
                # fresh prompt whose greedy next token is EOS
                seq.done = True
                self._just_finished.append(seq)
                self._release(slot)
        return spent

    def _preempt_newest(self, active: List[int]) -> int:
        """Evict the most recently admitted active sequence back to the
        waiting queue (front), releasing its pages. Returns the slot."""
        victim = max(active, key=lambda i: self._stamp[i])
        seq = self.slot_seq[victim]
        seq.preemptions += 1
        self.preemptions += 1
        if seq.trace is not None:
            seq.trace.event("preempted", lane=seq.lane, slot=victim,
                            n_out=len(seq.out), preemptions=seq.preemptions)
        self.waiting.appendleft(seq)
        self._release(victim, to_cache=False)
        active.remove(victim)
        return victim

    def _ensure_growth(self, active: List[int]) -> None:
        """Every active slot writes one token at position slot_len this step;
        allocate the page that position lands in. When the pool is dry, cold
        prefix-cache leaves are evicted FIRST (reclaimable capacity); only
        when nothing evictable remains is the newest sequence preempted."""
        for slot in sorted(active, key=lambda i: self._stamp[i]):
            if slot not in active:
                continue
            while self.tables[slot].capacity_tokens <= self.slot_len[slot]:
                if not self._reserve_pages(1, self.slot_seq[slot]):
                    if active == [slot]:
                        raise RuntimeError(
                            "page pool too small to grow the only active sequence; "
                            "increase num_pages"
                        )
                    preempted = self._preempt_newest(active)
                    if preempted == slot:
                        break
                    continue
                self.tables[slot].append_pages(self.allocator.alloc(1))
                self._sync_row(slot)

    def _spec_phase(self, active: List[int], spent: int, budget: int):
        """Speculate on decoding slots at the decode frontier (see the dense
        engine's ``_spec_phase`` for the budget/acceptance contract). The
        paged twist is the write-head's page coverage: the verify pass
        writes positions L..L+k_eff, so the pages covering them are
        allocated up front — through ``_reserve_pages``, which may evict
        cold prefix-cache leaves but NEVER preempts a live sequence for
        speculation (a failed reservation degrades the slot to plain
        decode). On rejection the speculative tail pages are rolled back:
        every page past max(pre-speculation count, accepted coverage) was
        freshly allocated this attempt — exclusively owned, never a
        prefix-cache or CoW-shared page (those sit at the table's front) —
        so ``PageTable.trim`` returns them to the free list whole."""
        ps = self.pcfg.page_size
        sped: List[int] = []
        for slot in active:
            seq = self.slot_seq[slot]
            L = int(self.slot_len[slot])
            k_eff = min(self._spec_tokens, self._len_cap - 1 - L)
            if k_eff < 1 or spent + k_eff > budget:
                continue
            proposal = self._propose(seq)
            if proposal is None:
                continue
            table = self.tables[slot]
            n0 = len(table.pages)
            need = PageTable.pages_needed(L + k_eff + 1, ps) - n0
            if need > 0:
                if not self._reserve_pages(need, seq):
                    continue               # pool dry: degrade to plain decode
                table.append_pages(self.allocator.alloc(need))
                self._sync_row(slot)
            toks, self.cache = self._verify(
                self.params,
                self.cache,
                jnp.asarray(
                    np.asarray([int(self._last[slot])] + proposal[:k_eff], np.int32)
                ),
                jnp.asarray(self.block_tab[slot]),
                jnp.asarray(L),
            )
            spent += k_eff
            m, done = self._accept_verified(slot, seq, proposal, np.asarray(toks), k_eff)
            keep = max(n0, PageTable.pages_needed(L + m, ps))
            if table.trim(keep, self.allocator):
                self._sync_row(slot)
            table.num_tokens = L + m
            sped.append(slot)
            if done:
                seq.done = True
                self._just_finished.append(seq)
                self._release(slot)
        return sped, spent

    def step(self) -> List[Sequence]:
        """Grow + admit (budget-gated) + chunk work + speculation + one
        decode step; returns sequences finished. Growth runs first so
        admission can't grab the last pages only for the freshly prefilled
        sequence to be preempted in the same step — admitted sequences are
        already growth-covered (ceil((ctx+1)/ps)), PREFILLING ones trivially
        so (their full-context pages are reserved at admission, and they are
        preemption candidates like any other occupant). PREFILLING slots
        are excluded from the host-side decode bookkeeping; the batched
        device decode still sweeps them, but its scatter lands on the chunk
        cursor's (allocated) page and is rewritten by the next chunk, and
        the authoritative recurrent state rides the off-cache carry until
        install. Speculated slots are excluded the same way: the sweep
        writes their pending token at the new write-head — idempotent with
        the next step's decode write when that page is allocated, absorbed
        by the null page when it is not."""
        with self.lock:
            budget = self.step_budget
            self.spec_runs = []
            occupied = [i for i in range(self.pcfg.max_slots) if self.slot_seq[i] is not None]
            self._ensure_growth(occupied)
            spent = sum(
                1 for i, s in enumerate(self.slot_seq)
                if s is not None and not self._chunking[i]
            )
            spent = self._admit(spent, budget)
            if self._chunk_tokens or self.prefix_cache is not None:
                spent = self._run_chunks(spent, budget)
            active = [
                i for i in range(self.pcfg.max_slots)
                if self.slot_seq[i] is not None and not self._chunking[i]
            ]
            self.peak_active = max(self.peak_active, len(active))
            if self._spec_tokens and active:
                sped, spent = self._spec_phase(active, spent, budget)
                active = [i for i in active if i not in set(sped)]
            finished, self._just_finished = self._just_finished, []
            if active:
                if self.chain is not None:
                    nxt, self.cache = self._decode(
                        self.params,
                        self.cache,
                        jnp.asarray(self._last),
                        jnp.asarray(self.slot_len),
                        jnp.asarray(self.chain.l1),
                        jnp.asarray(self.chain.l2),
                    )
                else:
                    nxt, self.cache = self._decode(
                        self.params,
                        self.cache,
                        jnp.asarray(self._last),
                        jnp.asarray(self.slot_len),
                        jnp.asarray(self.block_tab),
                    )
                nxt = np.asarray(nxt)
                tok_t = time.monotonic()      # one stamp per batched decode step
                for i in active:
                    seq = self.slot_seq[i]
                    self.slot_len[i] += 1
                    self.tables[i].num_tokens = int(self.slot_len[i])
                    self._last[i] = nxt[i]
                    seq.out.append(int(nxt[i]))
                    seq.token_times.append(tok_t)
                    self.tokens_emitted += 1
                    if self._stop_hit(seq, int(nxt[i]), int(self.slot_len[i])):
                        seq.done = True
                        finished.append(seq)
                        self._release(i)
            return finished

    def fork(self, sid: int) -> Optional[int]:
        """Clone a running sequence (hedged/retried copy): full prefix pages
        are shared via ref-counting, the trailing partial page is copied on
        device, and the clone continues decoding independently. Returns the
        new sid, or None if no free slot / pages."""
        with self.lock:
            src = next(
                (i for i, s in enumerate(self.slot_seq) if s is not None and s.sid == sid), None
            )
            dst = self._free_slot()
            if src is None or dst is None:
                return None
            if self._chunking[src]:
                # mid-prefill: the authoritative recurrent state is in the
                # off-cache carry, not the slot — nothing coherent to clone
                return None
            src_table = self.tables[src]
            cow_pages = len(src_table.pages) - src_table.num_tokens // self.pcfg.page_size
            if not self._reserve_pages(cow_pages, self.slot_seq[src]):
                return None                   # even evicting cache can't cover CoW
            try:
                new_table = src_table.fork(self.allocator)
            except OutOfPages:
                return None
            seq = self.slot_seq[src]
            clone = Sequence(self._sid, list(seq.prompt), out=list(seq.out),
                             submit_t=time.monotonic(), trace=seq.trace,
                             cached_tokens=seq.cached_tokens)
            if self._cache_nodes[src] is not None:
                # the clone shares the source's cache-attached pages: it must
                # hold the tree path too, or the source finishing would leave
                # the path evictable under the still-running clone
                self._cache_nodes[dst] = self.prefix_cache.pin(self._cache_nodes[src])
            self._sid += 1
            n_full = new_table.num_tokens // self.pcfg.page_size
            src_part = self.tables[src].pages[n_full:]
            dst_part = new_table.pages[n_full:]
            self.cache = self._copy_fork(
                self.cache,
                jnp.asarray(src_part or [NULL_PAGE], jnp.int32),
                jnp.asarray(dst_part or [NULL_PAGE], jnp.int32),
                jnp.asarray(src),
                jnp.asarray(dst),
            )
            self.tables[dst] = new_table
            self._sync_row(dst)
            self.slot_seq[dst] = clone
            self.slot_len[dst] = self.slot_len[src]
            self._last[dst] = self._last[src]
            self._stamp[dst] = self._stamp_next
            self._stamp_next += 1
            return clone.sid
