"""Paged KV-cache bookkeeping: BlockAllocator + per-sequence PageTable.

Page-table layout
-----------------
The device-side KV cache is a *pool* of fixed-size pages, one pool per
attention layer (stacked over superblocks, so each pool leaf is
``(n_sb, num_pages, KV, page_size, hd)``).  A sequence does not own a
contiguous ``max_len`` stripe of the cache; instead it owns an ordered list
of physical page ids — its *page table* — and logical token position ``t``
lives at ``(page_table[t // page_size], t % page_size)``.

  physical pool (per layer)          page tables (host, this module)
  ┌────┬────┬────┬────┬────┐         seq A: [3, 1]      (len 21, ps=16)
  │ p0 │ p1 │ p2 │ p3 │ p4 │  ...    seq B: [4]         (len  7)
  └────┴────┴────┴────┴────┘         free list: [2, ...]

Page 0 is reserved as the *null page*: it is never handed out, block-table
rows are padded with 0, and dead decode slots scatter their garbage writes
into it — so every index the kernels see is a valid physical page.

The allocator is pure host-side bookkeeping (device tensors never move when
pages change hands). Ref-counting lets hedged / retried copies of a request
share their common prefix pages: ``fork()`` bumps the ref-count of every
full page and only the last, partially-filled page must be copied
(copy-on-write, performed by the engine on device). ``free()`` decrements
and returns a page to the free list only when its count reaches zero.

All structures are deterministic (freed pages return to a FIFO free list)
so preemption/resume tests can assert exact page reuse.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List

import numpy as np

NULL_PAGE = 0


def bucket_tokens(n: int, unit: int, cap: int) -> int:
    """Length bucket for a context of ``n`` tokens: the smallest
    power-of-two multiple of ``unit`` (the page size) holding ``n``, capped
    at ``cap`` (``max_seq_len``). Right-padding every prefill to its bucket
    bounds the number of distinct prefill shapes — hence XLA compilations —
    at ``num_buckets(unit, cap)`` regardless of the traffic's length mix."""
    m = -(-max(1, n) // unit)           # pages needed, >= 1
    b = 1
    while b < m:
        b *= 2
    return max(n, min(b * unit, cap))


def bucket_lengths(unit: int, cap: int) -> List[int]:
    """Every distinct bucket length ``bucket_tokens`` can produce, ascending
    (the shapes ``prewarm`` must compile): power-of-two multiples of ``unit``
    capped at ``cap``."""
    out, b = [], unit
    while True:
        out.append(min(b, cap))
        if b >= cap:
            break
        b *= 2
    return out


def num_buckets(unit: int, cap: int) -> int:
    """How many distinct bucket lengths exist: ceil(log2(cap/unit)) + 1."""
    return len(bucket_lengths(unit, cap))


class OutOfPages(Exception):
    """Raised when an allocation cannot be satisfied from the free list."""


class BlockAllocator:
    """Fixed-size page allocator with ref-counting over ``num_pages`` pages.

    Page 0 (``NULL_PAGE``) is reserved and never allocated.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need at least 2 pages (page 0 is reserved)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: Deque[int] = deque(range(1, num_pages))
        self._refs: Dict[int, int] = {}

    # -- capacity ----------------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return len(self._refs)

    def can_alloc(self, n: int) -> bool:
        return n <= len(self._free)

    # -- alloc / free / share ---------------------------------------------
    def alloc(self, n: int = 1) -> List[int]:
        """Hand out ``n`` pages (ref-count 1 each) or raise OutOfPages —
        all-or-nothing, so a failed admission never leaks pages."""
        if not self.can_alloc(n):
            raise OutOfPages(f"need {n} pages, {len(self._free)} free")
        pages = [self._free.popleft() for _ in range(n)]
        for p in pages:
            self._refs[p] = 1
        return pages

    def share(self, page: int) -> int:
        """Bump the ref-count of an allocated page (prefix sharing)."""
        if page not in self._refs:
            raise ValueError(f"page {page} is not allocated")
        self._refs[page] += 1
        return self._refs[page]

    def ref_count(self, page: int) -> int:
        return self._refs.get(page, 0)

    def free(self, pages: List[int]) -> int:
        """Drop one reference per page; pages return to the free list only
        when the last reference dies. Returns how many pages actually came
        back to the free list (shared pages survive their co-holders), so
        the prefix cache's eviction can report *reclaimed* capacity rather
        than references dropped."""
        freed = 0
        for p in pages:
            if p not in self._refs:
                raise ValueError(f"double free of page {p}")
            self._refs[p] -= 1
            if self._refs[p] == 0:
                del self._refs[p]
                self._free.append(p)
                freed += 1
        return freed

    def check_invariants(self) -> None:
        """free + used = num_pages - 1 (null page); no page in both sets."""
        free = set(self._free)
        used = set(self._refs)
        assert NULL_PAGE not in free and NULL_PAGE not in used
        assert not (free & used), free & used
        assert len(free) + len(used) == self.num_pages - 1
        assert all(c > 0 for c in self._refs.values())


@dataclass
class PageTable:
    """Ordered physical pages backing one sequence's logical token stream."""

    page_size: int
    pages: List[int] = field(default_factory=list)
    num_tokens: int = 0

    @property
    def capacity_tokens(self) -> int:
        return len(self.pages) * self.page_size

    def page_of(self, t: int) -> int:
        return self.pages[t // self.page_size]

    def offset_of(self, t: int) -> int:
        return t % self.page_size

    @staticmethod
    def pages_needed(tokens: int, page_size: int) -> int:
        return -(-tokens // page_size)  # ceil div

    def append_pages(self, pages: List[int]) -> None:
        self.pages.extend(pages)

    def row(self, width: int) -> List[int]:
        """Block-table row padded with the null page to ``width`` entries."""
        if len(self.pages) > width:
            raise ValueError(f"sequence needs {len(self.pages)} pages, table width {width}")
        return self.pages + [NULL_PAGE] * (width - len(self.pages))

    def trim(self, keep: int, allocator: BlockAllocator) -> int:
        """Speculative-decode rollback: drop every page past the first
        ``keep``, returning how many came back to the free list. The caller
        guarantees the tail was appended for the current speculation attempt
        (freshly allocated, ref-count 1, exclusively owned) — prefix-cache
        and CoW-fork shared pages always sit at the FRONT of the table
        (matched prefixes are full leading pages; ``fork`` re-allocates the
        trailing partial page), so a trim that never cuts below the
        pre-speculation page count can never free a page another holder
        still reads."""
        if keep >= len(self.pages):
            return 0
        freed = allocator.free(self.pages[keep:])
        self.pages = self.pages[:keep]
        return freed

    def fork(self, allocator: BlockAllocator) -> "PageTable":
        """Share this table's pages with a new sequence (hedged/retried
        copy). Full pages are shared (ref-count++); the trailing partial
        page — which the original will keep appending into — is re-allocated
        fresh for the fork, and the engine must copy its contents on device
        (copy-on-write). Raises OutOfPages if the CoW page can't be had."""
        n_full = self.num_tokens // self.page_size
        shared = self.pages[:n_full]
        for p in shared:
            allocator.share(p)
        new_pages = list(shared)
        if n_full < len(self.pages):  # trailing partial page -> CoW
            try:
                new_pages.extend(allocator.alloc(len(self.pages) - n_full))
            except OutOfPages:
                for p in shared:
                    allocator.free([p])
                raise
        return PageTable(self.page_size, new_pages, self.num_tokens)

    def release(self, allocator: BlockAllocator) -> None:
        allocator.free(self.pages)
        self.pages = []
        self.num_tokens = 0


class ChainedTables:
    """Two-level ("chained") block tables for long-context sequences.

    A flat block table is a device array of shape ``(max_slots, W)`` where
    ``W`` must cover the longest admissible sequence — at long context the
    per-slot row (and the scalar-prefetch footprint the decode kernel pays
    for it) grows linearly with ``max_seq_len``. Chaining splits the map in
    two: each slot's first-level row (``l1``, width ``ceil(W / tpp)``) holds
    *table-page* ids — rows of the shared second-level pool ``l2`` of shape
    ``(n_rows, tpp)`` — and logical block ``i`` resolves to
    ``l2[l1[slot, i // tpp], i % tpp]``. Table pages are allocated on demand
    from a FIFO free list (mirroring ``BlockAllocator``), so a short
    sequence in a long-context engine consumes first-level entries only.

    Row 0 of ``l2`` is reserved as the all-null table page (the indirection
    twin of ``NULL_PAGE``): unused l1 entries point at it and resolve to the
    null data page, so every two-step lookup the kernels perform lands on a
    valid physical page.

    ``n_rows`` is worst-case sized by the caller (every slot holding a
    full-width row) so ``set_row`` can never fail — table-page exhaustion
    would otherwise be a second admission failure mode interleaved with data
    -page exhaustion, and the engine's all-or-nothing admission contract is
    easier to keep when only data pages can run out.
    """

    def __init__(self, max_slots: int, width1: int, tpp: int):
        if tpp < 1 or width1 < 1:
            raise ValueError("width1 and tpp must be >= 1")
        self.tpp = tpp
        self.width1 = width1
        n_rows = 1 + max_slots * width1
        self.l1 = np.zeros((max_slots, width1), np.int32)       # 0 -> null row
        self.l2 = np.full((n_rows, tpp), NULL_PAGE, np.int32)
        self._free: Deque[int] = deque(range(1, n_rows))
        self._owned: List[List[int]] = [[] for _ in range(max_slots)]

    @property
    def free_rows(self) -> int:
        return len(self._free)

    def set_row(self, slot: int, pages: List[int]) -> None:
        """Point ``slot`` at ``pages`` (a flat physical-page row, null-padded
        or not): allocates the table pages the row needs, writes them, and
        returns the slot's previous table pages to the free list. Called at
        every host point where a flat engine would rewrite its block-table
        row, so the device view is always whole-row consistent."""
        if len(pages) > self.width1 * self.tpp:
            raise ValueError(
                f"row of {len(pages)} pages exceeds chained capacity "
                f"{self.width1 * self.tpp}"
            )
        # Trailing null-page entries need no table page — they resolve
        # through the reserved null row.
        n = len(pages)
        while n > 0 and pages[n - 1] == NULL_PAGE:
            n -= 1
        need = -(-n // self.tpp) if n else 0
        rows = self._owned[slot]
        while len(rows) > need:
            r = rows.pop()
            self.l2[r, :] = NULL_PAGE
            self._free.append(r)
        while len(rows) < need:
            rows.append(self._free.popleft())
        for j, r in enumerate(rows):
            chunk = pages[j * self.tpp:(j + 1) * self.tpp]
            self.l2[r, :len(chunk)] = chunk
            self.l2[r, len(chunk):] = NULL_PAGE
        self.l1[slot, :len(rows)] = rows
        self.l1[slot, len(rows):] = 0

    def clear(self, slot: int) -> None:
        self.set_row(slot, [])

    def flat_row(self, slot: int) -> List[int]:
        """Reconstruct the flat physical row this slot's chain encodes
        (width1 * tpp entries, null-padded) — the oracle the fuzz tests
        compare against the flat table the engine also maintains."""
        out: List[int] = []
        for r in self.l1[slot]:
            out.extend(int(p) for p in self.l2[int(r)])
        return out

    def check_invariants(self, max_slots: int) -> None:
        free = set(self._free)
        owned = [r for rows in self._owned for r in rows]
        assert 0 not in free and 0 not in owned
        assert len(owned) == len(set(owned)), "l2 row owned twice"
        assert not (free & set(owned)), free & set(owned)
        assert len(free) + len(owned) == self.l2.shape[0] - 1
        assert (self.l2[0] == NULL_PAGE).all(), "null table row corrupted"
        for s in range(max_slots):
            rows = self._owned[s]
            assert list(self.l1[s, :len(rows)]) == rows
            assert (self.l1[s, len(rows):] == 0).all()
        for r in free:
            assert (self.l2[r] == NULL_PAGE).all(), f"free row {r} not nulled"
