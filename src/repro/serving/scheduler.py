"""Continuous-batching engine loop: submit -> shared step thread -> futures.

``EngineLoop`` turns an engine (dense ``InferenceEngine`` or
``PagedInferenceEngine``) from a synchronous ``generate``-per-caller device
into a shared continuous-batching service. Callers from any number of
threads ``submit(prompt)`` and block on ``wait(sid)``; ONE background step
thread owns all device stepping — each iteration admits pending sequences
under the engine lock, runs one batched ``step()`` across every active slot,
and resolves finished sequences into per-sid futures. Concurrent requests
therefore interleave inside a single decode batch instead of serializing
whole generations on the engine lock (the pre-loop ``generate`` contract),
so a tier's usable capacity really is ``max_slots``, not 1. With chunked
prefill enabled on the engine (``chunk_tokens``), each iteration further
interleaves budgeted prefill CHUNK work with the decode batch inside
``engine.step()`` — a long prompt is absorbed over many loop iterations
while decoding slots emit a token every iteration, and the remaining
``prefill_backlog_tokens`` is exported through ``capacity_now()``.

The router integration is two-phase: ``Backend.submit_fn`` enqueues into the
loop and returns a ticket, ``Backend.wait_fn`` blocks on it — the router
worker sleeps on a future while the loop batches its sequence with everyone
else's. ``capacity_now()`` re-exports the engine snapshot plus the loop's
occupancy telemetry (``active_slots`` / ``batch_occupancy`` /
``queue_depth``) so the placer sees true interleaved capacity — including,
for engines with a cross-request prefix cache, ``cached_pages`` /
``evictable_pages`` / ``prefix_hit_rate`` (evictable cache counts as
reclaimable free capacity; see serving/prefix_cache.py). Finished
sequences additionally record ``prefix_matched_tokens`` /
``prefix_cache_hit_ratio`` into the metrics registry.

Failure contract: an exception escaping ``engine.step()`` poisons the loop —
every pending and future waiter gets the error (wrapped in RuntimeError),
and subsequent submits raise. ``stop()`` joins the thread and unblocks
pending waiters with a "loop stopped" error; sequences already inside the
engine stay there (matching the router's stop() contract of leaving queued
work queued).

Trace context contract: ``submit(prompt, trace=...)`` forwards a
``core.tracing.Trace`` into the engine (carried on the ``Sequence``), so
engine-side spans — chunked-prefill chunks, preemption/resume, per-token
decode instants — land in the request's router-begun trace on a per-sid
lane (``engine-sid<N>``; a hedged request's two sids give two lanes). At
resolve time the loop copies the sequence's per-token timestamps into the
trace and derives TTFT / inter-token-latency observations into the
``ttft_seconds`` / ``itl_seconds`` histograms of its metrics registry
(``telemetry.default_registry()`` unless injected), labeled with the
loop's ``name``. All tracing work is guarded on ``trace is not None`` —
untraced submits pay one branch.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, List, Optional

from repro.core.telemetry import MetricsRegistry, default_registry, log_buckets
from repro.core.tracing import Trace, trace_now
from repro.serving.engine import Sequence


class _SeqFuture:
    """Per-sid completion future the submitting thread blocks on."""

    __slots__ = ("event", "seq", "error")

    def __init__(self):
        self.event = threading.Event()
        self.seq: Optional[Sequence] = None
        self.error: Optional[BaseException] = None


class EngineLoop:
    """Background continuous-batching step loop over one engine.

    Lock order: ``engine.lock`` (taken by engine entry points) and the loop's
    registry ``_lock`` are never held together *nested the wrong way round*:
    ``submit`` takes engine.lock (inside ``engine.submit``) then ``_lock``;
    the step thread calls ``engine.step()`` (engine.lock inside) and only
    takes ``_lock`` after the step returns. A sequence finishing between
    ``engine.submit`` and the future registration is parked in
    ``_unclaimed`` and claimed at registration — no completion is lost.
    """

    def __init__(
        self,
        engine,
        idle_wait_s: float = 0.02,
        name: str = "engine",
        registry: Optional[MetricsRegistry] = None,
    ):
        self.engine = engine
        self.idle_wait_s = idle_wait_s
        self.name = name
        self.registry = registry if registry is not None else default_registry()
        self._lock = threading.Lock()
        self._futures: Dict[int, _SeqFuture] = {}    # guarded by: _lock
        self._unclaimed: Dict[int, Sequence] = {}    # guarded by: _lock
        self._abandoned: set = set()    # guarded by: _lock -- timed-out sids: discard on finish
        self._work = threading.Event()
        self._stop_flag = False
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        self.steps = 0          # batched step() iterations executed
        # deltas for windowed metrics: engine tokens-per-step gauge and the
        # prefix-cache hit-ratio gauge (cumulative counters stay cumulative;
        # the gauges report what happened SINCE the last observation so a
        # long-running engine's gauges never go inert)
        self._tokens_seen = 0
        self._pc_queries_seen = 0
        self._pc_hits_seen = 0

    # -- lifecycle -----------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "EngineLoop":
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("engine loop already started")
            self._stop_flag = False
            t = self._thread = threading.Thread(
                target=self._run, daemon=True, name="engine-loop")
        t.start()
        return self

    def stop(self) -> None:
        """Join the step thread; waiters still pending are failed (the loop
        that would have finished them is gone). Unclaimed completions and
        abandoned sids are dropped too: their waiters have been failed (or
        timed out and left), so nothing will ever claim them — a
        stopped-then-restarted loop (``stop()`` resets ``_thread``, so
        ``start()`` is allowed again) must begin with a clean registry
        instead of carrying orphaned results forever.

        Idempotent and re-entrancy-safe: the thread handle is swapped out
        under ``_lock`` so of N racing stops exactly one joins, and the join
        runs with no lock held — the step thread takes ``_lock`` in
        ``_resolve``, so joining it under the lock would deadlock."""
        self._stop_flag = True
        self._work.set()
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None and t is not threading.current_thread():
            t.join()
        self._fail_pending(RuntimeError("engine loop stopped"))
        with self._lock:
            self._unclaimed.clear()
            self._abandoned.clear()

    def __enter__(self) -> "EngineLoop":
        if self._thread is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission / completion ----------------------------------------------
    def submit(self, prompt: List[int], trace: Optional[Trace] = None) -> int:
        """Enqueue a prompt for continuous batching; returns its sid. The
        engine admits it at the next step with free capacity. ``trace``
        rides the Sequence so engine-side spans land in the request's
        lifecycle trace."""
        if self._error is not None:
            raise RuntimeError(f"engine loop failed: {self._error!r}") from self._error
        sid = self.engine.submit(prompt, trace=trace)
        with self._lock:
            fut = _SeqFuture()
            seq = self._unclaimed.pop(sid, None)
            if seq is not None:        # finished before registration (tiny race)
                fut.seq = seq
                fut.event.set()
            elif self._error is not None or self._stop_flag:
                # the loop died/stopped between the entry check and this
                # registration — nothing will ever resolve the future; fail
                # it here so the waiter can't hang forever
                fut.error = self._error or RuntimeError("engine loop stopped")
                fut.event.set()
            self._futures[sid] = fut
        self._work.set()
        return sid

    def wait(self, sid: int, timeout: Optional[float] = None) -> Sequence:
        """Block until ``sid`` finishes; returns its Sequence (popping the
        future — one wait per sid). Raises TimeoutError past ``timeout``,
        RuntimeError if the loop failed or stopped under it. A timed-out sid
        is ABANDONED: its future is reaped and the eventual result discarded
        (the caller has moved on — the deadline verdict is final), so
        timed-out requests cannot grow the registry without bound."""
        with self._lock:
            fut = self._futures.get(sid)
        if fut is None:
            raise KeyError(f"unknown or already-waited sid {sid}")
        if not fut.event.wait(timeout):
            with self._lock:
                if not fut.event.is_set():     # lost no race: truly unfinished
                    self._futures.pop(sid, None)
                    self._abandoned.add(sid)
                    raise TimeoutError(f"sequence {sid} not finished within {timeout}s")
        with self._lock:
            self._futures.pop(sid, None)
        if fut.error is not None:
            raise RuntimeError(f"engine loop failed: {fut.error!r}") from fut.error
        return fut.seq

    def generate(self, prompts: List[List[int]], timeout: Optional[float] = None) -> List[Sequence]:
        """Drop-in for ``engine.generate``: submit all, wait all — but through
        the shared step loop, so concurrent callers interleave. ``timeout``
        is ONE overall deadline for the whole batch, shared across the
        per-sid waits (waiting a full ``timeout`` per sid would make the
        effective deadline N x the argument)."""
        sids: List[int] = []
        try:
            for p in prompts:
                sids.append(self.submit(p))
        except Exception:
            # a rejected prompt (e.g. too long for the engine) fails the
            # whole batch: reap the siblings already registered, or their
            # futures would sit in the registry forever (only wait() pops)
            with self._lock:
                for s in sids:
                    fut = self._futures.pop(s, None)
                    if fut is not None and not fut.event.is_set():
                        self._abandoned.add(s)
                    self._unclaimed.pop(s, None)
            raise
        deadline = None if timeout is None else time.monotonic() + timeout
        out = []
        for idx, s in enumerate(sids):
            left = None if deadline is None else max(0.0, deadline - time.monotonic())
            try:
                out.append(self.wait(s, left))
            except Exception:
                # a failed batch is final for the WHOLE batch (shared
                # deadline expired, loop poisoned or stopped): abandon the
                # sids never waited on too, so their eventual results are
                # discarded instead of growing the registry forever
                with self._lock:
                    for rest in sids[idx + 1 :]:
                        fut = self._futures.pop(rest, None)
                        if fut is not None and not fut.event.is_set():
                            self._abandoned.add(rest)   # discard on finish
                        self._unclaimed.pop(rest, None)
                raise
        return out

    # -- stepping --------------------------------------------------------------
    def step_once(self) -> List[Sequence]:
        """One loop iteration, synchronously (deterministic tests drive this
        instead of ``start()``): admit + batched step + resolve. Returns the
        sequences finished this step. Per-step speculation observability
        lands here: the ``engine_tokens_per_step`` gauge (delta of the
        engine's cumulative token counter — >1 per decoding slot when
        speculation is accepting) and the ``spec_accepted_run`` histogram
        (one observation per verify pass, the number of proposal tokens
        accepted)."""
        labels = {"engine": self.name}
        finished = self.engine.step()
        self.steps += 1
        self.registry.counter("engine_loop_steps_total", labels).inc()
        emitted = getattr(self.engine, "tokens_emitted", None)
        if emitted is not None:
            self.registry.gauge("engine_tokens_per_step", labels).set(
                emitted - self._tokens_seen
            )
            self._tokens_seen = emitted
        runs = getattr(self.engine, "spec_runs", None)
        if runs:
            hist = self.registry.histogram(
                "spec_accepted_run", labels, bounds=log_buckets(1.0, 2.0, 8)
            )
            for r in runs:
                hist.observe(float(r))
        if finished:
            self._resolve(finished)
        return finished

    def _busy(self) -> bool:
        """Lock-free activity snapshot (drives only the idle sleep; the step
        itself re-checks everything under the engine lock)."""
        eng = self.engine
        return bool(eng.waiting) or any(s is not None for s in eng.slot_seq)

    def _run(self) -> None:
        while not self._stop_flag:
            self._work.clear()
            if not self._busy():
                # cleared BEFORE the busy check: a submit landing after the
                # check sets the event and the wait returns immediately
                self._work.wait(self.idle_wait_s)
                continue
            try:
                self.step_once()
            except Exception as e:          # poison: device/step failure
                self._error = e
                self._fail_pending(e)
                return

    def _resolve(self, seqs: List[Sequence]) -> None:
        for seq in seqs:
            self._observe_finished(seq)
        with self._lock:
            for seq in seqs:
                if seq.sid in self._abandoned:     # waiter timed out and left
                    self._abandoned.discard(seq.sid)
                    continue
                fut = self._futures.get(seq.sid)
                if fut is None:
                    self._unclaimed[seq.sid] = seq
                else:
                    fut.seq = seq
                    fut.event.set()

    def _observe_finished(self, seq: Sequence) -> None:
        """Per-sequence terminal observability: TTFT / inter-token-latency
        histogram observations from the engine-stamped token times, token
        throughput counters, prefix-cache metrics (engines with a prefix
        cache: per-sequence matched tokens into the ``prefix_matched_tokens``
        histogram — misses observe 0 so the hit ratio is derivable — plus
        the cache-wide hit-ratio gauge), and the trace hand-off (per-token
        instants onto the sequence's engine lane)."""
        labels = {"engine": self.name}
        times = seq.token_times
        if times:
            self.registry.histogram("ttft_seconds", labels).observe(
                max(0.0, times[0] - seq.submit_t)
            )
            itl = self.registry.histogram("itl_seconds", labels)
            for a, b in zip(times, times[1:]):
                itl.observe(max(0.0, b - a))
        self.registry.counter("engine_tokens_total", labels).inc(len(seq.out))
        pc = getattr(self.engine, "prefix_cache", None)
        if pc is not None:
            self.registry.histogram(
                "prefix_matched_tokens", labels, bounds=log_buckets(1.0, 2.0, 16)
            ).observe(float(seq.cached_tokens))
            self.registry.counter(
                "prefix_cached_tokens_total", labels
            ).inc(seq.cached_tokens)
            # the hit-ratio gauge is WINDOWED: hits/queries since the last
            # observation, not the lifetime-cumulative ``pc.hit_rate`` (which
            # goes inert on a long-running engine — millions of old queries
            # drown any behavior change). The cumulative counts stay
            # available as counters for rate() -style consumers.
            dq = pc.queries - self._pc_queries_seen
            dh = pc.hits - self._pc_hits_seen
            if dq > 0:
                self.registry.gauge("prefix_cache_hit_ratio", labels).set(dh / dq)
                self.registry.counter("prefix_cache_queries_total", labels).inc(dq)
                self.registry.counter("prefix_cache_hits_total", labels).inc(dh)
                self._pc_queries_seen = pc.queries
                self._pc_hits_seen = pc.hits
        if seq.trace is not None:
            lane = f"engine-sid{seq.sid}"
            seq.trace.add_tokens(lane, times)
            seq.trace.event(
                "resolved", lane=lane, t=trace_now(), sid=seq.sid,
                n_out=len(seq.out), preemptions=seq.preemptions, engine=self.name,
            )

    def _fail_pending(self, err: BaseException) -> None:
        with self._lock:
            for fut in self._futures.values():
                if not fut.event.is_set():
                    fut.error = err
                    fut.event.set()

    # -- capacity telemetry ------------------------------------------------------
    def capacity_now(self) -> dict:
        """Engine snapshot plus loop occupancy: ``active_slots`` (sequences
        interleaved in the current decode batch — PREFILLING slots, which
        occupy capacity but do not decode yet, are counted separately via
        the engine's ``prefilling_slots``), ``batch_occupancy`` (their
        fraction of ``num_slots``), ``queue_depth`` (admitted-but-waiting),
        ``loop_steps``, and the engine's ``prefill_backlog_tokens`` — prompt
        tokens not yet absorbed by the budgeted chunk phase, the signal that
        a tier is digesting a long prompt. Lock-free, instantaneous — same
        staleness contract as ``engine.capacity_now``."""
        snap = self.engine.capacity_now()
        # one default for num_slots everywhere, clamped once: a sparse
        # snapshot (free_slots without num_slots, or the reverse) reports
        # zero occupancy instead of a negative slot count
        total = max(1, snap.get("num_slots", 1))
        occupied = min(total, max(0, total - snap.get("free_slots", total)))
        # PREFILLING slots occupy capacity but are not decoding yet — they
        # are reported via prefilling_slots, not inside the decode batch
        active = max(0, occupied - snap.get("prefilling_slots", 0))
        snap["active_slots"] = active
        snap["batch_occupancy"] = active / total
        snap["queue_depth"] = snap.get("waiting", 0)
        snap["loop_steps"] = self.steps
        snap.setdefault("prefill_backlog_tokens", 0)
        return snap

    def admission_capacity(self, est_tokens: int = 0) -> int:
        return self.engine.admission_capacity(est_tokens)
