"""Cross-request prefix cache: a hash-per-page radix tree over the paged pool.

At production scale most traffic shares prefixes — system prompts, few-shot
templates, multi-turn chat history — so most prefill work recomputes K/V
another request already produced. This module keeps those pages alive after
their sequence finishes and hands them to the next request with the same
token prefix, so prefill for the matched pages is skipped entirely.

Structure
---------
A radix (compressed prefix) tree keyed by *page runs*: every full page of a
token stream becomes one key — the tuple of its ``page_size`` token ids —
and a node holds a run of consecutive page keys plus the physical page ids
backing them, aligned 1:1::

    root ── [sys-prompt p0 p1 p2] ── [few-shot-A p3 p4]
                                  └─ [few-shot-B p5]

Nodes are split at the EXACT divergence point (page granularity): matching
or inserting a stream that shares only part of a node's run splices a fresh
parent holding the common pages above the original node, so matched paths
always end on node boundaries and pinning is exact.

Ownership & ref-counting
------------------------
The tree holds exactly ONE ``BlockAllocator`` reference per cached page, so
the allocator-wide invariant is ``ref_count(page) == live tables holding it
+ (1 if the tree holds it)``:

* ``acquire(tokens)`` bumps each matched page (``allocator.share``) before
  attaching it to the new sequence's ``PageTable`` — the same mechanism
  ``PageTable.fork`` uses for hedged copies — and *pins* the matched path
  (``holders`` +1 on every node from the match point to the root).
* ``insert(tokens, pages)`` CONSUMES the releasing sequence's reference on
  every page passed: pages whose prefix already exists in the tree are
  freed (the tree keeps its own copy), new suffix pages are adopted as-is
  (the sequence's reference becomes the tree's). Release-to-cache is
  therefore a pure ownership transfer — no page is copied or double-held.
* ``evict(n)`` drops cold, unpinned leaves in LRU order (logical-clock
  timestamps, deterministic) until ``n`` pages have actually returned to
  the free list. Pinned paths — prefixes live sequences are decoding from —
  are never evicted, and path-pinning means ``holders == 0`` on a node
  implies its whole subtree is unpinned.

``cached_pages`` / ``evictable_pages`` are maintained incrementally so the
engine's lock-free ``capacity_now()`` can export them without walking the
tree: cached pages are "free-ish" capacity the placer may count as
reclaimable, not occupancy.

Thread-safety: mutations (acquire/insert/evict/pin/release) happen under
the owning engine's lock; the integer stats are safe to read lock-free.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.serving.paging import BlockAllocator

PageKey = Tuple[int, ...]


class PrefixNode:
    """One run of consecutive cached pages; children keyed by the first
    page-key of each child run."""

    __slots__ = ("keys", "pages", "children", "parent", "holders", "last_used")

    def __init__(
        self,
        keys: List[PageKey],
        pages: List[int],
        parent: Optional["PrefixNode"],
        holders: int = 0,
        last_used: int = 0,
    ):
        self.keys = keys
        self.pages = pages
        self.children: Dict[PageKey, "PrefixNode"] = {}
        self.parent = parent
        self.holders = holders
        self.last_used = last_used


class PrefixCache:
    """Radix-tree prefix index over a ``BlockAllocator`` page pool."""

    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self._root = PrefixNode([], [], None)
        self._tick = 0                  # logical LRU clock (deterministic)
        # incremental counters (lock-free reads from capacity_now)
        self.cached_pages = 0           # pages the tree holds a reference to
        self._evictable = 0             # pages in unpinned (holders==0) nodes
        # stats
        self.queries = 0
        self.hits = 0
        self.matched_tokens_total = 0   # tokens served from cache, cumulative
        self.inserted_pages_total = 0
        self.evictions = 0              # leaf nodes dropped
        self.evicted_pages_total = 0

    # -- stats -----------------------------------------------------------------
    @property
    def hit_rate(self) -> float:
        """Fraction of prefix lookups that matched >= 1 page."""
        return self.hits / self.queries if self.queries else 0.0

    def evictable_pages(self) -> int:
        """Pages reclaimable by eviction right now: every page in a node no
        live sequence is pinned to (path-pinning makes holders==0 imply the
        whole subtree is unpinned, so these really can all be dropped)."""
        return self._evictable

    # -- internals -------------------------------------------------------------
    def _page_keys(self, tokens: List[int], n_pages: int) -> List[PageKey]:
        ps = self.page_size
        return [tuple(tokens[i * ps : (i + 1) * ps]) for i in range(n_pages)]

    def _touch(self, node: PrefixNode) -> None:
        """Refresh LRU stamps from ``node`` up to the root."""
        self._tick += 1
        while node is not self._root:
            node.last_used = self._tick
            node = node.parent

    def _split(self, node: PrefixNode, k: int) -> PrefixNode:
        """Split ``node`` at key index ``k`` (0 < k < len): a fresh parent
        takes the first ``k`` (key, page) pairs, ``node`` keeps the rest.
        The ORIGINAL object stays the deeper part so sequences holding a
        reference to it still unpin their full path through the new parent.
        Returns the new parent (the exact divergence point)."""
        upper = PrefixNode(
            node.keys[:k], node.pages[:k], node.parent,
            holders=node.holders, last_used=node.last_used,
        )
        node.parent.children[upper.keys[0]] = upper
        node.keys = node.keys[k:]
        node.pages = node.pages[k:]
        node.parent = upper
        upper.children[node.keys[0]] = node
        return upper

    def _walk(self, keys: List[PageKey], split: bool) -> Tuple[PrefixNode, int]:
        """Descend from the root matching ``keys``; returns (deepest fully
        matched node, number of keys matched). With ``split`` a mid-node
        divergence splits the node so the match ends on a node boundary."""
        node, i = self._root, 0
        while i < len(keys):
            child = node.children.get(keys[i])
            if child is None:
                break
            j, limit = 0, min(len(child.keys), len(keys) - i)
            while j < limit and child.keys[j] == keys[i + j]:
                j += 1
            if j == 0:
                break
            if j < len(child.keys):
                if split:
                    node = self._split(child, j)
                    i += j
                break
            node, i = child, i + j
        return node, i

    def _pin(self, node: PrefixNode, delta: int) -> None:
        """Adjust ``holders`` by +-1 along the path to the root, keeping the
        evictable-page counter exact across 0 <-> 1 transitions."""
        while node is not self._root:
            before = node.holders
            node.holders = before + delta
            assert node.holders >= 0, "prefix-cache pin/release imbalance"
            if before == 0 and delta > 0:
                self._evictable -= len(node.pages)
            elif node.holders == 0 and delta < 0:
                self._evictable += len(node.pages)
            node = node.parent

    # -- match / attach --------------------------------------------------------
    def acquire(self, tokens: List[int]) -> Tuple[List[int], Optional[PrefixNode], int]:
        """Match ``tokens`` against the tree and attach the longest cached
        prefix: returns ``(pages, node, matched_tokens)``. Matched pages get
        one extra allocator reference each (the caller owns it — put them at
        the front of the sequence's ``PageTable``) and the matched path is
        pinned until ``release(node)``. The match is capped one token short
        of the full context so at least one token is always left to prefill
        (something must produce the next-token logits). A miss returns
        ``([], None, 0)`` and pins nothing."""
        n_full = max(0, (len(tokens) - 1) // self.page_size)
        self.queries += 1
        if n_full == 0:
            return [], None, 0
        node, matched = self._walk(self._page_keys(tokens, n_full), split=True)
        if matched == 0:
            return [], None, 0
        pages: List[int] = []
        n = node
        while n is not self._root:
            pages[:0] = n.pages
            n = n.parent
        assert len(pages) == matched
        for p in pages:
            self.allocator.share(p)
        self._pin(node, +1)
        self._touch(node)
        self.hits += 1
        self.matched_tokens_total += matched * self.page_size
        return pages, node, matched * self.page_size

    def pin(self, node: PrefixNode) -> PrefixNode:
        """Add one holder along ``node``'s path — a forked sequence sharing
        cache-attached pages must hold the tree path like its source does,
        so the source finishing does not make the path evictable under the
        still-running clone."""
        self._pin(node, +1)
        return node

    def release(self, node: PrefixNode) -> None:
        """Drop one holder along ``node``'s path (sequence finished or was
        preempted). Page references are NOT touched here — the sequence's
        ``PageTable`` release/insert handles those."""
        self._pin(node, -1)

    def cancel(self, pages: List[int], node: Optional[PrefixNode]) -> None:
        """Undo an ``acquire`` whose admission failed (the remaining pages
        could not be allocated): drop the shares and the pin."""
        if node is None:
            return
        for p in pages:
            self.allocator.free([p])
        self.release(node)

    # -- release-to-cache ------------------------------------------------------
    def insert(self, tokens: List[int], pages: List[int]) -> int:
        """Retire a finished sequence's full pages into the tree, consuming
        the caller's allocator reference on every page passed: prefixes the
        tree already holds free the incoming duplicates, new suffix pages
        are adopted (the reference transfers to the tree). ``tokens`` must
        be exactly the tokens whose K/V the pages contain (``len(pages) ==
        len(tokens) // page_size``). Returns the number of pages adopted."""
        n_full = len(tokens) // self.page_size
        if len(pages) != n_full:
            raise ValueError(f"need {n_full} full pages for {len(tokens)} tokens, got {len(pages)}")
        if n_full == 0:
            return 0
        keys = self._page_keys(tokens, n_full)
        node, matched = self._walk(keys, split=True)
        for p in pages[:matched]:           # duplicates: tree keeps its own copy
            self.allocator.free([p])
        adopted = n_full - matched
        if adopted:
            child = PrefixNode(keys[matched:], list(pages[matched:]), node)
            node.children[keys[matched]] = child
            node = child
            self.cached_pages += adopted
            self._evictable += adopted      # new leaves start unpinned
            self.inserted_pages_total += adopted
        self._touch(node)
        return adopted

    # -- eviction --------------------------------------------------------------
    def _evictable_leaves(self) -> List[PrefixNode]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            if not n.children and n.holders == 0:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def evict(self, n_pages: int) -> int:
        """Drop cold unpinned leaves (LRU first) until ``n_pages`` pages have
        actually returned to the allocator's free list, or nothing evictable
        remains. Returns the pages freed — the engine calls this BEFORE
        preempting any live sequence, because cached pages are reclaimable
        capacity, not occupancy."""
        freed = 0
        while freed < n_pages:
            leaves = self._evictable_leaves()
            if not leaves:
                break
            leaf = min(leaves, key=lambda n: n.last_used)
            freed += self.allocator.free(leaf.pages)   # last-ref pages only
            self.cached_pages -= len(leaf.pages)
            self._evictable -= len(leaf.pages)
            self.evicted_pages_total += len(leaf.pages)
            self.evictions += 1
            leaf.parent.children.pop(leaf.keys[0])
            leaf.parent = None
        return freed

    def drop(self) -> int:
        """Free every cached page and reset the tree (shutdown / tests).
        Requires no live pins — a pinned path means a sequence still decodes
        from these pages and dropping them would corrupt the accounting."""
        stack, dropped = list(self._root.children.values()), 0
        while stack:
            n = stack.pop()
            assert n.holders == 0, "drop() with live sequences attached to the cache"
            self.allocator.free(n.pages)
            dropped += len(n.pages)
            stack.extend(n.children.values())
        self._root.children.clear()
        self.cached_pages = 0
        self._evictable = 0
        return dropped

    # -- introspection ---------------------------------------------------------
    def pages(self) -> List[int]:
        """Every page the tree currently holds a reference to (tests)."""
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            out.extend(n.pages)
            stack.extend(n.children.values())
        return out

    def nodes(self) -> List[PrefixNode]:
        out, stack = [], list(self._root.children.values())
        while stack:
            n = stack.pop()
            out.append(n)
            stack.extend(n.children.values())
        return out

    def stats(self) -> dict:
        return {
            "cached_pages": self.cached_pages,
            "evictable_pages": self._evictable,
            "queries": self.queries,
            "hits": self.hits,
            "hit_rate": self.hit_rate,
            "matched_tokens_total": self.matched_tokens_total,
            "inserted_pages_total": self.inserted_pages_total,
            "evictions": self.evictions,
            "evicted_pages_total": self.evicted_pages_total,
        }

    def check_invariants(self) -> None:
        """Structural + accounting invariants (tests call after every op):
        key/page alignment, child keying, parent links, page uniqueness,
        every cached page allocated, incremental counters exact."""
        seen: set = set()
        total = evictable = 0
        stack = [(self._root, True)]
        while stack:
            n, unpinned_path = stack.pop()
            if n is not self._root:
                assert n.keys and len(n.keys) == len(n.pages), "empty or misaligned node"
                assert all(len(k) == self.page_size for k in n.keys)
                assert n.holders >= 0
                # path-pinning: a pinned descendant pins every ancestor
                assert not (n.holders > 0 and unpinned_path is False) or True
                total += len(n.pages)
                if n.holders == 0:
                    evictable += len(n.pages)
                for p in n.pages:
                    assert p not in seen, f"page {p} cached twice"
                    seen.add(p)
                    assert self.allocator.ref_count(p) >= 1, f"cached page {p} not allocated"
            for key, child in n.children.items():
                assert child.keys[0] == key, "child keyed by wrong first page"
                assert child.parent is n, "broken parent link"
                if n is not self._root and n.holders == 0:
                    assert child.holders == 0, "pinned child under unpinned parent"
                stack.append((child, n.holders == 0))
        assert total == self.cached_pages, (total, self.cached_pages)
        assert evictable == self._evictable, (evictable, self._evictable)
