"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution (arXiv:2409.12191).

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936. The vision frontend
is a STUB: input_specs() provides precomputed patch+token embeddings; M-RoPE
position ids carry the (t, h, w) streams (sections 16/24/24 over hd=128).
"""
import jax.numpy as jnp

from repro.models import ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    inputs="embeds",
    pos="mrope",
    mrope_sections=(16, 24, 24),
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    head_dim=16, mrope_sections=(2, 3, 3),
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
