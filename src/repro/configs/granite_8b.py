"""granite-8b [dense] — llama-arch, code (arXiv:2405.04324).

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.
"""
import jax.numpy as jnp

from repro.models import ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    rope_theta=10000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
