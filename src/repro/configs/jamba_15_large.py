"""jamba-1.5-large-398b [hybrid] — Mamba+attn 1:7 interleave, MoE 16e top-2
(arXiv:2403.19887).

72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536. Superblock = 8 layers
with one attention layer (1:7); MoE FFN on every other layer (matches the
released Jamba period — pins the 398B total). Only 9/72 layers hold KV =>
long_500k decode runs.
"""
import jax.numpy as jnp

from repro.models import MambaCfg, MoECfg, ModelConfig

FULL = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=("mamba", "mamba", "mamba", "attn", "mamba", "mamba", "mamba", "mamba"),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2, chunk=128),
    # fsdp_experts: ~696 GB of expert weights need d_ff sharded over 'data'
    # in addition to experts over 'model' (all-gather at use).
    moe=MoECfg(n_experts=16, top_k=2, every_k=2, fsdp_experts=True),
    rope_theta=10000.0,
)

SMOKE = FULL.replace(
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    mamba=MambaCfg(d_state=4, d_conv=4, expand=2, chunk=8),
    moe=MoECfg(n_experts=4, top_k=2, every_k=2),
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {}
