"""llama4-maverick-400b-a17b [moe] — MoE 128e top-1, early fusion
(hf:meta-llama/Llama-4-Maverick). Text backbone per the assignment.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1.
Expert weights are ~773B params => FSDP-sharded over ('model' experts x
'data' d_ff) and all-gathered at use (fsdp_experts=True).
"""
import jax.numpy as jnp

from repro.models import MoECfg, ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoECfg(n_experts=128, top_k=1, every_k=1, fsdp_experts=True),
    rope_theta=500000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    moe=MoECfg(n_experts=8, top_k=1, every_k=1),
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
