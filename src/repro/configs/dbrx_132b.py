"""dbrx-132b [moe] — 16 experts top-4, fine-grained (hf:databricks/dbrx-base).

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352, MoE 16e top-4.
"""
import jax.numpy as jnp

from repro.models import MoECfg, ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab_size=100352,
    # fsdp_experts: 254 GB of expert weights don't fit 16-way TP alone —
    # shard d_ff over 'data' too and all-gather at use (ZeRO-3 semantics).
    moe=MoECfg(n_experts=16, top_k=4, every_k=1, fsdp_experts=True),
    rope_theta=500000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    moe=MoECfg(n_experts=4, top_k=2, every_k=1),
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
