"""glm4-9b [dense] — RoPE, GQA, QKV bias (hf:THUDM/glm-4-9b).

40L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.
"""
import jax.numpy as jnp

from repro.models import ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="glm4-9b",
    family="dense",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=2,
    d_ff=13696,
    vocab_size=151552,
    qkv_bias=True,
    rope_theta=10000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
