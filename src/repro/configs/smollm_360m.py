"""smollm-360m [dense] — llama-arch small (hf:HuggingFaceTB/SmolLM-360M).

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152.
"""
import jax.numpy as jnp

from repro.models import ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="smollm-360m",
    family="dense",
    n_layers=32,
    d_model=960,
    n_heads=15,
    n_kv_heads=5,
    d_ff=2560,
    vocab_size=49152,
    rope_theta=10000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=60, n_heads=3, n_kv_heads=1, d_ff=128, vocab_size=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
