"""Assigned input shapes (LM-family): every arch runs all four unless its
family makes a shape inapplicable (recorded per-arch in SKIP_SHAPES)."""
from repro.models.api import ShapeSpec

SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

FULL_ATTENTION_SKIP = (
    "long_500k requires sub-quadratic attention; this arch is pure "
    "full-attention (see DESIGN.md §Arch-applicability)"
)
