"""qwen1.5-32b [dense] — QKV bias, MHA-like GQA kv=40 (hf:Qwen/Qwen1.5-32B).

64L d_model=5120 40H (kv=40) d_ff=27392 vocab=152064. The decode_32k KV cache
is 5.5 TB in bf16 — int8 KV quantization (kv_quant) is enabled for decode
shapes by the launcher (see DESIGN.md §Arch-applicability).
"""
import jax.numpy as jnp

from repro.models import ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1000000.0,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
