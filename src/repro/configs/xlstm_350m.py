"""xlstm-350m [ssm] — sLSTM + mLSTM blocks (arXiv:2405.04517).

24L d_model=1024 4H d_ff=0 vocab=50304. Superblock = 7 mLSTM + 1 sLSTM
(xLSTM[7:1]); no separate FFN (d_ff=0 — mixers carry their own projections).
Constant-size recurrent state => long_500k decode runs (no KV growth).
"""
import jax.numpy as jnp

from repro.models import ModelConfig, XLSTMCfg

FULL = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    xlstm=XLSTMCfg(chunk=64, proj_factor=2.0, conv=4),
    pos="none",
)

SMOKE = FULL.replace(
    n_layers=8,
    d_model=64,
    n_heads=4,
    vocab_size=512,
    xlstm=XLSTMCfg(chunk=8, proj_factor=2.0, conv=4),
    param_dtype=jnp.float32,
    compute_dtype=jnp.float32,
    remat="none",
    ce_chunks=2,
)

SKIP_SHAPES = {}
