"""Architecture registry: ``--arch <id>`` resolution for launchers/tests."""
from __future__ import annotations

from typing import Dict, List

from repro.configs import (
    dbrx_132b,
    glm4_9b,
    granite_8b,
    jamba_15_large,
    llama4_maverick,
    qwen2_vl_2b,
    qwen15_32b,
    smollm_360m,
    whisper_large_v3,
    xlstm_350m,
)
from repro.configs.shapes import SHAPES
from repro.models.common import ModelConfig

_MODULES = {
    "xlstm-350m": xlstm_350m,
    "smollm-360m": smollm_360m,
    "glm4-9b": glm4_9b,
    "granite-8b": granite_8b,
    "qwen1.5-32b": qwen15_32b,
    "jamba-1.5-large-398b": jamba_15_large,
    "dbrx-132b": dbrx_132b,
    "llama4-maverick-400b-a17b": llama4_maverick,
    "qwen2-vl-2b": qwen2_vl_2b,
    "whisper-large-v3": whisper_large_v3,
}


def list_archs() -> List[str]:
    return list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {list_archs()}")
    m = _MODULES[arch]
    return m.SMOKE if smoke else m.FULL


def skip_reason(arch: str, shape_name: str) -> str:
    """Empty string if (arch, shape) runs; otherwise the documented reason."""
    return _MODULES[arch].SKIP_SHAPES.get(shape_name, "")


def cells(include_skipped: bool = True):
    """All 40 (arch, shape) cells; skipped ones flagged with their reason."""
    out = []
    for arch in list_archs():
        for sname, spec in SHAPES.items():
            out.append((arch, spec, skip_reason(arch, sname)))
    return out
