"""whisper-large-v3 [audio] — enc-dec, conv frontend stub (arXiv:2212.04356).

32L d_model=1280 20H (kv=20) d_ff=5120 vocab=51866. 32 encoder + 32 decoder
layers (whisper-large-v3's num_hidden_layers=32 applies to each stack). The
audio frontend is a STUB: input_specs() provides precomputed 1500-frame
embeddings. Decoder self-attention uses RoPE instead of the 448-entry
learned table so the assigned decode shapes are well-defined (DESIGN.md §2).
vocab 51866 is not divisible by the TP axis => unembed stays replicated
(ce_chunks raised to bound the logits slice).
"""
import jax.numpy as jnp

from repro.models import EncoderCfg, ModelConfig

from repro.configs.shapes import FULL_ATTENTION_SKIP

FULL = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    encoder=EncoderCfg(n_layers=32, n_ctx=1500, n_heads=20, d_ff=5120),
    cross_attn=True,
    norm="layernorm",
    act="gelu",
    gated_mlp=False,
    ce_chunks=32,
)

SMOKE = FULL.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=512,
    encoder=EncoderCfg(n_layers=2, n_ctx=12, n_heads=4, d_ff=128),
    param_dtype=jnp.float32, compute_dtype=jnp.float32, remat="none",
    attn_chunk=8, ce_chunks=2,
)

SKIP_SHAPES = {"long_500k": FULL_ATTENTION_SKIP}
