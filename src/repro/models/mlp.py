"""Dense FFN: gated (SwiGLU) or plain (GELU) MLP, tensor-parallel over TP."""
from __future__ import annotations

from typing import Mapping

import jax.numpy as jnp

from repro.models.common import NULL, TP, ModelConfig, ParamDef, activation
from repro.models.quant import qeinsum


def mlp_defs(cfg: ModelConfig, d_ff: int = 0) -> dict:
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    defs = {
        "w1": ParamDef((d, f), (NULL, TP)),
        "w2": ParamDef((f, d), (TP, NULL)),
    }
    if cfg.gated_mlp:
        defs["w3"] = ParamDef((d, f), (NULL, TP))
    return defs


def mlp(cfg: ModelConfig, p: Mapping, x: jnp.ndarray) -> jnp.ndarray:
    h = qeinsum("bsd,df->bsf", x, p["w1"])
    h = activation(cfg, h)
    if cfg.gated_mlp:
        h = h * qeinsum("bsd,df->bsf", x, p["w3"])
    return qeinsum("bsf,fd->bsd", h, p["w2"])
