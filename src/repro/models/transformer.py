"""Decoder stack: superblock pattern -> scan over superblocks.

A *superblock* is one repetition of ``cfg.block_pattern`` (e.g. 1 layer for
dense archs; 1 attn + 7 mamba for Jamba; 7 mLSTM + 1 sLSTM for xLSTM). All
superblocks are structurally identical, so their parameters are stacked on a
leading axis and the stack is a single ``lax.scan`` — keeping the HLO (and
compile time at 512 devices) independent of depth. Remat wraps the scan body.

Cache: a pytree whose leaves carry a leading (n_superblocks,) axis; the scan
consumes/produces it as xs/ys.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mamba as mam
from repro.models import xlstm as xl
from repro.models.common import (
    ModelConfig,
    ParamDef,
    apply_norm,
    embed_defs,
    embed_tokens,
    init_tree,
    norm_defs,
    shape_tree,
    stack_defs,
)
from repro.models.mlp import mlp, mlp_defs
from repro.models.moe import moe_defs, moe_ffn

MIXER_KINDS = ("attn", "mamba", "mlstm", "slstm")


@jax.custom_vjp
def _loop_barrier(tree):
    """``optimization_barrier`` that is transparent to reverse-mode AD.

    The barrier primitive has no differentiation rule; training (jax.grad)
    through the superblock scan needs one. The pass-through VJP keeps the
    barrier in the primal graph (where it blocks loop-invariant hoisting of
    weight gathers / upcasts / dequants) while the cotangent flows through
    untouched."""
    return jax.lax.optimization_barrier(tree)


def _loop_barrier_fwd(tree):
    return jax.lax.optimization_barrier(tree), None


def _loop_barrier_bwd(_, ct):
    return (ct,)


_loop_barrier.defvjp(_loop_barrier_fwd, _loop_barrier_bwd)


# ---------------------------------------------------------------------------
# Param / cache definitions
# ---------------------------------------------------------------------------


def superblock_defs(cfg: ModelConfig) -> dict:
    defs: dict = {}
    for i, kind in enumerate(cfg.block_pattern):
        defs[f"l{i}_norm"] = norm_defs(cfg)
        if kind == "attn":
            defs[f"l{i}_mixer"] = attn.attention_defs(cfg)
        elif kind == "mamba":
            defs[f"l{i}_mixer"] = mam.mamba_defs(cfg)
        elif kind == "mlstm":
            defs[f"l{i}_mixer"] = xl.mlstm_defs(cfg)
        elif kind == "slstm":
            defs[f"l{i}_mixer"] = xl.slstm_defs(cfg)
        else:
            raise ValueError(kind)
        if cfg.cross_attn:
            defs[f"l{i}_cross_norm"] = norm_defs(cfg)
            defs[f"l{i}_cross"] = attn.attention_defs(cfg, cross=True)
        if cfg.d_ff > 0:
            defs[f"l{i}_ffn_norm"] = norm_defs(cfg)
            if cfg.layer_has_moe(i):
                defs[f"l{i}_ffn"] = moe_defs(cfg)
            else:
                defs[f"l{i}_ffn"] = mlp_defs(cfg)
    return defs


def param_defs(cfg: ModelConfig) -> dict:
    defs = dict(embed_defs(cfg))
    defs["blocks"] = stack_defs(superblock_defs(cfg), cfg.n_superblocks)
    defs["final_norm"] = norm_defs(cfg)
    return defs


def init(rng: jax.Array, cfg: ModelConfig) -> Any:
    return init_tree(rng, param_defs(cfg), cfg.param_dtype)


def param_shapes(cfg: ModelConfig) -> Any:
    return shape_tree(param_defs(cfg), cfg.param_dtype)


def _stack_shape(defs: Mapping, n: int) -> Any:
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), defs
    )


def cache_defs(cfg: ModelConfig, batch: int, cap: int, enc_len: int = 0) -> dict:
    """ShapeDtypeStructs for the full decode cache (leading n_sb axis)."""
    per_sb: dict = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            per_sb[f"l{i}_mixer"] = attn.kv_cache_defs(cfg, batch, cap)
        elif kind == "mamba":
            per_sb[f"l{i}_mixer"] = mam.mamba_cache_defs(cfg, batch)
        elif kind == "mlstm":
            per_sb[f"l{i}_mixer"] = xl.mlstm_cache_defs(cfg, batch)
        elif kind == "slstm":
            per_sb[f"l{i}_mixer"] = xl.slstm_cache_defs(cfg, batch)
        if cfg.cross_attn:
            assert enc_len > 0
            per_sb[f"l{i}_cross"] = {
                "k": jax.ShapeDtypeStruct((batch, enc_len, cfg.n_heads, cfg.hd), cfg.compute_dtype),
                "v": jax.ShapeDtypeStruct((batch, enc_len, cfg.n_heads, cfg.hd), cfg.compute_dtype),
            }
    return {"blocks": _stack_shape(per_sb, cfg.n_superblocks)}


def init_cache(cfg: ModelConfig, batch: int, cap: int, enc_len: int = 0) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cache_defs(cfg, batch, cap, enc_len))


def paged_cache_defs(cfg: ModelConfig, batch: int, num_pages: int, page_size: int) -> dict:
    """Paged decode cache: attention layers share a per-layer page pool
    (no per-slot max_len stripes — serving/paging.py hands out pages);
    recurrent mixers (mamba/xlstm) keep O(1) per-slot state as before."""
    if cfg.cross_attn:
        raise NotImplementedError("paged cache does not support cross-attention")
    per_sb: dict = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "attn":
            per_sb[f"l{i}_mixer"] = attn.paged_kv_pool_defs(cfg, num_pages, page_size)
        elif kind == "mamba":
            per_sb[f"l{i}_mixer"] = mam.mamba_cache_defs(cfg, batch)
        elif kind == "mlstm":
            per_sb[f"l{i}_mixer"] = xl.mlstm_cache_defs(cfg, batch)
        elif kind == "slstm":
            per_sb[f"l{i}_mixer"] = xl.slstm_cache_defs(cfg, batch)
    return {"blocks": _stack_shape(per_sb, cfg.n_superblocks)}


def init_paged_cache(cfg: ModelConfig, batch: int, num_pages: int, page_size: int) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), paged_cache_defs(cfg, batch, num_pages, page_size)
    )


def chunk_state_defs(cfg: ModelConfig, batch: int = 1) -> dict:
    """ShapeDtypeStructs for the chunked-prefill recurrent carry: one entry
    per NON-attention mixer (attention chunks live directly in the KV
    cache/pool). The carry is deliberately OUTSIDE the decode cache: while a
    sequence is mid-prefill, batched decode steps for other slots still
    sweep every slot's in-cache recurrent state with garbage updates — the
    engine keeps the authoritative state here and installs it into the slot
    only when the last chunk completes."""
    per_sb: dict = {}
    for i, kind in enumerate(cfg.block_pattern):
        if kind == "mamba":
            per_sb[f"l{i}_mixer"] = mam.mamba_cache_defs(cfg, batch)
        elif kind == "mlstm":
            per_sb[f"l{i}_mixer"] = xl.mlstm_cache_defs(cfg, batch)
        elif kind == "slstm":
            per_sb[f"l{i}_mixer"] = xl.slstm_cache_defs(cfg, batch)
    return {"blocks": _stack_shape(per_sb, cfg.n_superblocks)}


def init_chunk_state(cfg: ModelConfig, batch: int = 1) -> dict:
    """Zero carry for the first chunk of a chunked prefill (fresh sequence);
    attention-only models get an empty (leafless) tree."""
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), chunk_state_defs(cfg, batch))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _constrain(ctx, x: jax.Array, seq_shard: bool = False) -> jax.Array:
    if ctx is None:
        return x
    seq_ax = None
    if seq_shard and x.shape[1] % ctx.tp_size == 0:
        # Megatron-style sequence parallelism: activations between blocks live
        # seq-sharded over TP, so XLA emits reduce-scatter + all-gather pairs
        # instead of all-reduces — half the TP wire bytes.
        seq_ax = ctx.tp_axis
    sh = jax.sharding.NamedSharding(ctx.mesh, P(ctx.batch_spec_for(x.shape[0]), seq_ax, None))
    return jax.lax.with_sharding_constraint(x, sh)


def _superblock(
    cfg: ModelConfig,
    ctx,
    p: Mapping,
    x: jax.Array,
    positions,
    mode: str,
    cache_sb: Optional[Mapping],
    cache_index,
    enc_out,
    causal: bool,
    valid=None,
    chunk_sb=None,
):
    new_cache: dict = {}
    new_chunk: dict = {}
    aux = jnp.zeros((), jnp.float32)
    x = _constrain(ctx, x, cfg.seq_shard_activations)
    # Block loop-invariant code motion out of the layer scan: without the
    # barrier XLA hoists (a) FSDP weight all-gathers (materializing every
    # layer's gathered experts at once — 100s of GB for llama4/jamba),
    # (b) bf16->f32 weight upcasts (CPU backend), (c) int8->bf16 KV-cache
    # dequants — all per-layer transients that must stay inside the loop.
    p = _loop_barrier(p)
    if cache_sb is not None:
        cache_sb = _loop_barrier(cache_sb)
    if chunk_sb is not None:
        chunk_sb = _loop_barrier(chunk_sb)
    # Paged prefill: attention layers write straight through the sequence's
    # block-table row into the shared page pool; recurrent mixers run from a
    # zero state (a fresh sequence) and their final state lands in the slot.
    paged_pf = isinstance(cache_index, attn.PagedPrefillIndex)
    # Chunked prefill: attention layers write this chunk at its offset;
    # recurrent mixers resume from (and return) the explicit chunk_sb carry
    # while the in-cache slot state is passed through untouched — the engine
    # installs the carry only after the final chunk (see chunk_state_defs).
    chunk_pf = isinstance(
        cache_index, (attn.ChunkPrefillIndex, attn.PagedChunkPrefillIndex)
    )
    recurrent = {"mamba": mam.mamba_mixer, "mlstm": xl.mlstm_mixer, "slstm": xl.slstm_mixer}
    for i, kind in enumerate(cfg.block_pattern):
        h = apply_norm(cfg, p[f"l{i}_norm"], x)
        c_in = cache_sb.get(f"l{i}_mixer") if cache_sb is not None else None
        if kind == "attn":
            h, c_out = attn.self_attention(
                cfg, p[f"l{i}_mixer"], h, positions, mode, c_in, cache_index, causal=causal
            )
        elif chunk_pf and chunk_sb is not None:
            s_in = chunk_sb[f"l{i}_mixer"]
            h, s_out = recurrent[kind](cfg, p[f"l{i}_mixer"], h, mode, s_in, valid=valid)
            new_chunk[f"l{i}_mixer"] = s_out
            c_out = c_in
        elif paged_pf and c_in is not None:
            zero = jax.tree.map(lambda l: jnp.zeros((1,) + l.shape[1:], l.dtype), c_in)
            h, c_part = recurrent[kind](cfg, p[f"l{i}_mixer"], h, mode, zero, valid=valid)
            c_out = jax.tree.map(
                lambda full, part: jax.lax.dynamic_update_slice_in_dim(
                    full, part.astype(full.dtype), cache_index.slot, axis=0
                ),
                c_in,
                c_part,
            )
        else:
            h, c_out = recurrent[kind](cfg, p[f"l{i}_mixer"], h, mode, c_in, valid=valid)
        x = x + h
        if cache_sb is not None:
            new_cache[f"l{i}_mixer"] = c_out
        if cfg.cross_attn:
            h = apply_norm(cfg, p[f"l{i}_cross_norm"], x)
            if mode == "train":
                kv = attn.cross_kv(cfg, p[f"l{i}_cross"], enc_out)
            elif mode == "prefill":
                kv = attn.cross_kv(cfg, p[f"l{i}_cross"], enc_out)
                new_cache[f"l{i}_cross"] = jax.tree.map(lambda a: a.astype(cfg.compute_dtype), kv)
            else:  # decode
                kv = cache_sb[f"l{i}_cross"]
                new_cache[f"l{i}_cross"] = kv
            x = x + attn.cross_attention(cfg, p[f"l{i}_cross"], h, kv)
        if cfg.d_ff > 0:
            h = apply_norm(cfg, p[f"l{i}_ffn_norm"], x)
            if cfg.layer_has_moe(i):
                h, a = moe_ffn(cfg, ctx, p[f"l{i}_ffn"], h, valid=valid)
                aux = aux + a
            else:
                h = mlp(cfg, p[f"l{i}_ffn"], h)
            x = x + h
        x = _constrain(ctx, x, cfg.seq_shard_activations)
    return (
        x,
        (new_cache if cache_sb is not None else None),
        (new_chunk if chunk_sb is not None else None),
        aux,
    )


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def run_stack(
    cfg: ModelConfig,
    ctx,
    blocks_params,
    x: jax.Array,
    positions,
    mode: str,
    cache: Optional[Mapping] = None,
    cache_index=None,
    enc_out=None,
    causal: bool = True,
    valid=None,
    chunk_state=None,
):
    """Scan the superblock stack. Returns (x, new_cache, new_chunk_state,
    aux); ``new_chunk_state`` is None unless ``chunk_state`` (the chunked
    prefill recurrent carry, scanned alongside the cache) was given."""
    remat = mode == "train" and cfg.remat != "none"

    if cache is None:
        def body(carry, p_sb):
            xx, aux = carry
            xx, _, _, a = _superblock(cfg, ctx, p_sb, xx, positions, mode, None, cache_index, enc_out, causal, valid)
            return (xx, aux + a), None

        body = _remat_wrap(cfg, body) if remat else body
        (x, aux), _ = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), blocks_params, unroll=cfg.scan_unroll
        )
        return x, None, None, aux

    if chunk_state is not None:
        def body(carry, sb):
            xx, aux = carry
            p_sb, c_sb, s_sb = sb
            xx, c_new, s_new, a = _superblock(
                cfg, ctx, p_sb, xx, positions, mode, c_sb, cache_index, enc_out,
                causal, valid, chunk_sb=s_sb,
            )
            return (xx, aux + a), (c_new, s_new)

        body = _remat_wrap(cfg, body) if remat else body
        (x, aux), (new_blocks, new_state) = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)),
            (blocks_params, cache["blocks"], chunk_state["blocks"]),
            unroll=cfg.scan_unroll,
        )
        return x, {"blocks": new_blocks}, {"blocks": new_state}, aux

    def body(carry, sb):
        xx, aux = carry
        p_sb, c_sb = sb
        xx, c_new, _, a = _superblock(cfg, ctx, p_sb, xx, positions, mode, c_sb, cache_index, enc_out, causal, valid)
        return (xx, aux + a), c_new

    body = _remat_wrap(cfg, body) if remat else body
    (x, aux), new_blocks = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), (blocks_params, cache["blocks"]),
        unroll=cfg.scan_unroll,
    )
    return x, {"blocks": new_blocks}, None, aux


def forward(
    cfg: ModelConfig,
    ctx,
    params: Mapping,
    tokens: Optional[jax.Array] = None,
    inputs_embeds: Optional[jax.Array] = None,
    positions=None,
    mode: str = "train",
    cache: Optional[Mapping] = None,
    cache_index=None,
    enc_out=None,
    n_valid=None,
    chunk_state=None,
) -> Tuple[jax.Array, Optional[Mapping], jax.Array]:
    """Returns (hidden (B,S,d) post-final-norm, new_cache, moe_aux) — or,
    when ``chunk_state`` is given (chunked prefill), the 4-tuple
    (hidden, new_cache, new_chunk_state, moe_aux).

    ``n_valid`` (B,) marks right-padded prefill: tokens at positions >=
    n_valid[b] are padding and must be identity for every stateful update —
    causal attention ignores them for free, recurrent mixers and the MoE
    router receive the derived ``valid`` mask. (Chunked prefill caveat: the
    MoE capacity competition is per-CHUNK, so expert drops can differ from a
    whole-prompt prefill when capacity binds.)"""
    if inputs_embeds is not None:
        x = inputs_embeds.astype(cfg.compute_dtype)
    else:
        x = embed_tokens(cfg, params, tokens)
    valid = None
    if n_valid is not None:
        S = x.shape[1]
        nv = jnp.asarray(n_valid, jnp.int32).reshape(-1, 1)
        valid = jnp.arange(S, dtype=jnp.int32)[None, :] < nv
    x = _constrain(ctx, x)
    x, new_cache, new_chunk, aux = run_stack(
        cfg, ctx, params["blocks"], x, positions, mode, cache, cache_index, enc_out,
        valid=valid, chunk_state=chunk_state,
    )
    x = apply_norm(cfg, params["final_norm"], x)
    if chunk_state is not None:
        return x, new_cache, new_chunk, aux
    return x, new_cache, aux
