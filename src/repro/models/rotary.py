"""Rotary position embeddings: standard RoPE and Qwen2-VL's M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rotate(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    # x: (..., head_dim); cos/sin broadcastable to (..., head_dim//2)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, hd); positions: (B, S) int32."""
    inv = rope_freqs(x.shape[-1], theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, hd/2)
    cos = jnp.cos(ang)[..., None, :]  # (B, S, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x, cos, sin)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple
) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions: (3, B, S) int32 — temporal/height/width
    streams. ``sections`` partitions the hd/2 frequency slots among the three
    streams (e.g. (16, 24, 24) for hd=128).
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (3, B, S, hd/2)
    # Select which stream drives each frequency slot.
    sel = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=hd // 2)
    ang = jnp.take_along_axis(ang, sel[None, None, None, :].astype(jnp.int32), axis=0)[0]
    # -> (B, S, hd/2) after picking stream per slot
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    return _rotate(x, cos, sin)


def positions_for(
    batch: int, seq: int, offset=0, dtype=jnp.int32
) -> jax.Array:
    return jnp.arange(seq, dtype=dtype)[None, :] + jnp.asarray(offset, dtype)


def mrope_positions_for(batch: int, seq: int, offset=0) -> jax.Array:
    """Text-only default: all three streams share the temporal index."""
    p = positions_for(batch, seq, offset)
    p = jnp.broadcast_to(p, (batch, seq))
    return jnp.broadcast_to(p[None], (3, batch, seq))
