"""Unified model API: one interface over decoder-LM / VLM-backbone / enc-dec.

Everything downstream (trainer, serving engine, dry-run launcher, StraightLine
estimator) talks to models through this facade:

    model = get_model(cfg)
    loss, metrics = model.loss(ctx, params, batch)          # train step core
    tok, cache    = model.prefill(ctx, params, batch)        # serve prefill
    tok, cache    = model.decode(ctx, params, cache, batch)  # serve decode

``input_specs(shape)`` returns ShapeDtypeStruct stand-ins for every input of
the corresponding step — the dry-run lowers against these, no allocation.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import transformer as tf
from repro.models import whisper as wh
from repro.models.common import ModelConfig, init_tree, shape_tree
from repro.models.loss import lm_loss, next_tokens, next_tokens_all
from repro.models.rotary import mrope_positions_for, positions_for


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


def _tok(shape) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def _last_valid(h: jax.Array, n_valid) -> jax.Array:
    """Hidden state of the last *valid* token of a right-padded prefill.
    h: (B, S, d); n_valid: (B,) or scalar. Returns (B, 1, d)."""
    if n_valid is None:
        return h
    B, S, _ = h.shape
    idx = jnp.clip(jnp.asarray(n_valid, jnp.int32).reshape(-1) - 1, 0, S - 1)
    return h[jnp.arange(B), idx][:, None, :]


@dataclass
class DecoderLM:
    cfg: ModelConfig

    # -- params ------------------------------------------------------------
    def param_defs(self):
        return tf.param_defs(self.cfg)

    def init(self, rng):
        return init_tree(rng, self.param_defs(), self.cfg.param_dtype)

    def param_shapes(self):
        return shape_tree(self.param_defs(), self.cfg.param_dtype)

    # -- cache ---------------------------------------------------------------
    def cache_defs(self, batch: int, cap: int):
        return tf.cache_defs(self.cfg, batch, cap)

    def init_cache(self, batch: int, cap: int):
        return tf.init_cache(self.cfg, batch, cap)

    def init_paged_cache(self, batch: int, num_pages: int, page_size: int):
        return tf.init_paged_cache(self.cfg, batch, num_pages, page_size)

    # -- steps ---------------------------------------------------------------
    def _positions(self, batch: int, seq: int, offset=0):
        if self.cfg.pos == "mrope":
            return mrope_positions_for(batch, seq, offset)
        p = positions_for(batch, seq, offset)
        return jnp.broadcast_to(p, (batch, seq))

    def loss(self, ctx, params, batch: Mapping) -> Tuple[jax.Array, dict]:
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = batch.get("positions")
        if pos is None:
            pos = self._positions(B, S)
        h, _, aux = tf.forward(self.cfg, ctx, params, tokens=tokens, positions=pos, mode="train")
        loss, metrics = lm_loss(self.cfg, ctx, params, h, batch["labels"])
        if self.cfg.moe is not None:
            loss = loss + self.cfg.moe.aux_weight * aux
            metrics["moe_aux"] = aux
        return loss, metrics

    def prefill(self, ctx, params, batch: Mapping, cap: int = 0):
        """Dense prefill. An optional ``batch["n_valid"]`` (B,) marks
        right-padded prompts: pad positions are identity for every stateful
        update and the emitted token comes from the last valid position."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        cap = cap or S
        n_valid = batch.get("n_valid")
        pos = batch.get("positions")
        if pos is None:
            pos = self._positions(B, S)
        cache = self.init_cache(B, cap)
        h, cache, _ = tf.forward(
            self.cfg, ctx, params, tokens=tokens, positions=pos,
            mode="prefill", cache=cache, cache_index=0, n_valid=n_valid,
        )
        return next_tokens(self.cfg, ctx, params, _last_valid(h, n_valid)), cache

    def prefill_paged(self, ctx, params, batch: Mapping, cache):
        """Paged prefill of ONE sequence straight into the shared page pool.

        batch: tokens (1, Lp) right-padded to a bucket length, n_valid (1,),
        tab_row (P,) block-table row, slot scalar (recurrent-state slot).
        Attention K/V scatter through the block table inside each layer (no
        dense per-length staging cache); recurrent mixers run from zero state
        and land their final state in ``slot``. Returns (next_token, cache)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert B == 1, "prefill_paged scatters through ONE block-table row; B must be 1"
        n_valid = batch.get("n_valid")
        pos = batch.get("positions")
        if pos is None:
            pos = self._positions(B, S)
        pidx = attn_mod.PagedPrefillIndex(
            tab_row=jnp.asarray(batch["tab_row"], jnp.int32),
            slot=jnp.asarray(batch["slot"], jnp.int32),
        )
        h, cache, _ = tf.forward(
            self.cfg, ctx, params, tokens=tokens, positions=pos,
            mode="prefill", cache=cache, cache_index=pidx, n_valid=n_valid,
        )
        return next_tokens(self.cfg, ctx, params, _last_valid(h, n_valid)), cache

    def init_chunk_state(self):
        """Zero recurrent carry for a chunked prefill (B=1): one leaf per
        non-attention mixer, empty tree for attention-only models. The
        engine threads this through ``prefill_chunk*`` calls and installs it
        into the decode cache after the final chunk."""
        return tf.init_chunk_state(self.cfg)

    def prefill_chunk(self, ctx, params, batch: Mapping, cache, chunk_state):
        """Dense resumable partial-context prefill of ONE slot's stripe.

        batch: tokens (1, Cp) — one right-padded chunk; n_valid (1,) valid
        tokens IN THIS CHUNK; offset scalar int32 — tokens already in cache
        (chunk token t sits at absolute position offset + t, positions and
        causal masks follow). cache: the slot's mini cache (B=1 leaves, full
        capacity) — attention K/V is written at ``offset`` and the chunk
        attends over the whole stripe by absolute position. chunk_state: the
        recurrent carry from the previous chunk (``init_chunk_state()`` for
        the first). Returns (next_token (1,), cache, chunk_state); only the
        FINAL chunk's token (emitted from the chunk's last valid position)
        is meaningful."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        n_valid = batch.get("n_valid")
        offset = jnp.asarray(batch["offset"], jnp.int32)
        pos = self._positions(B, S, offset)
        cidx = attn_mod.ChunkPrefillIndex(offset=offset)
        h, cache, chunk_state, _ = tf.forward(
            self.cfg, ctx, params, tokens=tokens, positions=pos,
            mode="prefill", cache=cache, cache_index=cidx, n_valid=n_valid,
            chunk_state=chunk_state,
        )
        return next_tokens(self.cfg, ctx, params, _last_valid(h, n_valid)), cache, chunk_state

    def prefill_chunk_paged(self, ctx, params, batch: Mapping, cache, chunk_state):
        """Paged resumable partial-context prefill of ONE sequence.

        Like ``prefill_chunk`` but against the shared page pool: batch
        additionally carries tab_row (P,) — the sequence's FULL block-table
        row — and slot (scalar). ``offset`` must be a page multiple (the
        engine's chunk size is); the chunk's K/V scatters through the row
        shifted to the offset (tail-chunk bucket padding past the table
        lands on the null page) and its queries attend over the dense
        gathered context view. Returns (next_token (1,), cache,
        chunk_state)."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert B == 1, "prefill_chunk_paged scatters through ONE block-table row; B must be 1"
        n_valid = batch.get("n_valid")
        offset = jnp.asarray(batch["offset"], jnp.int32)
        pos = self._positions(B, S, offset)
        cidx = attn_mod.PagedChunkPrefillIndex(
            tab_row=jnp.asarray(batch["tab_row"], jnp.int32),
            slot=jnp.asarray(batch["slot"], jnp.int32),
            offset=offset,
        )
        h, cache, chunk_state, _ = tf.forward(
            self.cfg, ctx, params, tokens=tokens, positions=pos,
            mode="prefill", cache=cache, cache_index=cidx, n_valid=n_valid,
            chunk_state=chunk_state,
        )
        return next_tokens(self.cfg, ctx, params, _last_valid(h, n_valid)), cache, chunk_state

    def verify(self, ctx, params, batch: Mapping, cache):
        """Dense speculative-decode verify of ONE slot's stripe (B=1).

        batch: tokens (1, S) — the slot's pending last token followed by k
        proposal tokens; offset scalar int32 — tokens already in cache (the
        stripe write-head). All S tokens' K/V are written at ``offset`` (a
        verify step IS a chunk — same stripe write + absolute-position
        masking as ``prefill_chunk``, reusing its cache index; offsets need
        not be aligned, dense stripes accept any position) and the greedy
        next token is emitted at EVERY position: (tokens (1, S), cache).
        Token j of the output is the model's continuation after verify
        position j — the engine accepts the longest run where proposal
        tokens match and rolls the write-head back past the rest (stale
        positions are masked by length and overwritten by the next write).
        Attention-only decoders only (no recurrent carry rides this pass);
        the engine enforces that at construction."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert B == 1, "verify runs ONE slot's stripe; B must be 1"
        offset = jnp.asarray(batch["offset"], jnp.int32)
        pos = self._positions(B, S, offset)
        cidx = attn_mod.ChunkPrefillIndex(offset=offset)
        h, cache, _ = tf.forward(
            self.cfg, ctx, params, tokens=tokens, positions=pos,
            mode="prefill", cache=cache, cache_index=cidx,
        )
        return next_tokens_all(self.cfg, ctx, params, h), cache

    def verify_paged(self, ctx, params, batch: Mapping, cache):
        """Paged speculative-decode verify of ONE sequence (B=1).

        Like ``verify`` but against the shared page pool: batch additionally
        carries tab_row (P,) — the sequence's full block-table row. The S
        verify tokens scatter through the row at an ARBITRARY (mid-page)
        offset — ``PagedVerifyIndex`` / ``paged_verify_write``, the
        per-token-indexed sibling of ``prefill_chunk_paged``'s page-shifted
        scatter — and queries attend over the gathered context view masked
        by absolute position. Returns (tokens (1, S), cache); rejected
        speculative positions stay in the pool as garbage until the engine
        rolls its write-head (and speculative tail pages) back."""
        tokens = batch["tokens"]
        B, S = tokens.shape
        assert B == 1, "verify_paged scatters through ONE block-table row; B must be 1"
        offset = jnp.asarray(batch["offset"], jnp.int32)
        pos = self._positions(B, S, offset)
        cidx = attn_mod.PagedVerifyIndex(
            tab_row=jnp.asarray(batch["tab_row"], jnp.int32), offset=offset
        )
        h, cache, _ = tf.forward(
            self.cfg, ctx, params, tokens=tokens, positions=pos,
            mode="prefill", cache=cache, cache_index=cidx,
        )
        return next_tokens_all(self.cfg, ctx, params, h), cache

    def install_chunk_state(self, cache, chunk_state, slot):
        """Write a completed chunked prefill's recurrent carry into the
        decode cache at ``slot`` (leaves are (n_sb, B, ...); the carry is
        (n_sb, 1, ...)). Attention K/V needs no install — chunks wrote the
        cache/pool directly."""
        blocks = dict(cache["blocks"])
        for key, part in chunk_state["blocks"].items():
            blocks[key] = jax.tree.map(
                lambda full, p: jax.lax.dynamic_update_slice_in_dim(
                    full, p.astype(full.dtype), jnp.asarray(slot, jnp.int32), axis=1
                ),
                blocks[key],
                part,
            )
        return {**cache, "blocks": blocks}

    def decode(self, ctx, params, cache, batch: Mapping):
        tok = batch["token"]
        B, S = tok.shape
        if "block_tab" in batch:
            # paged path: cache is a page pool, "block_tab" (B, P) maps each
            # slot's logical blocks to physical pages (serving/paging.py);
            # with "l2_tab" it is instead the first level of a chained table.
            lens = jnp.asarray(batch["lengths"], jnp.int32)
            l2 = batch.get("l2_tab")
            pidx = attn_mod.PagedIndex(
                lens,
                jnp.asarray(batch["block_tab"], jnp.int32),
                None if l2 is None else jnp.asarray(l2, jnp.int32),
            )
            pos = lens[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
            if self.cfg.pos == "mrope":
                pos = jnp.broadcast_to(pos[None], (3, B, S))
            h, cache, _ = tf.forward(
                self.cfg, ctx, params, tokens=tok, positions=pos,
                mode="decode", cache=cache, cache_index=pidx,
            )
            return next_tokens(self.cfg, ctx, params, h), cache
        # "lengths" (B,) enables per-slot cache positions (continuous
        # batching); "cache_index" scalar is the aligned-batch/dry-run path.
        idx = batch.get("lengths", batch["cache_index"])
        if hasattr(idx, "ndim") and getattr(idx, "ndim", 0) == 1:
            pos = idx[:, None] + jnp.arange(S, dtype=jnp.int32)[None, :]
            if self.cfg.pos == "mrope":
                pos = jnp.broadcast_to(pos[None], (3, B, S))
        else:
            pos = self._positions(B, S, offset=idx)
        h, cache, _ = tf.forward(
            self.cfg, ctx, params, tokens=tok, positions=pos,
            mode="decode", cache=cache, cache_index=idx,
        )
        return next_tokens(self.cfg, ctx, params, h), cache

    # -- dry-run specs ---------------------------------------------------------
    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "train":
            return {"tokens": _tok((B, S)), "labels": _tok((B, S))}
        if shape.kind == "prefill":
            return {"tokens": _tok((B, S))}
        if shape.kind == "decode":
            return {"token": _tok((B, 1)), "cache_index": _tok(())}
        raise ValueError(shape.kind)


@dataclass
class EmbedsLM(DecoderLM):
    """VLM backbone: inputs are precomputed patch/token embeddings (stub frontend)."""

    def loss(self, ctx, params, batch: Mapping):
        emb = batch["inputs_embeds"]
        B, S, _ = emb.shape
        pos = batch.get("positions")
        if pos is None:
            pos = self._positions(B, S)
        h, _, aux = tf.forward(self.cfg, ctx, params, inputs_embeds=emb, positions=pos, mode="train")
        loss, metrics = lm_loss(self.cfg, ctx, params, h, batch["labels"])
        return loss, metrics

    def prefill(self, ctx, params, batch: Mapping, cap: int = 0):
        emb = batch["inputs_embeds"]
        B, S, _ = emb.shape
        cap = cap or S
        n_valid = batch.get("n_valid")
        pos = batch.get("positions")
        if pos is None:
            pos = self._positions(B, S)
        cache = self.init_cache(B, cap)
        h, cache, _ = tf.forward(
            self.cfg, ctx, params, inputs_embeds=emb, positions=pos,
            mode="prefill", cache=cache, cache_index=0, n_valid=n_valid,
        )
        return next_tokens(self.cfg, ctx, params, _last_valid(h, n_valid)), cache

    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        B, S, d = shape.global_batch, shape.seq_len, self.cfg.d_model
        emb = jax.ShapeDtypeStruct((B, S, d), self.cfg.compute_dtype)
        pos = _tok((3, B, S))
        if shape.kind == "train":
            return {"inputs_embeds": emb, "positions": pos, "labels": _tok((B, S))}
        if shape.kind == "prefill":
            return {"inputs_embeds": emb, "positions": pos}
        if shape.kind == "decode":
            return {"token": _tok((B, 1)), "cache_index": _tok(())}
        raise ValueError(shape.kind)


@dataclass
class EncDecLM(DecoderLM):
    """Whisper-style enc-dec; frames are stub (precomputed) embeddings."""

    def param_defs(self):
        return wh.param_defs(self.cfg)

    def cache_defs(self, batch: int, cap: int):
        return tf.cache_defs(self.cfg, batch, cap, enc_len=self.cfg.encoder.n_ctx)

    def init_cache(self, batch: int, cap: int):
        return tf.init_cache(self.cfg, batch, cap, enc_len=self.cfg.encoder.n_ctx)

    def loss(self, ctx, params, batch: Mapping):
        tokens = batch["tokens"]
        B, S = tokens.shape
        pos = self._positions(B, S)
        h, _, aux = wh.forward(
            self.cfg, ctx, params, frames=batch["frames"], tokens=tokens,
            positions=pos, mode="train",
        )
        loss, metrics = lm_loss(self.cfg, ctx, params["decoder"], h, batch["labels"])
        return loss, metrics

    def prefill(self, ctx, params, batch: Mapping, cap: int = 0):
        tokens = batch["tokens"]
        B, S = tokens.shape
        cap = cap or S
        n_valid = batch.get("n_valid")
        pos = self._positions(B, S)
        cache = self.init_cache(B, cap)
        h, cache, _ = wh.forward(
            self.cfg, ctx, params, frames=batch["frames"], tokens=tokens,
            positions=pos, mode="prefill", cache=cache, cache_index=0,
            n_valid=n_valid,
        )
        return next_tokens(self.cfg, ctx, params["decoder"], _last_valid(h, n_valid)), cache

    def decode(self, ctx, params, cache, batch: Mapping):
        tok = batch["token"]
        B, S = tok.shape
        idx = batch["cache_index"]
        pos = self._positions(B, S, offset=idx)
        h, cache, _ = wh.forward(
            self.cfg, ctx, params, tokens=tok, positions=pos,
            mode="decode", cache=cache, cache_index=idx,
        )
        return next_tokens(self.cfg, ctx, params["decoder"], h), cache

    def input_specs(self, shape: ShapeSpec) -> Dict[str, Any]:
        B, S = shape.global_batch, shape.seq_len
        frames = jax.ShapeDtypeStruct(
            (B, self.cfg.encoder.n_ctx, self.cfg.d_model), self.cfg.compute_dtype
        )
        if shape.kind == "train":
            return {"frames": frames, "tokens": _tok((B, S)), "labels": _tok((B, S))}
        if shape.kind == "prefill":
            return {"frames": frames, "tokens": _tok((B, S))}
        if shape.kind == "decode":
            return {"token": _tok((B, 1)), "cache_index": _tok(())}
        raise ValueError(shape.kind)


def get_model(cfg: ModelConfig):
    if cfg.encoder is not None:
        return EncDecLM(cfg)
    if cfg.inputs == "embeds":
        return EmbedsLM(cfg)
    return DecoderLM(cfg)
