"""Shared model substrate: configs, parameter definitions, norms, embeddings.

Pure-JAX (no flax): parameters are nested dicts of arrays; every module
exposes ``*_defs(cfg) -> dict[name, ParamDef]`` so that initialization and
PartitionSpec trees are derived from a single source of truth.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass
from typing import Any, Callable, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    every_k: int = 1            # MoE FFN on layers where (layer_idx % every_k == every_k - 1)
    capacity_factor: float = 1.25
    aux_weight: float = 0.01
    fsdp_experts: bool = False  # shard expert weights over 'data' too; all-gather at use


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    chunk: int = 256
    dt_rank: int = 0            # 0 -> ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMCfg:
    chunk: int = 64
    proj_factor: float = 2.0    # mLSTM up-projection factor
    conv: int = 4
    slstm_ff_factor: float = 1.375  # sLSTM post-FFN factor (4/3 rounded up to /64)


@dataclass(frozen=True)
class EncoderCfg:
    """Encoder stack for enc-dec archs (whisper). Frontend is a stub:
    input_specs() provides precomputed frame embeddings of length n_ctx."""

    n_layers: int
    n_ctx: int
    n_heads: int
    d_ff: int


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    block_pattern: tuple = ("attn",)  # mixer types per superblock
    qkv_bias: bool = False
    pos: str = "rope"           # rope | mrope | none | learned
    rope_theta: float = 10000.0
    mrope_sections: tuple = (16, 24, 24)
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    act: str = "silu"
    gated_mlp: bool = True
    moe: Optional[MoECfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    encoder: Optional[EncoderCfg] = None
    cross_attn: bool = False    # decoder layers carry cross-attention (enc-dec)
    inputs: str = "tokens"      # tokens | embeds (vlm backbone)
    tie_embeddings: bool = False
    param_dtype: Any = jnp.bfloat16
    compute_dtype: Any = jnp.bfloat16
    remat: str = "full"         # full | dots | none
    attn_chunk: int = 1024      # query-chunk size for chunked causal attention
    ce_chunks: int = 8          # sequence chunks for vocab-parallel CE
    kv_quant: bool = False      # int8 KV cache
    kv_cache_dtype: Any = None  # non-quantized KV cache storage dtype
                                # (None -> compute_dtype; ignored when kv_quant)
    use_pallas: bool = False    # select Pallas kernels (TPU target); jnp ref path on CPU
    logit_softcap: float = 0.0
    # --- perf-variant knobs (EXPERIMENTS.md §Perf) ---
    weights_int8: bool = False        # weight-only int8 serving (quant.py)
    attn_scores_bf16: bool = False    # materialize attention scores in bf16
    seq_shard_activations: bool = False  # Megatron-SP: shard seq over TP between blocks
    moe_token_gather: bool = False    # decode MoE: gather tokens, keep experts sharded
    scan_unroll: int = 1              # unroll factor for the layer scan (1 = loop)

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def kv_dtype(self):
        """Storage dtype of non-quantized KV caches/pools."""
        return self.kv_cache_dtype if self.kv_cache_dtype is not None else self.compute_dtype

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % len(self.block_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not divisible by "
            f"pattern length {len(self.block_pattern)}"
        )
        return self.n_layers // len(self.block_pattern)

    def layer_has_moe(self, pos_in_superblock: int) -> bool:
        if self.moe is None:
            return False
        k = self.moe.every_k
        return pos_in_superblock % k == k - 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Parameter definitions: one source of truth for shape / sharding / init
# ---------------------------------------------------------------------------

# Logical axis names used in ParamDef specs; resolved to mesh axes by
# repro.sharding.axes.Rules.
EMBED = "embed"      # d_model dims of weights          -> replicated (or fsdp)
TP = "tp"            # tensor-parallel dim (heads/ff/vocab/d_inner) -> 'model'
FSDP = "fsdp"        # fully-sharded dim                -> 'data'
STACK = "stack"      # superblock stacking dim          -> replicated
NULL = None


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple           # logical axis per dim (same length as shape)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.0    # 0 -> 1/sqrt(fan_in)

    def fan_in(self) -> int:
        if len(self.shape) == 1:
            return self.shape[0]
        return self.shape[-2]


def init_param(rng: jax.Array, d: ParamDef, dtype) -> jax.Array:
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    scale = d.scale if d.scale else 1.0 / math.sqrt(max(1, d.fan_in()))
    return (jax.random.normal(rng, d.shape, jnp.float32) * scale).astype(dtype)


def init_tree(rng: jax.Array, defs, dtype) -> Any:
    """defs: nested dict of ParamDef -> same-structure dict of arrays."""
    leaves, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    rngs = jax.random.split(rng, len(leaves))
    out = [init_param(r, d, dtype) for r, d in zip(rngs, leaves)]
    return jax.tree.unflatten(treedef, out)


def shape_tree(defs, dtype) -> Any:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def stack_defs(defs: Any, n: int) -> Any:
    """Prepend a superblock-stacking dim to every ParamDef in a tree."""

    def f(d: ParamDef) -> ParamDef:
        return ParamDef((n,) + d.shape, (STACK,) + d.axes, d.init, d.scale)

    return jax.tree.map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Norms / activations
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32)).astype(x.dtype)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def norm_defs(cfg: ModelConfig, d: int = 0) -> dict:
    d = d or cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": ParamDef((d,), (NULL,), "ones"), "b": ParamDef((d,), (NULL,), "zeros")}
    return {"w": ParamDef((d,), (NULL,), "ones")}


def apply_norm(cfg: ModelConfig, p: Mapping, x: jax.Array) -> jax.Array:
    if cfg.norm == "layernorm":
        return layernorm(x, p["w"], p["b"])
    if cfg.use_pallas:
        from repro.kernels.rmsnorm import ops as rms_ops

        return rms_ops.rmsnorm(x, p["w"])
    return rmsnorm(x, p["w"])


def activation(cfg: ModelConfig, x: jax.Array) -> jax.Array:
    if cfg.act == "gelu":
        return jax.nn.gelu(x)
    return jax.nn.silu(x)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig) -> dict:
    # Embedding table: vocab-sharded over TP (Megatron VocabParallelEmbedding —
    # SPMD lowers the gather as mask-local-rows + psum of partial embeddings;
    # the d-sharded alternative trips an SPMD resharding bug under the
    # microbatch scan). Unembed: vocab sharded over TP for vocab-parallel CE.
    d = {"embedding": ParamDef((cfg.vocab_size, cfg.d_model), (TP, NULL), scale=1.0)}
    if not cfg.tie_embeddings:
        d["unembed"] = ParamDef((cfg.d_model, cfg.vocab_size), (NULL, TP))
    return d


def embed_tokens(cfg: ModelConfig, p: Mapping, tokens: jax.Array) -> jax.Array:
    x = p["embedding"][tokens]
    return x.astype(cfg.compute_dtype)


def unembed_weight(cfg: ModelConfig, p: Mapping) -> jax.Array:
    if cfg.tie_embeddings:
        return p["embedding"].T
    return p["unembed"]
