"""GQA attention: train / prefill / decode, KV cache (bf16 or int8).

Memory discipline: training/prefill attention is *query-chunked* (lax.scan
over query blocks) so the live score tensor is (B, KV, G, Cq, T) instead of
(B, H, S, S) — this is what makes 32k prefill lower/compile within per-device
HBM. The Pallas flash-attention kernel (kernels/flash_attention) is the TPU
execution path; the jnp path here is the oracle and the CPU dry-run path.
"""
from __future__ import annotations

from typing import Mapping, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import EMBED, NULL, TP, ModelConfig, ParamDef
from repro.models.quant import dequantize_kv, qeinsum, quantize_kv
from repro.models.rotary import apply_mrope, apply_rope

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attention_defs(cfg: ModelConfig, n_heads: int = 0, cross: bool = False) -> dict:
    H = n_heads or cfg.n_heads
    KV = H if cross else min(cfg.n_kv_heads, H)
    hd = cfg.hd
    d = cfg.d_model
    defs = {
        "wq": ParamDef((d, H * hd), (NULL, TP)),
        "wk": ParamDef((d, KV * hd), (NULL, TP)),
        "wv": ParamDef((d, KV * hd), (NULL, TP)),
        "wo": ParamDef((H * hd, d), (TP, NULL)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H * hd,), (TP,), "zeros")
        defs["bk"] = ParamDef((KV * hd,), (TP,), "zeros")
        defs["bv"] = ParamDef((KV * hd,), (TP,), "zeros")
    return defs


def kv_cache_defs(
    cfg: ModelConfig, batch: int, cap: int, n_heads: int = 0
) -> dict:
    """ShapeDtypeStructs for one attention layer's KV cache."""
    H = n_heads or cfg.n_heads
    KV = min(cfg.n_kv_heads, H)
    hd = cfg.hd
    if cfg.kv_quant:
        return {
            "k": jax.ShapeDtypeStruct((batch, cap, KV, hd), jnp.int8),
            "v": jax.ShapeDtypeStruct((batch, cap, KV, hd), jnp.int8),
            "k_scale": jax.ShapeDtypeStruct((batch, cap, KV, 1), jnp.bfloat16),
            "v_scale": jax.ShapeDtypeStruct((batch, cap, KV, 1), jnp.bfloat16),
        }
    return {
        "k": jax.ShapeDtypeStruct((batch, cap, KV, hd), cfg.kv_dtype),
        "v": jax.ShapeDtypeStruct((batch, cap, KV, hd), cfg.kv_dtype),
    }


def init_kv_cache(cfg: ModelConfig, batch: int, cap: int, n_heads: int = 0) -> dict:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), kv_cache_defs(cfg, batch, cap, n_heads))


# ---------------------------------------------------------------------------
# Paged KV cache (vLLM-style page pool + block tables; serving/paging.py
# owns the host-side allocator, this is the device layout + access path)
# ---------------------------------------------------------------------------


class PagedIndex(NamedTuple):
    """Decode-time cache address for the paged path.

    lengths: (B,) int32 — tokens already in cache per slot (write position).
    block_tab: (B, P) int32 — physical page per logical block; unused
    entries point at the reserved null page 0. With ``l2`` set (chained
    two-level tables), block_tab is instead the (B, W1) first-level row of
    *table-page* ids and l2 is the (n_rows, tpp) pool of second-level rows:
    logical block i resolves to ``l2[block_tab[b, i // tpp], i % tpp]``.
    """

    lengths: jax.Array
    block_tab: jax.Array
    l2: Optional[jax.Array] = None


class PagedPrefillIndex(NamedTuple):
    """Prefill-time cache address for the paged path (one sequence).

    tab_row: (P,) int32 — the sequence's block-table row; token t scatters to
    (tab_row[t // ps], t % ps). Bucket padding beyond the allocated pages
    maps to the reserved null page 0 (harmless by construction).
    slot: scalar int32 — decode-batch slot owning the recurrent (SSM) state.
    """

    tab_row: jax.Array
    slot: jax.Array


class ChunkPrefillIndex(NamedTuple):
    """Chunked (resumable) dense prefill of one slot's cache stripe.

    offset: scalar int32 — tokens already in cache when this chunk starts;
    chunk token t lives at absolute position offset + t. The chunk's K/V is
    written at ``offset`` and its queries attend causally over the WHOLE
    stripe by absolute position, so positions written by earlier chunks stay
    visible while unwritten/stale positions (> offset + t) are masked out.
    Recurrent-mixer state does NOT live in the cache mid-prefill — it rides
    the explicit ``chunk_state`` carry (see transformer.forward) so decode
    steps batched between chunks cannot corrupt it.
    """

    offset: jax.Array


class PagedChunkPrefillIndex(NamedTuple):
    """Chunked (resumable) paged prefill of one sequence.

    tab_row: (P,) int32 — the sequence's full block-table row.
    slot: scalar int32 — decode-batch slot (recurrent-state install target).
    offset: scalar int32 — page-multiple chunk start; the chunk's K/V
    scatters through the row shifted by offset // ps pages (tail overruns
    land on the null page), and its queries attend over the dense gathered
    context view masked by absolute position.
    """

    tab_row: jax.Array
    slot: jax.Array
    offset: jax.Array


class PagedVerifyIndex(NamedTuple):
    """Speculative-decode verify pass over one paged sequence.

    tab_row: (P,) int32 — the sequence's full block-table row.
    offset: scalar int32 — tokens already in cache; verify token t (the
    pending last token plus k proposal tokens) scatters to absolute position
    offset + t through the row at an ARBITRARY offset (per-token page
    indexing — unlike chunk offsets, verify starts mid-page), and the
    queries attend over the dense gathered context view masked by absolute
    position, exactly like a chunked-prefill chunk. Rejected speculative
    positions (> the accepted run) stay in the pool as stale garbage — the
    engine rolls its write-head back and absolute-position masks plus
    overwrite-on-next-write keep them invisible.
    """

    tab_row: jax.Array
    offset: jax.Array


def paged_kv_pool_defs(cfg: ModelConfig, num_pages: int, page_size: int, n_heads: int = 0) -> dict:
    """ShapeDtypeStructs for one attention layer's shared page pool.

    With ``cfg.kv_quant`` the pool stores int8 values plus per-(page-slot,
    head) bf16 scales — ``models/quant.py``'s KV idiom with the token axis
    living inside the page. Every access path dispatches on the presence of
    the ``k_scale`` leaf."""
    H = n_heads or cfg.n_heads
    KV = min(cfg.n_kv_heads, H)
    shape = (num_pages, KV, page_size, cfg.hd)
    if cfg.kv_quant:
        sshape = (num_pages, KV, page_size, 1)
        return {
            "k": jax.ShapeDtypeStruct(shape, jnp.int8),
            "v": jax.ShapeDtypeStruct(shape, jnp.int8),
            "k_scale": jax.ShapeDtypeStruct(sshape, jnp.bfloat16),
            "v_scale": jax.ShapeDtypeStruct(sshape, jnp.bfloat16),
        }
    return {
        "k": jax.ShapeDtypeStruct(shape, cfg.kv_dtype),
        "v": jax.ShapeDtypeStruct(shape, cfg.kv_dtype),
    }


def paged_cache_kv(cfg: ModelConfig, cache: Mapping, k: jax.Array, v: jax.Array, idx: PagedIndex) -> dict:
    """Scatter one new token's K/V (B, 1, KV, hd) into the page pool at each
    slot's (page, offset). Dead slots (length 0, null block table) scatter
    into the reserved null page — harmless by construction. With chained
    tables (``idx.l2``) the logical page index resolves through the
    second-level pool; with a quantized pool the token is quantized here and
    its scales land in the scale pools through the same indices."""
    ps = cache["k"].shape[2]
    KV = cache["k"].shape[1]
    lp = idx.lengths // ps                                   # logical page index
    if idx.l2 is not None:
        tpp = idx.l2.shape[1]
        l1e = jnp.take_along_axis(idx.block_tab, (lp // tpp)[:, None], axis=1)[:, 0]
        pages = idx.l2[l1e, lp % tpp]
    else:
        pages = jnp.take_along_axis(idx.block_tab, lp[:, None], axis=1)[:, 0]
    offs = idx.lengths % ps
    kvh = jnp.arange(KV)
    at = (pages[:, None], kvh[None, :], offs[:, None])
    out = dict(cache)
    if "k_scale" in cache:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        out["k"] = cache["k"].at[at].set(kq[:, 0])
        out["v"] = cache["v"].at[at].set(vq[:, 0])
        out["k_scale"] = cache["k_scale"].at[at].set(ks[:, 0].astype(cache["k_scale"].dtype))
        out["v_scale"] = cache["v_scale"].at[at].set(vs[:, 0].astype(cache["v_scale"].dtype))
    else:
        out["k"] = cache["k"].at[at].set(k[:, 0].astype(cache["k"].dtype))
        out["v"] = cache["v"].at[at].set(v[:, 0].astype(cache["v"].dtype))
    return out


def paged_write_prompt(
    cfg: ModelConfig, cache: Mapping, k: jax.Array, v: jax.Array, tab_row: jax.Array,
    offset=None,
) -> dict:
    """Write a whole prefilled prompt — or, with ``offset``, one prompt
    chunk — (1, Lp, KV, hd) through one sequence's block-table row (P,) into
    the pool; chunk token t -> absolute position offset + t (offset is a
    page multiple; tail-chunk padding past the table lands on the null
    page). The scatter itself lives with the paged kernels (the decode
    gather's write-side twin): a Pallas kernel on the TPU path, the jnp ref
    oracle otherwise."""
    from repro.kernels.paged_attention import ops as pa_ops

    out = dict(cache)
    if "k_scale" in cache:
        out["k"], out["v"], out["k_scale"], out["v_scale"] = pa_ops.paged_prefill_write_quant(
            cache["k"], cache["v"], cache["k_scale"], cache["v_scale"],
            k, v, tab_row, use_pallas=cfg.use_pallas, offset=offset,
        )
    else:
        out["k"], out["v"] = pa_ops.paged_prefill_write(
            cache["k"], cache["v"], k, v, tab_row, use_pallas=cfg.use_pallas, offset=offset
        )
    return out


# ---------------------------------------------------------------------------
# int8 KV quantization — the shared idiom lives in models/quant.py (the paged
# pool's write kernels and jnp oracles import it from there too, which is
# what keeps every storage path bit-identical on the int8 tensors).
# ---------------------------------------------------------------------------


def _dus(buf: jax.Array, upd: jax.Array, index) -> jax.Array:
    """Write upd (B,S,...) into buf (B,T,...) at seq position index. index may
    be a scalar or per-batch (B,) — the latter vmaps (continuous batching:
    every slot has its own length)."""
    idx = jnp.asarray(index)
    if idx.ndim == 1:
        return jax.vmap(
            lambda b, u, i: jax.lax.dynamic_update_slice_in_dim(b, u, i, axis=0)
        )(buf, upd, idx)
    return jax.lax.dynamic_update_slice_in_dim(buf, upd, index, axis=1)


def cache_kv(cfg: ModelConfig, cache: Mapping, k: jax.Array, v: jax.Array, index) -> dict:
    """Write k/v (B, S_new, KV, hd) into cache at position ``index``."""
    out = dict(cache)
    if cfg.kv_quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        out["k"] = _dus(cache["k"], kq, index)
        out["v"] = _dus(cache["v"], vq, index)
        out["k_scale"] = _dus(cache["k_scale"], ks, index)
        out["v_scale"] = _dus(cache["v_scale"], vs, index)
    else:
        out["k"] = _dus(cache["k"], k.astype(cache["k"].dtype), index)
        out["v"] = _dus(cache["v"], v.astype(cache["v"].dtype), index)
    return out


def read_kv(cfg: ModelConfig, cache: Mapping, dtype):
    if cfg.kv_quant:
        return (
            dequantize_kv(cache["k"], cache["k_scale"], dtype),
            dequantize_kv(cache["v"], cache["v_scale"], dtype),
        )
    return cache["k"].astype(dtype), cache["v"].astype(dtype)


# ---------------------------------------------------------------------------
# Core attention math (grouped-query, fp32 softmax)
# ---------------------------------------------------------------------------


def _group(q: jax.Array, kv_heads: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    B, S, H, hd = q.shape
    return q.reshape(B, S, kv_heads, H // kv_heads, hd)


def _attend_block(q, k, v, mask, softcap: float = 0.0, scores_bf16: bool = False):
    """q: (B,Cq,KV,G,hd); k/v: (B,T,KV,hd); mask: (B,1,1,Cq,T) bool.
    scores_bf16 halves the materialized score traffic (row stats stay f32)."""
    hd = q.shape[-1]
    sdt = jnp.bfloat16 if scores_bf16 else jnp.float32
    scale = jnp.asarray(1.0 / (hd ** 0.5), sdt)
    s = jnp.einsum("bqkgh,btkh->bkgqt", q, k, preferred_element_type=sdt)
    s = s * scale
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    s = jnp.where(mask, s, jnp.asarray(NEG_INF, sdt))
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp((s - m).astype(jnp.float32))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(q.dtype), v)
    return o


def chunked_attention(
    cfg: ModelConfig,
    q: jax.Array,           # (B, S, H, hd)
    k: jax.Array,           # (B, T, KV, hd)
    v: jax.Array,
    pos_q: jax.Array,       # (B, S) int32
    pos_k: jax.Array,       # (B, T) int32
    causal: bool = True,
    allow_kernel: bool = True,
) -> jax.Array:
    """Query-chunked attention; returns (B, S, H, hd). ``allow_kernel=False``
    forces the jnp path — the flash kernel assumes square causal q/k of equal
    length, which the chunked-prefill context attention (short q over a long
    cached prefix at an absolute-position offset) violates."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if cfg.use_pallas and causal and S > 1 and allow_kernel and S == k.shape[1]:
        from repro.kernels.flash_attention import ops as fa_ops

        return fa_ops.flash_attention(q, k, v, pos_q, pos_k)
    qg = _group(q, KV)
    chunk = min(cfg.attn_chunk, S)
    if S % chunk != 0:
        chunk = S  # irregular small shapes: single block
    nc = S // chunk
    if nc == 1:
        mask = (pos_q[:, None, None, :, None] >= pos_k[:, None, None, None, :]) if causal else jnp.ones((B, 1, 1, S, k.shape[1]), bool)
        o = _attend_block(qg, k, v, mask, cfg.logit_softcap, cfg.attn_scores_bf16)
        return o.reshape(B, S, H, hd)

    qc = qg.reshape(B, nc, chunk, KV, H // KV, hd).transpose(1, 0, 2, 3, 4, 5)
    pc = pos_q.reshape(B, nc, chunk).transpose(1, 0, 2)

    @jax.checkpoint  # flash-attention-style: recompute scores/probs in bwd —
    # without this the scan stacks per-chunk probs (O(S^2) live residuals)
    def body(_, qp):
        qb, pb = qp
        if causal:
            mask = pb[:, None, None, :, None] >= pos_k[:, None, None, None, :]
        else:
            mask = jnp.ones((B, 1, 1, chunk, k.shape[1]), bool)
        return None, _attend_block(qb, k, v, mask, cfg.logit_softcap, cfg.attn_scores_bf16)

    _, o = jax.lax.scan(body, None, (qc, pc))
    o = o.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H, hd)
    return o


def context_attention(
    cfg: ModelConfig,
    q: jax.Array,           # (B, Cq, H, hd) — one prefill chunk's queries
    k: jax.Array,           # (B, T, KV, hd) — the full cached context view
    v: jax.Array,
    pos_q: jax.Array,       # (B, Cq) absolute positions (offset + arange)
) -> jax.Array:
    """Chunked-prefill attention: a chunk of queries over the whole cached
    context (earlier chunks + this chunk, freshly written), masked causally
    by ABSOLUTE position — key t is visible to query at position p iff
    t <= p, which simultaneously exposes the valid prefix, enforces
    causality inside the chunk, and hides unwritten/stale cache positions
    and tail-chunk bucket padding (all strictly in the future)."""
    B, T = k.shape[0], k.shape[1]
    pos_k = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None, :], (B, T))
    return chunked_attention(cfg, q, k, v, pos_q, pos_k, causal=True, allow_kernel=False)


def decode_attention_quant(cfg: ModelConfig, q: jax.Array, cache: Mapping, cache_len) -> jax.Array:
    """int8-KV decode without materializing a dequantized cache: the per
    (token, head) scales fold into the score matrix (k) and the probability
    matrix (v), so the int8 tensors feed the dots directly (mixed-dtype dot;
    converts fuse into the MXU pass on TPU)."""
    B, S, H, hd = q.shape
    KV = cache["k"].shape[2]
    qg = _group(q, KV)                                       # (B,S,KV,G,hd)
    T = cache["k"].shape[1]
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,btkh->bkgqt", qg, cache["k"], preferred_element_type=jnp.float32)
    k_sc = cache["k_scale"].astype(jnp.float32)[..., 0]      # (B,T,KV)
    s = s * scale * k_sc.transpose(0, 2, 1)[:, :, None, None, :]
    cl = jnp.asarray(cache_len)
    cl = cl.reshape(-1, 1, 1, 1, 1) if cl.ndim == 1 else cl
    mask = (jnp.arange(T)[None, None, None, None, :] < cl) & jnp.ones((B, 1, 1, S, 1), bool)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    v_sc = cache["v_scale"].astype(jnp.float32)[..., 0]
    p = p * v_sc.transpose(0, 2, 1)[:, :, None, None, :]
    o = jnp.einsum("bkgqt,btkh->bqkgh", p.astype(jnp.bfloat16), cache["v"], preferred_element_type=jnp.float32)
    return o.astype(q.dtype).reshape(B, S, H, hd)


def decode_attention(
    cfg: ModelConfig,
    q: jax.Array,           # (B, 1, H, hd)
    k: jax.Array,           # (B, T, KV, hd)  (cache contents, incl. new token)
    v: jax.Array,
    cache_len,              # scalar: valid prefix length
) -> jax.Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    if cfg.use_pallas:
        from repro.kernels.decode_attention import ops as da_ops

        return da_ops.decode_attention(q, k, v, cache_len)
    qg = _group(q, KV)
    T = k.shape[1]
    cl = jnp.asarray(cache_len)
    cl = cl.reshape(-1, 1, 1, 1, 1) if cl.ndim == 1 else cl  # per-slot lengths
    mask = (jnp.arange(T)[None, None, None, None, :] < cl) & jnp.ones((B, 1, 1, S, 1), bool)
    o = _attend_block(qg, k, v, mask, cfg.logit_softcap, cfg.attn_scores_bf16)
    return o.reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# Full attention sub-layer (projection + rope + attend + out-projection)
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, p: Mapping, x: jax.Array, n_heads: int):
    B, S, _ = x.shape
    H = n_heads
    KV = min(cfg.n_kv_heads, H)
    hd = cfg.hd
    q = qeinsum("bsd,dh->bsh", x, p["wq"])
    k = qeinsum("bsd,dh->bsh", x, p["wk"])
    v = qeinsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    return (
        q.reshape(B, S, H, hd),
        k.reshape(B, S, KV, hd),
        v.reshape(B, S, KV, hd),
    )


def _apply_pos(cfg: ModelConfig, x: jax.Array, positions) -> jax.Array:
    if cfg.pos == "rope":
        return apply_rope(x, positions, cfg.rope_theta)
    if cfg.pos == "mrope":
        return apply_mrope(x, positions, cfg.rope_theta, cfg.mrope_sections)
    return x


def self_attention(
    cfg: ModelConfig,
    p: Mapping,
    x: jax.Array,
    positions: jax.Array,
    mode: str,                      # train | prefill | decode
    cache: Optional[Mapping] = None,
    cache_index=None,               # scalar write offset for decode/prefill
    causal: bool = True,
    n_heads: int = 0,
):
    """Returns (out, new_cache)."""
    H = n_heads or cfg.n_heads
    B, S, _ = x.shape
    q, k, v = _project_qkv(cfg, p, x, H)
    pos_t = positions[0] if cfg.pos == "mrope" else positions  # temporal stream for masks
    q = _apply_pos(cfg, q, positions)
    k = _apply_pos(cfg, k, positions)

    new_cache = cache
    if mode == "train":
        o = chunked_attention(cfg, q, k, v, pos_t, pos_t, causal=causal)
    elif mode == "prefill" and isinstance(cache_index, PagedPrefillIndex):
        # truly paged prefill: K/V scatter straight through the block table
        # into the page pool — no dense per-length staging cache exists.
        assert cache is not None
        new_cache = paged_write_prompt(cfg, cache, k, v, cache_index.tab_row)
        o = chunked_attention(cfg, q, k, v, pos_t, pos_t, causal=causal)
    elif mode == "prefill" and isinstance(cache_index, PagedChunkPrefillIndex):
        # chunked paged prefill: scatter this chunk at its page-aligned
        # offset, then attend over the dense gathered context view (fixed
        # table_width * ps shape — compilation stays offset-independent).
        from repro.kernels.paged_attention import ops as pa_ops

        assert cache is not None
        new_cache = paged_write_prompt(
            cfg, cache, k, v, cache_index.tab_row, offset=cache_index.offset
        )
        ck, cv = pa_ops.paged_gather_context(
            new_cache["k"], new_cache["v"], cache_index.tab_row,
            pool_ks=new_cache.get("k_scale"), pool_vs=new_cache.get("v_scale"),
        )
        o = context_attention(cfg, q, ck.astype(x.dtype), cv.astype(x.dtype), pos_t)
    elif mode == "prefill" and isinstance(cache_index, PagedVerifyIndex):
        # speculative verify: scatter the k+1 verify tokens' K/V at an
        # arbitrary (mid-page) offset, then attend over the gathered context
        # view — same absolute-position masking as a prefill chunk, so every
        # verify position sees exactly the prefix + its own causal slice.
        from repro.kernels.paged_attention import ops as pa_ops

        assert cache is not None
        new_cache = dict(cache)
        if "k_scale" in cache:
            (
                new_cache["k"], new_cache["v"],
                new_cache["k_scale"], new_cache["v_scale"],
            ) = pa_ops.paged_verify_write_quant(
                cache["k"], cache["v"], cache["k_scale"], cache["v_scale"],
                k, v, cache_index.tab_row, cache_index.offset,
            )
        else:
            new_cache["k"], new_cache["v"] = pa_ops.paged_verify_write(
                cache["k"], cache["v"], k, v, cache_index.tab_row, cache_index.offset
            )
        ck, cv = pa_ops.paged_gather_context(
            new_cache["k"], new_cache["v"], cache_index.tab_row,
            pool_ks=new_cache.get("k_scale"), pool_vs=new_cache.get("v_scale"),
        )
        o = context_attention(cfg, q, ck.astype(x.dtype), cv.astype(x.dtype), pos_t)
    elif mode == "prefill" and isinstance(cache_index, ChunkPrefillIndex):
        # chunked dense prefill: write this chunk into the slot's stripe at
        # ``offset`` and attend over the whole stripe by absolute position.
        assert cache is not None
        new_cache = cache_kv(cfg, cache, k, v, cache_index.offset)
        ck, cv = read_kv(cfg, new_cache, x.dtype)
        o = context_attention(cfg, q, ck, cv, pos_t)
    elif mode == "prefill":
        assert cache is not None
        new_cache = cache_kv(cfg, cache, k, v, 0 if cache_index is None else cache_index)
        o = chunked_attention(cfg, q, k, v, pos_t, pos_t, causal=causal)
    elif mode == "decode" and isinstance(cache_index, PagedIndex):
        assert cache is not None and S == 1
        new_cache = paged_cache_kv(cfg, cache, k, v, cache_index)
        from repro.kernels.paged_attention import ops as pa_ops

        o = pa_ops.paged_attention(
            q, new_cache["k"], new_cache["v"],
            cache_index.block_tab, cache_index.lengths + 1,
            use_pallas=cfg.use_pallas,
            softcap=cfg.logit_softcap,
            pool_ks=new_cache.get("k_scale"),
            pool_vs=new_cache.get("v_scale"),
            l2_tab=cache_index.l2,
        )
    elif mode == "decode":
        assert cache is not None and cache_index is not None
        new_cache = cache_kv(cfg, cache, k, v, cache_index)
        if cfg.kv_quant:
            o = decode_attention_quant(cfg, q, new_cache, cache_len=cache_index + S)
        else:
            ck, cv = read_kv(cfg, new_cache, x.dtype)
            o = decode_attention(cfg, q, ck, cv, cache_len=cache_index + S)
    else:
        raise ValueError(mode)

    out = qeinsum("bsh,he->bse", o.reshape(B, S, H * cfg.hd), p["wo"])
    return out, new_cache


def cross_attention(
    cfg: ModelConfig,
    p: Mapping,
    x: jax.Array,
    kv_cache: Mapping,       # precomputed {"k": (B,T,H,hd), "v": ...} from encoder
):
    """Enc-dec cross attention; KV computed once from encoder output."""
    B, S, _ = x.shape
    H = cfg.n_heads
    hd = cfg.hd
    q = qeinsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    k = kv_cache["k"].astype(x.dtype)
    v = kv_cache["v"].astype(x.dtype)
    T = k.shape[1]
    pos_q = jnp.zeros((B, S), jnp.int32)
    pos_k = jnp.zeros((B, T), jnp.int32)
    o = chunked_attention(cfg, q, k, v, pos_q, pos_k, causal=False)
    return qeinsum("bsh,he->bse", o.reshape(B, S, H * hd), p["wo"])


def cross_kv(cfg: ModelConfig, p: Mapping, enc_out: jax.Array):
    """Project encoder output to cross-attention K/V once (prefill)."""
    B, T, _ = enc_out.shape
    H = cfg.n_heads
    hd = cfg.hd
    k = qeinsum("btd,dh->bth", enc_out, p["wk"]).reshape(B, T, H, hd)
    v = qeinsum("btd,dh->bth", enc_out, p["wv"]).reshape(B, T, H, hd)
    return {"k": k, "v": v}
