"""Whisper-style encoder-decoder backbone.

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` supplies precomputed frame embeddings
(B, n_ctx=1500, d). The encoder is a bidirectional transformer; the decoder
is a causal stack with cross-attention (cross K/V cached at prefill).

Adaptation note (DESIGN.md): the decoder uses RoPE instead of Whisper's
learned 448-position table so that the assigned decode_32k shape is
well-defined; everything else follows the published architecture
(layernorm, GELU MLP, MHA).
"""
from __future__ import annotations

from typing import Mapping

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.common import ModelConfig, apply_norm, norm_defs, stack_defs
from repro.models.transformer import run_stack, superblock_defs


def encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    e = cfg.encoder
    return cfg.replace(
        n_layers=e.n_layers,
        n_heads=e.n_heads,
        n_kv_heads=e.n_heads,
        d_ff=e.d_ff,
        cross_attn=False,
        pos="none",              # positions baked into the stub frame embeddings
        block_pattern=("attn",),
        moe=None,
    )


def param_defs(cfg: ModelConfig) -> dict:
    ecfg = encoder_cfg(cfg)
    return {
        "decoder": tf.param_defs(cfg),
        "encoder": {
            "blocks": stack_defs(superblock_defs(ecfg), ecfg.n_superblocks),
            "final_norm": norm_defs(ecfg),
        },
    }


def encode(cfg: ModelConfig, ctx, params: Mapping, frames: jax.Array) -> jax.Array:
    ecfg = encoder_cfg(cfg)
    B, T, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    x = frames.astype(ecfg.compute_dtype)
    x, _, _, _ = run_stack(
        ecfg, ctx, params["encoder"]["blocks"], x, pos,
        "train", cache=None, causal=False,
    )
    return apply_norm(ecfg, params["encoder"]["final_norm"], x)


def forward(
    cfg: ModelConfig,
    ctx,
    params: Mapping,
    frames: jax.Array = None,
    tokens: jax.Array = None,
    positions=None,
    mode: str = "train",
    cache=None,
    cache_index=None,
    enc_out=None,
    n_valid=None,
):
    """Returns (decoder hidden, new_cache, aux). Encoder runs in train/prefill.
    ``n_valid`` marks right-padded decoder prefill (cross-attention K/V come
    from the encoder, so only the causal decoder stack needs the mask)."""
    if mode in ("train", "prefill"):
        enc_out = encode(cfg, ctx, params, frames)
    return tf.forward(
        cfg, ctx, params["decoder"], tokens=tokens, positions=positions,
        mode=mode, cache=cache, cache_index=cache_index, enc_out=enc_out,
        n_valid=n_valid,
    )
