"""Qwen2-VL backbone helpers.

The vision frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed inputs_embeds (patch embeddings already merged with token
embeddings). This module supplies M-RoPE position-id construction for
image-bearing sequences, used by examples and tests; the backbone itself is
``transformer.forward`` with ``inputs='embeds'`` and ``pos='mrope'``.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def mrope_positions_with_image(
    batch: int, seq: int, image_start: int, grid_h: int, grid_w: int
) -> jnp.ndarray:
    """(3, B, S) positions: text ranks advance temporally; the image span gets
    a constant temporal index with spatial (h, w) coordinates — Qwen2-VL §2.1."""
    n_img = grid_h * grid_w
    assert image_start + n_img <= seq
    t = np.zeros(seq, np.int32)
    h = np.zeros(seq, np.int32)
    w = np.zeros(seq, np.int32)
    # leading text
    t[:image_start] = np.arange(image_start)
    h[:image_start] = np.arange(image_start)
    w[:image_start] = np.arange(image_start)
    # image block: constant t, spatial h/w
    t[image_start : image_start + n_img] = image_start
    hh, ww = np.meshgrid(np.arange(grid_h), np.arange(grid_w), indexing="ij")
    h[image_start : image_start + n_img] = image_start + hh.reshape(-1)
    w[image_start : image_start + n_img] = image_start + ww.reshape(-1)
    # trailing text resumes after max position so far
    nxt = image_start + max(grid_h, grid_w)
    tail = seq - image_start - n_img
    if tail > 0:
        r = np.arange(tail)
        for arr in (t, h, w):
            arr[image_start + n_img :] = nxt + r
    pos = np.stack([t, h, w])  # (3, S)
    return jnp.asarray(np.broadcast_to(pos[:, None, :], (3, batch, seq)))
