"""Weight-only int8 quantization for serving (per-output-channel scales).

A quantized weight is a dict {"q": int8 W, "s": scales} where the scale
tensor is W's shape with the contracting axis (ndim-2 for every dense weight
in this codebase: x @ W layouts) reduced to 1. ``qeinsum`` computes the dot
on the int8 tensor directly (mixed-dtype dot — the dequant fuses into the
MXU read on TPU) and applies scales on the output, so HBM traffic for
weights halves vs bf16. Accuracy: per-channel absmax keeps relative error
~0.4% — greedy decode parity is tested.
"""
from __future__ import annotations

from typing import Any, Mapping, Union

import jax
import jax.numpy as jnp

QuantW = Mapping  # {"q": int8, "s": float}

# leaf names eligible for weight-only quantization (attention / MLP / MoE /
# unembed — embedding gathers and 1D params stay fp)
QUANT_LEAVES = {"wq", "wk", "wv", "wo", "w1", "w2", "w3", "unembed"}


def quantize_weight(w: jax.Array) -> dict:
    axis = w.ndim - 2
    scale = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=axis, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(w.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale.astype(jnp.bfloat16)}


def is_quant(w: Any) -> bool:
    return isinstance(w, Mapping) and "q" in w and "s" in w


# ---------------------------------------------------------------------------
# KV-cache quantization: the weight idiom extended to activations. One scale
# per (token, head-group) — the head dim is the reduced axis, so dequant is a
# rank-1 broadcast and the scale tensor is hd x smaller than the cache.
# Shared by the dense int8 cache (models/attention.py) and the paged pool
# legs (kernels/paged_attention), so every storage path quantizes
# bit-identically and kernel-vs-ref parity is exact on the int8 tensors.
# ---------------------------------------------------------------------------


def quantize_kv(x: jax.Array):
    """Per (token, head) absmax int8. x: (..., hd) — typically (B, T, KV, hd).
    Returns (int8 values, bf16 scales with the trailing axis reduced to 1)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def qeinsum(pattern: str, x: jax.Array, w: Union[jax.Array, QuantW]) -> jax.Array:
    """einsum where w may be a quantized dict; output dtype follows x."""
    if not is_quant(w):
        return jnp.einsum(pattern, x, w.astype(x.dtype))
    y = jnp.einsum(pattern, x, w["q"], preferred_element_type=jnp.float32)
    # scale shape = w.shape with the contracting axis (ndim-2) at 1; output
    # trailing dims line up with w's non-contracted dims in every pattern
    # used in this codebase ("...d,df->...f", "ecd,edf->ecf", ...).
    s = w["s"].astype(jnp.float32)
    s = jnp.squeeze(s, axis=s.ndim - 2) if s.ndim == 2 else s
    return (y * s).astype(x.dtype)


def quantize_params(params: Any) -> Any:
    """Replace eligible 2D/3D weight leaves with quantized dicts (by key)."""

    def walk(node):
        if isinstance(node, Mapping):
            out = {}
            for k, v in node.items():
                if (
                    k in QUANT_LEAVES
                    and hasattr(v, "ndim")
                    and v.ndim >= 2
                    and v.dtype in (jnp.bfloat16, jnp.float32, jnp.float16)
                ):
                    out[k] = quantize_weight(v)
                else:
                    out[k] = walk(v)
            return out
        return node

    return walk(params)


def quantized_shape_tree(shapes: Any) -> Any:
    """ShapeDtypeStruct tree matching quantize_params (dry-run lowering)."""

    def walk(node):
        if isinstance(node, Mapping):
            out = {}
            for k, v in node.items():
                if k in QUANT_LEAVES and hasattr(v, "shape") and len(v.shape) >= 2:
                    sshape = list(v.shape)
                    sshape[-2] = 1
                    out[k] = {
                        "q": jax.ShapeDtypeStruct(v.shape, jnp.int8),
                        "s": jax.ShapeDtypeStruct(tuple(sshape), jnp.bfloat16),
                    }
                else:
                    out[k] = walk(v)
            return out
        return node

    return walk(shapes)


def quantized_sharding_tree(shardings: Any, shapes: Any) -> Any:
    """Sharding tree matching quantize_params: q keeps the weight's spec; the
    scale drops the (now size-1) contracting-axis sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    def walk(sh_node, shp_node):
        if isinstance(shp_node, Mapping):
            out = {}
            for k, v in shp_node.items():
                sh = sh_node[k] if isinstance(sh_node, Mapping) else sh_node
                if k in QUANT_LEAVES and hasattr(v, "shape") and len(v.shape) >= 2:
                    if sh is None:
                        out[k] = {"q": None, "s": None}
                    else:
                        spec = list(sh.spec) + [None] * (len(v.shape) - len(sh.spec))
                        s_spec = list(spec)
                        s_spec[-2] = None
                        out[k] = {
                            "q": sh,
                            "s": NamedSharding(sh.mesh, P(*s_spec)),
                        }
                else:
                    out[k] = walk(sh, v)
            return out
        return sh_node

    return walk(shardings, shapes)
