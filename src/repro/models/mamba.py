"""Mamba (S6) selective-state-space block — chunked parallel scan.

TPU adaptation (DESIGN.md §2): the CUDA selective-scan kernel fuses the
(B, S, d_inner, d_state) discretized tensors in SRAM; on TPU we instead
*chunk* the sequence (outer lax.scan carrying h) and run an associative scan
within each chunk, so the materialized working set is
(B, chunk, d_inner/TP, d_state) — sized for VMEM-friendly tiles and sharded
over the 'model' axis on d_inner (all per-channel ops are elementwise there).

Decode is the exact single-step recurrence on (conv_state, ssm_state).
"""
from __future__ import annotations

import math
from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import NULL, TP, ModelConfig, ParamDef


def _dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or math.ceil(cfg.d_model / 16)
    return d_inner, dt_rank, m.d_state


def mamba_defs(cfg: ModelConfig) -> dict:
    m = cfg.mamba
    d = cfg.d_model
    dI, dtR, dS = _dims(cfg)
    return {
        "in_proj": ParamDef((d, 2 * dI), (NULL, TP)),
        "conv_w": ParamDef((m.d_conv, dI), (NULL, TP), scale=0.5),
        "conv_b": ParamDef((dI,), (TP,), "zeros"),
        "x_proj": ParamDef((dI, dtR + 2 * dS), (TP, NULL)),
        "dt_proj": ParamDef((dtR, dI), (NULL, TP)),
        "dt_bias": ParamDef((dI,), (TP,), "zeros"),
        "A_log": ParamDef((dI, dS), (TP, NULL), "zeros"),   # A = -exp(A_log) ~ -1
        "D": ParamDef((dI,), (TP,), "ones"),
        "out_proj": ParamDef((dI, d), (TP, NULL)),
    }


def mamba_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    m = cfg.mamba
    dI, _, dS = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, m.d_conv - 1, dI), cfg.compute_dtype),
        "ssm": jax.ShapeDtypeStruct((batch, dI, dS), jnp.float32),
    }


def conv_state_at(xp: jax.Array, n_valid: jax.Array, K: int) -> jax.Array:
    """Rolling conv state as of the last *valid* token of a right-padded
    sequence. xp is the state-prepended input (B, S+K-1, dI), so the K-1
    inputs ending at token n_valid-1 live at xp[:, n_valid : n_valid+K-1]."""
    return jax.vmap(
        lambda row, s: jax.lax.dynamic_slice_in_dim(row, s, K - 1, axis=0)
    )(xp, jnp.asarray(n_valid, jnp.int32))


def _causal_conv(
    cfg: ModelConfig, p: Mapping, x: jax.Array, state: Optional[jax.Array], n_valid=None
):
    """Depthwise causal conv1d. x: (B, S, dI); state: (B, K-1, dI) or None.
    Returns (out (B,S,dI), new_state (B,K-1,dI)). ``n_valid`` (B,) makes the
    carried state reflect the last valid token instead of trailing padding."""
    B, S, dI = x.shape
    K = cfg.mamba.d_conv
    if state is None:
        state = jnp.zeros((B, K - 1, dI), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+K-1, dI)
    out = jnp.zeros((B, S, dI), x.dtype)
    w = p["conv_w"].astype(x.dtype)
    for k in range(K):
        out = out + xp[:, k : k + S, :] * w[k]
    out = out + p["conv_b"].astype(x.dtype)
    if K <= 1:
        new_state = state
    elif n_valid is None:
        new_state = xp[:, S:, :]
    else:
        new_state = conv_state_at(xp, n_valid, K)
    return out, new_state


def _ssm_inputs(cfg: ModelConfig, p: Mapping, xc: jax.Array):
    """xc: conv+silu output (B,S,dI) -> dt (B,S,dI), Bc/Cc (B,S,dS), A (dI,dS)."""
    dI, dtR, dS = _dims(cfg)
    proj = jnp.einsum("bsd,dr->bsr", xc, p["x_proj"].astype(xc.dtype))
    dt_r, Bc, Cc = jnp.split(proj, [dtR, dtR + dS], axis=-1)
    dt = jnp.einsum("bsr,rd->bsd", dt_r, p["dt_proj"].astype(xc.dtype))
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (dI, dS)
    return dt, Bc.astype(jnp.float32), Cc.astype(jnp.float32), A


def _chunk_scan(dt, Bc, Cc, A, xc, h0):
    """One chunk of the selective scan.

    dt: (B,L,dI) f32; Bc/Cc: (B,L,dS) f32; A: (dI,dS); xc: (B,L,dI);
    h0: (B,dI,dS) f32. Returns (y (B,L,dI), h_last).
    """
    Abar = jnp.exp(dt[..., None] * A)                               # (B,L,dI,dS)
    Bx = (dt * xc.astype(jnp.float32))[..., None] * Bc[:, :, None, :]

    def comb(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    Acum, hin = jax.lax.associative_scan(comb, (Abar, Bx), axis=1)
    h = Acum * h0[:, None] + hin                                    # (B,L,dI,dS)
    y = jnp.einsum("blds,bls->bld", h, Cc)
    return y, h[:, -1]


def mamba_mixer(
    cfg: ModelConfig,
    p: Mapping,
    x: jax.Array,
    mode: str,
    cache: Optional[Mapping] = None,
    valid=None,
):
    """x: (B, S, d). Returns (out (B,S,d), new_cache).

    ``valid`` (B, S) bool marks right-padded prefill: pad steps must be
    identity on the carried state. Masking dt to 0 does exactly that —
    Abar = exp(0·A) = 1 and the input contribution dt·x·B vanishes — and the
    conv state is gathered at the last valid token."""
    B, S, d = x.shape
    dI, _, dS = _dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(x.dtype))
    xp, z = jnp.split(xz, 2, axis=-1)

    conv_state = cache["conv"] if cache is not None else None
    n_valid = jnp.sum(valid, axis=1).astype(jnp.int32) if valid is not None else None
    if mode == "decode":
        # single (or few) step(s): exact recurrence
        xc, new_conv = _causal_conv(cfg, p, xp, conv_state)
        xc = jax.nn.silu(xc)
        dt, Bc, Cc, A = _ssm_inputs(cfg, p, xc)
        h = cache["ssm"]
        ys = []
        for t in range(S):  # S is 1 for decode shapes; tiny static loop otherwise
            Abar = jnp.exp(dt[:, t, :, None] * A)
            h = Abar * h + (dt[:, t] * xc[:, t].astype(jnp.float32))[..., None] * Bc[:, t, None, :]
            ys.append(jnp.einsum("bds,bs->bd", h, Cc[:, t]))
        y = jnp.stack(ys, axis=1)
        new_cache = {"conv": new_conv, "ssm": h}
    else:
        xc, new_conv = _causal_conv(cfg, p, xp, conv_state, n_valid=n_valid)
        xc = jax.nn.silu(xc)
        dt, Bc, Cc, A = _ssm_inputs(cfg, p, xc)
        if valid is not None:
            dt = jnp.where(valid[..., None], dt, 0.0)   # pad step == identity
        chunk = min(cfg.mamba.chunk, S)
        if S % chunk != 0:
            chunk = S
        nc = S // chunk
        # resume from the cached SSM state: zeros for a fresh prefill (every
        # caller hands a zero cache), the carried state for a chunked
        # prefill continuation (models/api.py prefill_chunk*)
        h0 = cache["ssm"] if cache is not None else jnp.zeros((B, dI, dS), jnp.float32)
        if nc == 1:
            y, h_last = _chunk_scan(dt, Bc, Cc, A, xc, h0)
        else:
            def body(h, args):
                dtc, Bcc, Ccc, xcc = args
                y, h = _chunk_scan(dtc, Bcc, Ccc, A, xcc, h)
                return h, y

            split = lambda t: t.reshape(B, nc, chunk, *t.shape[2:]).swapaxes(0, 1)
            h_last, y = jax.lax.scan(body, h0, (split(dt), split(Bc), split(Cc), split(xc)))
            y = y.swapaxes(0, 1).reshape(B, S, dI)
        new_cache = {"conv": new_conv, "ssm": h_last} if cache is not None else cache

    y = y.astype(x.dtype) + p["D"].astype(x.dtype) * xc
    out = y * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", out, p["out_proj"].astype(x.dtype))
    return out, new_cache
