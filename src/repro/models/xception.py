"""Xception-analog image classifier — the paper's own application model.

StraightLine's evaluation serves an Xception image classifier (4 classes:
cats / chook / dogs / horses, 299x299 inputs). We implement the same
depthwise-separable-convolution architecture in JAX (configurable width /
depth so examples and benchmarks run quickly on CPU) and use it as the
default request workload in the serving examples — request "data size" is
image resolution, exactly the paper's axis.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Mapping

import jax
import jax.numpy as jnp

from repro.models.common import NULL, ParamDef, init_tree, shape_tree


@dataclass(frozen=True)
class XceptionConfig:
    num_classes: int = 4
    width: int = 32            # entry conv channels
    n_blocks: int = 4          # middle separable blocks
    img_size: int = 64         # reduced from 299 for CPU speed (same structure)
    param_dtype: object = jnp.float32


def _conv_def(k: int, cin: int, cout: int) -> ParamDef:
    return ParamDef((k, k, cin, cout), (NULL,) * 4)


def param_defs(cfg: XceptionConfig) -> dict:
    w = cfg.width
    defs = {
        "entry": _conv_def(3, 3, w),
        "entry_b": ParamDef((w,), (NULL,), "zeros"),
    }
    for i in range(cfg.n_blocks):
        defs[f"b{i}_dw"] = ParamDef((3, 3, 1, w), (NULL,) * 4)       # depthwise
        defs[f"b{i}_pw"] = _conv_def(1, w, w)                         # pointwise
        defs[f"b{i}_bn_scale"] = ParamDef((w,), (NULL,), "ones")
        defs[f"b{i}_bn_bias"] = ParamDef((w,), (NULL,), "zeros")
    defs["head"] = ParamDef((w, cfg.num_classes), (NULL, NULL))
    defs["head_b"] = ParamDef((cfg.num_classes,), (NULL,), "zeros")
    return defs


def init(rng: jax.Array, cfg: XceptionConfig):
    return init_tree(rng, param_defs(cfg), cfg.param_dtype)


def param_shapes(cfg: XceptionConfig):
    return shape_tree(param_defs(cfg), cfg.param_dtype)


def _sep_block(p: Mapping, i: int, x: jax.Array) -> jax.Array:
    h = jax.lax.conv_general_dilated(
        x, p[f"b{i}_dw"], (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=x.shape[-1],
    )
    h = jax.lax.conv_general_dilated(
        h, p[f"b{i}_pw"], (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    mu = h.mean(axis=(0, 1, 2))
    var = h.var(axis=(0, 1, 2))
    h = (h - mu) * jax.lax.rsqrt(var + 1e-5)
    h = h * p[f"b{i}_bn_scale"] + p[f"b{i}_bn_bias"]
    return jax.nn.relu(x + h)


def forward(cfg: XceptionConfig, params: Mapping, images: jax.Array) -> jax.Array:
    """images: (B, H, W, 3) -> logits (B, num_classes)."""
    x = jax.lax.conv_general_dilated(
        images, params["entry"], (2, 2), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    x = jax.nn.relu(x + params["entry_b"])
    for i in range(cfg.n_blocks):
        x = _sep_block(params, i, x)
    x = x.mean(axis=(1, 2))
    return x @ params["head"] + params["head_b"]


def loss_fn(cfg: XceptionConfig, params: Mapping, images: jax.Array, labels: jax.Array):
    logits = forward(cfg, params, images)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (jnp.argmax(logits, -1) == labels).mean()
    return loss, {"loss": loss, "acc": acc}
