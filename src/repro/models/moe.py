"""Mixture-of-Experts FFN with expert parallelism over the TP ('model') axis.

Design (see DESIGN.md §5):
  * Activations are replicated over 'model' after the attention psum, so each
    model shard holds *all* local tokens and a slice of the experts. Dispatch
    is therefore local: each shard gathers (capacity-bounded) the tokens
    routed to its experts, runs the expert FFNs, scatter-adds the gated
    outputs, and a single psum over 'model' combines — the same collective
    cost as a TP FFN, no all-to-all.
  * Capacity per expert: C = ceil(cf * k * T_local / E). Overflow tokens are
    dropped (standard Switch/GShard semantics); property tests check exact
    equivalence with the dense reference when capacity is ample.
  * llama4-scale expert weights (773 B params) additionally shard d_ff over
    'data' (FSDP) and all-gather at use.

The single-device path (ctx is None) runs the identical capacity algorithm
with all experts local — it is the oracle for the sharded path.
"""
from __future__ import annotations

from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import FSDP, NULL, TP, ModelConfig, ParamDef, activation
from repro.models.quant import qeinsum
from repro.sharding.compat import shard_map_nocheck


def moe_defs(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, f, E = cfg.d_model, cfg.d_ff, m.n_experts
    ff_axis = FSDP if m.fsdp_experts else NULL
    defs = {
        "router": ParamDef((d, E), (NULL, NULL)),
        "w1": ParamDef((E, d, f), (TP, NULL, ff_axis)),
        "w2": ParamDef((E, f, d), (TP, ff_axis, NULL)),
    }
    if cfg.gated_mlp:
        defs["w3"] = ParamDef((E, d, f), (TP, NULL, ff_axis))
    return defs


def capacity_for(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(m.capacity_factor * m.top_k * n_tokens / m.n_experts) + 1
    c = min(max(c, 4), n_tokens)
    return c


# ---------------------------------------------------------------------------
# Core per-shard algorithm (also the single-device path)
# ---------------------------------------------------------------------------


def _experts_ffn(cfg: ModelConfig, xg, w1, w3, w2):
    """xg: (E_local, C, d); expert weights (E_local, d, f) / (E_local, f, d)."""
    h = qeinsum("ecd,edf->ecf", xg, w1)
    h = activation(cfg, h)
    if cfg.gated_mlp:
        h = h * qeinsum("ecd,edf->ecf", xg, w3)
    return qeinsum("ecf,efd->ecd", h, w2)


def moe_core(
    cfg: ModelConfig,
    x_flat: jax.Array,         # (T, d)
    logits: jax.Array,         # (T, E_global) fp32
    w1: jax.Array,             # (E_local, d, f)
    w3: Optional[jax.Array],
    w2: jax.Array,             # (E_local, f, d)
    e_offset,                  # first global expert id held by this shard
    capacity: int,
    valid=None,                # (T,) bool — padding tokens never claim capacity
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (T, d), aux_loss scalar)."""
    m = cfg.moe
    T = x_flat.shape[0]
    E_local = (w1["q"] if isinstance(w1, Mapping) else w1).shape[0]
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)   # (T, E)
    topv, topi = jax.lax.top_k(probs, m.top_k)                    # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    eids = e_offset + jnp.arange(E_local)                          # (E_local,)
    match = topi[None, :, :] == eids[:, None, None]                # (E_local, T, k)
    if valid is not None:
        # padded prefill: a pad token must neither displace a valid token
        # from an expert's top-C slots nor contribute output anywhere
        match = match & valid[None, :, None]
    w_te = jnp.sum(match * topv[None], axis=-1)                    # (E_local, T)
    assigned = jnp.any(match, axis=-1)                             # (E_local, T)

    # top-C tokens per expert, ranked by gate weight among assigned tokens
    score = assigned.astype(jnp.float32) + w_te
    _, sel_idx = jax.lax.top_k(score, capacity)                    # (E_local, C)
    sel_valid = jnp.take_along_axis(assigned, sel_idx, axis=-1)    # (E_local, C)
    if valid is not None:
        # bucket padding must not inflate expert capacity: the static C was
        # sized from the padded token count, so re-derive capacity_for() at
        # the dynamic valid count and keep only that top-ranked prefix —
        # exactly the slots an unpadded run of the same tokens would have.
        # A host-precomputed table (valid count is bounded by the static T)
        # keeps the arithmetic bit-identical to capacity_for's Python floats.
        caps = jnp.asarray(
            [0] + [capacity_for(cfg, t) for t in range(1, T + 1)], jnp.int32
        )
        dyn_c = caps[jnp.sum(valid)]
        sel_valid = sel_valid & (jnp.arange(capacity)[None, :] < dyn_c)
    gate = jnp.take_along_axis(w_te, sel_idx, axis=-1) * sel_valid

    xg = jnp.take(x_flat, sel_idx.reshape(-1), axis=0).reshape(E_local, capacity, -1)
    y = _experts_ffn(cfg, xg, w1, w3, w2)
    y = y * gate[..., None].astype(y.dtype)
    out = jnp.zeros_like(x_flat).at[sel_idx.reshape(-1)].add(y.reshape(-1, x_flat.shape[-1]))

    # Switch-style load-balance aux loss over *global* experts (identical on
    # every model shard because logits/topi are computed from replicated x).
    E = probs.shape[-1]
    frac_tokens = jnp.mean(
        jax.nn.one_hot(topi, E, dtype=jnp.float32).sum(1), axis=0
    ) / m.top_k                                                    # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac_tokens * frac_probs)
    return out, aux


# ---------------------------------------------------------------------------
# Public layer
# ---------------------------------------------------------------------------


def moe_ffn(cfg: ModelConfig, ctx, p: Mapping, x: jax.Array, valid=None):
    """x: (B, S, d) — replicated over TP, batch-sharded. ``valid`` (B, S)
    bool marks right-padded prefill tokens to exclude from expert-capacity
    competition. Returns (out, aux)."""
    B, S, d = x.shape
    w3 = p.get("w3")
    if ctx is None or ctx.tp_size == 1:
        x_flat = x.reshape(B * S, d)
        logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), p["router"].astype(jnp.float32))
        cap = capacity_for(cfg, B * S)
        v_flat = valid.reshape(B * S) if valid is not None else None
        out, aux = moe_core(cfg, x_flat, logits, p["w1"], w3, p["w2"], 0, cap, valid=v_flat)
        return out.reshape(B, S, d), aux

    mesh = ctx.mesh
    m = cfg.moe
    assert m.n_experts % ctx.tp_size == 0, (cfg.name, m.n_experts, ctx.tp_size)
    batch_spec = ctx.batch_spec_for(B)
    x_spec = jax.sharding.PartitionSpec(batch_spec, None, None)
    ff_ax = ctx.fsdp_axis if m.fsdp_experts else None
    P = jax.sharding.PartitionSpec

    def wspec(spec3):
        """Spec for a (possibly int8-quantized) expert-weight leaf."""
        def leaf_spec(v):
            if hasattr(v, "ndim") and v.shape[-2:] == (1,) + v.shape[-1:]:
                # scale tensor: contracting dim is 1 — drop its sharding
                s = list(spec3)
                s[-2] = None
                return P(*s)
            return P(*spec3)
        return leaf_spec

    def spec_tree_for(w, spec3):
        if isinstance(w, Mapping) and "q" in w:
            return {"q": P(*spec3), "s": wspec(spec3)(w["s"])}
        return P(*spec3)

    w1_s3 = (ctx.tp_axis, None, ff_ax)
    w2_s3 = (ctx.tp_axis, ff_ax, None)
    r_spec = P(None, None)
    dp = ctx.size_of(batch_spec)
    T_local = (B // dp) * S
    token_gather = cfg.moe_token_gather and m.fsdp_experts and batch_spec is not None
    cap = capacity_for(cfg, T_local * dp if token_gather else T_local)

    def _gather_w(w, axis):
        if isinstance(w, Mapping) and "q" in w:
            return {
                "q": jax.lax.all_gather(w["q"], ctx.fsdp_axis, axis=axis, tiled=True),
                "s": jax.lax.all_gather(w["s"], ctx.fsdp_axis, axis=axis, tiled=True)
                if w["s"].shape[axis] > 1
                else w["s"],
            }
        return jax.lax.all_gather(w, ctx.fsdp_axis, axis=axis, tiled=True)

    # statically known: only padded prefill carries a real mask — unpadded
    # train/decode must stay on the pre-existing static-capacity path
    has_mask = valid is not None

    def shard_fn(x_l, rw, w1, w3_, w2, valid_l=None):
        Bl, Sl, dl = x_l.shape
        if m.fsdp_experts:
            w1 = _gather_w(w1, 2)
            w2 = _gather_w(w2, 1)
            if w3_ is not None:
                w3_ = _gather_w(w3_, 2)
        x_flat = x_l.reshape(Bl * Sl, dl)
        logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), rw.astype(jnp.float32))
        e_off = jax.lax.axis_index(ctx.tp_axis) * (m.n_experts // ctx.tp_size)
        out, aux = moe_core(
            cfg, x_flat, logits, w1, w3_, w2, e_off, cap,
            valid=valid_l.reshape(Bl * Sl) if has_mask else None,
        )
        out = jax.lax.psum(out, ctx.tp_axis)
        aux = jax.lax.pmean(aux, ctx.batch_axes) if ctx.batch_axes else aux
        return out.reshape(Bl, Sl, dl), aux

    def shard_fn_tokens(x_l, rw, w1, w3_, w2, valid_l=None):
        """Decode-mode layout: tokens are tiny — all-gather THEM over the
        fsdp axis and keep expert weights sharded (experts x 'model',
        d_ff x 'data'). Per-layer wire drops from gigabytes (weight
        gathers) to a few MB (token gather + partial-output psum)."""
        Bl, Sl, dl = x_l.shape
        xg = x_l
        vg = valid_l
        for ax in reversed(ctx.batch_axes):
            xg = jax.lax.all_gather(xg, ax, axis=0, tiled=True)
            if has_mask:
                vg = jax.lax.all_gather(vg, ax, axis=0, tiled=True)
        T = xg.shape[0] * Sl
        x_flat = xg.reshape(T, dl)
        logits = jnp.einsum("td,de->te", x_flat.astype(jnp.float32), rw.astype(jnp.float32))
        e_off = jax.lax.axis_index(ctx.tp_axis) * (m.n_experts // ctx.tp_size)
        out, aux = moe_core(
            cfg, x_flat, logits, w1, w3_, w2, e_off, cap,
            valid=vg.reshape(T) if has_mask else None,
        )
        # partial over d_ff ('data') and experts ('model') — one combined psum
        out = jax.lax.psum(out, (ctx.fsdp_axis, ctx.tp_axis))
        # slice this shard's tokens back out
        idx = jnp.zeros((), jnp.int32)
        for a in ctx.batch_axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        out_l = jax.lax.dynamic_slice_in_dim(out.reshape(-1, Bl * Sl, dl), idx, 1, axis=0)[0]
        return out_l.reshape(Bl, Sl, dl), aux

    fn_body = shard_fn_tokens if token_gather else shard_fn
    w1_arg = p["w1"]
    w3_arg = w3 if w3 is not None else p["w1"]
    w2_arg = p["w2"]
    args = [x, p["router"], w1_arg, w3_arg, w2_arg]
    in_specs = [
        x_spec,
        r_spec,
        spec_tree_for(w1_arg, w1_s3),
        spec_tree_for(w3_arg, w1_s3),
        spec_tree_for(w2_arg, w2_s3),
    ]
    if has_mask:
        args.append(valid)
        in_specs.append(P(batch_spec, None))
    out_specs = (x_spec, jax.sharding.PartitionSpec())
    fn = shard_map_nocheck(
        fn_body, mesh=mesh, in_specs=tuple(in_specs), out_specs=out_specs
    )
    out, aux = fn(*args)
    return out, aux
