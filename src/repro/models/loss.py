"""Losses: chunked vocab-parallel cross-entropy (+ z-loss, MoE aux).

The unembedding is sharded over the TP axis on the vocab dim. Materializing
(B, S, V) logits replicated would cost e.g. 1M tokens x 202k vocab x 4 B
~ 800 GB for llama4 — instead we (a) keep logits TP-sharded via a sharding
constraint, (b) scan over ``cfg.ce_chunks`` sequence chunks so the live
logits slice is (B, S/chunks, V/tp), and (c) avoid one-hot materialization
by an iota-mask gather that stays sharded.
"""
from __future__ import annotations

from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import ModelConfig, unembed_weight
from repro.models.quant import qeinsum

IGNORE = -1


def _chunk_ce(cfg: ModelConfig, ctx, w, x_c: jax.Array, labels_c: jax.Array):
    """x_c: (B, C, d); labels_c: (B, C) int32. Returns (sum_loss, sum_z2, count)."""
    logits = qeinsum("bcd,dv->bcv", x_c, w).astype(jnp.float32)
    if ctx is not None:
        vocab_ax = ctx.tp_axis if logits.shape[-1] % ctx.tp_size == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits,
            jax.sharding.NamedSharding(
                ctx.mesh, P(ctx.batch_spec_for(logits.shape[0]), None, vocab_ax)
            ),
        )
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    z = jax.nn.logsumexp(logits, axis=-1)                       # (B, C)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    ll = jnp.sum(
        jnp.where(vocab_iota == labels_c[..., None], logits, 0.0), axis=-1
    )                                                           # (B, C)
    valid = labels_c != IGNORE
    loss = jnp.where(valid, z - ll, 0.0)
    return loss.sum(), jnp.where(valid, z * z, 0.0).sum(), valid.sum()


def lm_loss(
    cfg: ModelConfig,
    ctx,
    params: Mapping,
    hidden: jax.Array,          # (B, S, d) — final-normed
    labels: jax.Array,          # (B, S) int32, IGNORE to mask
    z_weight: float = 1e-4,
) -> Tuple[jax.Array, dict]:
    B, S, d = hidden.shape
    w = unembed_weight(cfg, params)
    nc = cfg.ce_chunks if S % cfg.ce_chunks == 0 else 1
    if nc == 1:
        sl, sz, cnt = _chunk_ce(cfg, ctx, w, hidden, labels)
    else:
        C = S // nc
        xs = (
            hidden.reshape(B, nc, C, d).swapaxes(0, 1),
            labels.reshape(B, nc, C).swapaxes(0, 1),
        )

        @jax.checkpoint  # recompute the logits chunk in bwd instead of saving
        def body(carry, args):
            x_c, l_c = args
            sl, sz, cnt = _chunk_ce(cfg, ctx, w, x_c, l_c)
            return (carry[0] + sl, carry[1] + sz, carry[2] + cnt), None

        (sl, sz, cnt), _ = jax.lax.scan(
            body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)), xs
        )
    denom = jnp.maximum(cnt, 1).astype(jnp.float32)
    loss = sl / denom
    zloss = z_weight * sz / denom
    return loss + zloss, {"ce": loss, "z": zloss, "tokens": denom}


def next_tokens_all(cfg: ModelConfig, ctx, params: Mapping, hidden: jax.Array) -> jax.Array:
    """Greedy next-token ids at EVERY position: (B, S, d) -> (B, S) int32.

    The speculative-decode verify pass needs the greedy continuation after
    each verified position in one shot. Argmax is monotone under the tanh
    softcap, so (matching ``next_tokens``) the cap is skipped — ids are
    identical either way and the (B, S, V) logits slice stays transient."""
    w = unembed_weight(cfg, params)
    logits = qeinsum("bsd,dv->bsv", hidden, w).astype(jnp.float32)
    if ctx is not None:
        vocab_ax = ctx.tp_axis if logits.shape[-1] % ctx.tp_size == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(ctx.mesh, P(None, None, vocab_ax))
        )
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def next_tokens(cfg: ModelConfig, ctx, params: Mapping, hidden_last: jax.Array) -> jax.Array:
    """Greedy next-token ids from final hidden states (B, 1|S, d) -> (B,).

    Argmax over the TP-sharded vocab dim stays a cheap sharded reduce —
    serve_step outputs token ids, never full logits.
    """
    w = unembed_weight(cfg, params)
    x = hidden_last[:, -1, :]
    logits = qeinsum("bd,dv->bv", x, w).astype(jnp.float32)
    if ctx is not None:
        vocab_ax = ctx.tp_axis if logits.shape[-1] % ctx.tp_size == 0 else None
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(ctx.mesh, P(None, vocab_ax))
        )
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)
