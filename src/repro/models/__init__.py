from repro.models.api import DecoderLM, EmbedsLM, EncDecLM, ShapeSpec, get_model
from repro.models.common import (
    EncoderCfg,
    MambaCfg,
    MoECfg,
    ModelConfig,
    XLSTMCfg,
)

__all__ = [
    "DecoderLM",
    "EmbedsLM",
    "EncDecLM",
    "EncoderCfg",
    "MambaCfg",
    "MoECfg",
    "ModelConfig",
    "ShapeSpec",
    "XLSTMCfg",
    "get_model",
]
