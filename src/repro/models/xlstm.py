"""xLSTM blocks: chunkwise-parallel mLSTM and sequential sLSTM.

mLSTM recurrence (per head; q scaled by 1/sqrt(DK)):
    m_t = max(lf_t + m_{t-1}, i_t)
    C_t = exp(lf_t + m_{t-1} - m_t) C_{t-1} + exp(i_t - m_t) k_t v_t^T
    n_t = exp(lf_t + m_{t-1} - m_t) n_{t-1} + exp(i_t - m_t) k_t
    h_t = (q_t C_t) / max(|q_t . n_t|, 1)

The chunkwise form below is algebraically identical (stabilizers included)
and is the shape the Pallas kernel (kernels/mlstm_chunk) implements; the
sequential form is retained as the decode step and the test oracle.
"""
from __future__ import annotations

from typing import Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.common import NULL, TP, ModelConfig, ParamDef, rmsnorm
from repro.models.mamba import conv_state_at

NEG = -1e30
# A forget-gate preactivation this large makes log_sigmoid(f) exactly 0.0 in
# f32 (softplus(-BIG) underflows), so a masked pad step multiplies the state
# by exp(0) == 1 — bit-exact identity, not merely approximate.
BIG = 1e9


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    dU = int(cfg.xlstm.proj_factor * cfg.d_model)  # up-projected width
    NH = cfg.n_heads
    return dU, dU // NH


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    dU, DH = _mlstm_dims(cfg)
    NH = cfg.n_heads
    K = cfg.xlstm.conv
    return {
        "up_proj": ParamDef((d, 2 * dU), (NULL, TP)),
        "conv_w": ParamDef((K, dU), (NULL, TP), scale=0.5),
        "conv_b": ParamDef((dU,), (TP,), "zeros"),
        "wq": ParamDef((dU, dU), (NULL, TP)),
        "wk": ParamDef((dU, dU), (NULL, TP)),
        "wv": ParamDef((dU, dU), (NULL, TP)),
        "wi": ParamDef((dU, NH), (TP, NULL)),
        "wf": ParamDef((dU, NH), (TP, NULL)),
        "bi": ParamDef((NH,), (NULL,), "zeros"),
        "bf": ParamDef((NH,), (NULL,), "ones"),   # bias toward remembering
        "hnorm": ParamDef((dU,), (TP,), "ones"),
        "down_proj": ParamDef((dU, d), (TP, NULL)),
    }


def mlstm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    dU, DH = _mlstm_dims(cfg)
    NH = cfg.n_heads
    K = cfg.xlstm.conv
    return {
        "C": jax.ShapeDtypeStruct((batch, NH, DH, DH), jnp.float32),
        "n": jax.ShapeDtypeStruct((batch, NH, DH), jnp.float32),
        "m": jax.ShapeDtypeStruct((batch, NH), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, K - 1, dU), cfg.compute_dtype),
    }


def _conv(cfg: ModelConfig, p: Mapping, x: jax.Array, state, n_valid=None):
    B, S, dU = x.shape
    K = cfg.xlstm.conv
    if state is None:
        state = jnp.zeros((B, K - 1, dU), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    out = jnp.zeros_like(x)
    w = p["conv_w"].astype(x.dtype)
    for k in range(K):
        out = out + xp[:, k : k + S, :] * w[k]
    new_state = xp[:, S:, :] if n_valid is None else conv_state_at(xp, n_valid, K)
    return jax.nn.silu(out + p["conv_b"].astype(x.dtype)), new_state


def _qkvif(cfg: ModelConfig, p: Mapping, xm: jax.Array, xc: jax.Array):
    """xm: conv path (B,S,dU); xc: raw up-projection (B,S,dU) for v."""
    B, S, dU = xm.shape
    NH = cfg.n_heads
    DH = dU // NH
    q = jnp.einsum("bsd,de->bse", xm, p["wq"].astype(xm.dtype)).reshape(B, S, NH, DH)
    k = jnp.einsum("bsd,de->bse", xm, p["wk"].astype(xm.dtype)).reshape(B, S, NH, DH)
    v = jnp.einsum("bsd,de->bse", xc, p["wv"].astype(xm.dtype)).reshape(B, S, NH, DH)
    i = jnp.einsum("bsd,dh->bsh", xm, p["wi"].astype(xm.dtype)).astype(jnp.float32) + p["bi"].astype(jnp.float32)
    f = jnp.einsum("bsd,dh->bsh", xm, p["wf"].astype(xm.dtype)).astype(jnp.float32) + p["bf"].astype(jnp.float32)
    q = q * (DH ** -0.5)
    return q, k, v, i, f


def mlstm_sequential(q, k, v, i, f, C0, n0, m0):
    """Oracle / decode path. q,k,v: (B,S,NH,DH); i,f: (B,S,NH) raw.
    Returns (h (B,S,NH,DH), (C, n, m))."""
    lf = jax.nn.log_sigmoid(f)

    def step(carry, t):
        C, n, m = carry
        qt, kt, vt, it, lft = t
        qt = qt.astype(jnp.float32)
        kt = kt.astype(jnp.float32)
        vt = vt.astype(jnp.float32)
        m_new = jnp.maximum(lft + m, it)
        a = jnp.exp(lft + m - m_new)[..., None]          # (B,NH,1)
        b = jnp.exp(it - m_new)[..., None]
        C = a[..., None] * C + b[..., None] * (kt[..., :, None] * vt[..., None, :])
        n = a * n + b * kt
        num = jnp.einsum("bhd,bhde->bhe", qt, C)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, i, lf))
    (C, n, m), hs = jax.lax.scan(step, (C0, n0, m0), xs)
    return jnp.moveaxis(hs, 0, 1), (C, n, m)


def _mlstm_chunk(q, k, v, i, lf, C0, n0, m0):
    """One chunk, exact stabilized chunkwise form.

    q,k,v: (B,L,NH,DH); i,lf: (B,L,NH) f32; carry C0 (B,NH,DH,DH),
    n0 (B,NH,DH), m0 (B,NH). Returns (h, (C,n,m)).
    """
    B, L, NH, DH = q.shape
    qf = q.astype(jnp.float32).transpose(0, 2, 1, 3)   # (B,NH,L,DH)
    kf = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vf = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    it = i.transpose(0, 2, 1)                          # (B,NH,L)
    lft = lf.transpose(0, 2, 1)

    cum = jnp.cumsum(lft, axis=-1)                     # inclusive cumsum of log-forget
    total = cum[..., -1:]

    # intra-chunk decay D_ij = cum_i - cum_j + i_j  (j <= i)
    Dm = cum[..., :, None] - cum[..., None, :] + it[..., None, :]
    tri = jnp.tril(jnp.ones((L, L), bool))
    Dm = jnp.where(tri, Dm, NEG)

    g = cum + m0[..., None]                            # inter stabilizer input
    m_row = jnp.maximum(jnp.max(Dm, axis=-1), g)       # (B,NH,L)

    s = jnp.einsum("bhld,bhmd->bhlm", qf, kf)          # (B,NH,L,L)
    s = s * jnp.exp(Dm - m_row[..., None])
    inter_scale = jnp.exp(g - m_row)[..., None]        # (B,NH,L,1)
    num = jnp.einsum("bhlm,bhmd->bhld", s, vf) + inter_scale * jnp.einsum(
        "bhld,bhde->bhle", qf, C0
    )
    den = jnp.sum(s, axis=-1) + inter_scale[..., 0] * jnp.einsum("bhld,bhd->bhl", qf, n0)
    h = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]

    # carry update
    a = total - cum + it                               # (B,NH,L): decay j..L + gate
    m_new = jnp.maximum(total[..., 0] + m0, jnp.max(a, axis=-1))
    scale_old = jnp.exp(total[..., 0] + m0 - m_new)    # (B,NH)
    w = jnp.exp(a - m_new[..., None])                  # (B,NH,L)
    C = scale_old[..., None, None] * C0 + jnp.einsum("bhl,bhld,bhle->bhde", w, kf, vf)
    n = scale_old[..., None] * n0 + jnp.einsum("bhl,bhld->bhd", w, kf)
    return h.transpose(0, 2, 1, 3), (C, n, m_new)


def mlstm_chunkwise(cfg, q, k, v, i, f, C0, n0, m0):
    B, S, NH, DH = q.shape
    lf = jax.nn.log_sigmoid(f)
    L = min(cfg.xlstm.chunk, S)
    if S % L != 0:
        L = S
    nc = S // L
    if nc == 1:
        return _mlstm_chunk(q, k, v, i, lf, C0, n0, m0)

    split = lambda t: t.reshape(B, nc, L, *t.shape[2:]).swapaxes(0, 1)

    def body(carry, args):
        h, carry = _mlstm_chunk(*args, *carry)
        return carry, h

    carry, hs = jax.lax.scan(body, (C0, n0, m0), tuple(split(t) for t in (q, k, v, i, lf)))
    return hs.swapaxes(0, 1).reshape(B, S, NH, DH), carry


def mlstm_mixer(cfg: ModelConfig, p: Mapping, x: jax.Array, mode: str, cache=None, valid=None):
    """x: (B,S,d) -> (out, new_cache).

    ``valid`` (B, S) bool marks right-padded prefill. Identity pad steps via
    the gates: i -> NEG kills the input branch (exp(i - m) == 0) and
    f -> BIG makes the retain factor exp(log_sigmoid(f)) == 1 exactly, in
    both the sequential and chunkwise (incl. Pallas) forms."""
    B, S, d = x.shape
    dU, DH = _mlstm_dims(cfg)
    NH = cfg.n_heads
    xz = jnp.einsum("bsd,de->bse", x, p["up_proj"].astype(x.dtype))
    xu, z = jnp.split(xz, 2, axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    n_valid = jnp.sum(valid, axis=1).astype(jnp.int32) if valid is not None else None
    xm, new_conv = _conv(cfg, p, xu, conv_state, n_valid=n_valid)
    q, k, v, i, f = _qkvif(cfg, p, xm, xu)
    if valid is not None:
        i = jnp.where(valid[..., None], i, NEG)
        f = jnp.where(valid[..., None], f, BIG)

    if cache is not None:
        C0, n0, m0 = cache["C"], cache["n"], cache["m"]
    else:
        C0 = jnp.zeros((B, NH, DH, DH), jnp.float32)
        n0 = jnp.zeros((B, NH, DH), jnp.float32)
        m0 = jnp.full((B, NH), 0.0, jnp.float32)

    if mode == "decode":
        h, (C, n, m) = mlstm_sequential(q, k, v, i, f, C0, n0, m0)
    elif cfg.use_pallas:
        from repro.kernels.mlstm_chunk import ops as mk_ops

        h, (C, n, m) = mk_ops.mlstm_chunkwise(q, k, v, i, f, C0, n0, m0, chunk=cfg.xlstm.chunk)
    else:
        h, (C, n, m) = mlstm_chunkwise(cfg, q, k, v, i, f, C0, n0, m0)

    h = h.reshape(B, S, dU).astype(x.dtype)
    # headwise norm (rmsnorm over DH per head), then output gate
    h = rmsnorm(h.reshape(B, S, NH, DH), jnp.ones((DH,), jnp.float32)).reshape(B, S, dU)
    h = h * p["hnorm"].astype(x.dtype)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bsd,de->bse", h, p["down_proj"].astype(x.dtype))
    new_cache = None
    if cache is not None:
        new_cache = {"C": C, "n": n, "m": m, "conv": new_conv.astype(cache["conv"].dtype)}
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    NH = cfg.n_heads
    DH = d // NH
    return {
        "w_gates": ParamDef((d, 4 * d), (NULL, NULL)),
        "r_gates": ParamDef((NH, DH, 4 * DH), (NULL, NULL, NULL), scale=0.3),
        "b_gates": ParamDef((4 * d,), (NULL,), "zeros"),
        "out_proj": ParamDef((d, d), (NULL, TP)),
        "hnorm": ParamDef((d,), (NULL,), "ones"),
    }


def slstm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    NH = cfg.n_heads
    DH = cfg.d_model // NH
    sd = lambda: jax.ShapeDtypeStruct((batch, NH, DH), jnp.float32)
    return {"c": sd(), "n": sd(), "h": sd(), "m": jax.ShapeDtypeStruct((batch, NH), jnp.float32)}


def slstm_mixer(cfg: ModelConfig, p: Mapping, x: jax.Array, mode: str, cache=None, valid=None):
    """Sequential sLSTM with exponential gating and head-wise recurrence.
    ``valid`` (B, S) bool: pad steps keep the previous carry unchanged."""
    B, S, d = x.shape
    NH = cfg.n_heads
    DH = d // NH
    wx = jnp.einsum("bsd,de->bse", x, p["w_gates"].astype(x.dtype)).astype(jnp.float32)
    wx = wx + p["b_gates"].astype(jnp.float32)
    wx = wx.reshape(B, S, NH, 4 * DH)
    R = p["r_gates"].astype(jnp.float32)

    if cache is not None:
        c0, n0, h0, m0 = cache["c"], cache["n"], cache["h"], cache["m"]
    else:
        # zeros to match slstm_cache_defs init (prefill/decode continuation
        # must be exact); h_t divides by max(n, 1) so n0=0 is safe.
        c0 = jnp.zeros((B, NH, DH), jnp.float32)
        n0 = jnp.zeros((B, NH, DH), jnp.float32)
        h0 = jnp.zeros((B, NH, DH), jnp.float32)
        m0 = jnp.zeros((B, NH), jnp.float32)

    def step(carry, inp):
        c0_, n0_, h0_, m0_ = carry
        wt, vt = inp                                          # vt: (B,) valid mask
        pre = wt + jnp.einsum("bhd,hde->bhe", h0_, R)         # (B,NH,4DH)
        zt, it, ft, ot = jnp.split(pre, 4, axis=-1)
        # scalar-per-cell exponential gating with stabilizer (max over cell dims)
        i_s = jnp.max(it, axis=-1)                            # (B,NH) stabilizer proxy
        f_s = jax.nn.log_sigmoid(jnp.max(ft, axis=-1))
        m_new = jnp.maximum(f_s + m0_, i_s)
        i_g = jnp.exp(it - m_new[..., None])
        f_g = jnp.exp(jax.nn.log_sigmoid(ft) + m0_[..., None] - m_new[..., None])
        z_g = jnp.tanh(zt)
        o_g = jax.nn.sigmoid(ot)
        c = f_g * c0_ + i_g * z_g
        n = f_g * n0_ + i_g
        h = o_g * c / jnp.maximum(n, 1.0)
        # pad steps carry the previous state through untouched
        keep = vt[:, None, None]
        c = jnp.where(keep, c, c0_)
        n = jnp.where(keep, n, n0_)
        h_c = jnp.where(keep, h, h0_)
        m_c = jnp.where(keep[..., 0], m_new, m0_)
        return (c, n, h_c, m_c), h

    vmask = valid if valid is not None else jnp.ones((B, S), bool)
    (c, n, h, m), hs = jax.lax.scan(
        step, (c0, n0, h0, m0), (jnp.moveaxis(wx, 1, 0), jnp.moveaxis(vmask, 1, 0))
    )
    hs = jnp.moveaxis(hs, 0, 1).reshape(B, S, d).astype(x.dtype)
    hs = rmsnorm(hs, p["hnorm"])
    out = jnp.einsum("bsd,de->bse", hs, p["out_proj"].astype(x.dtype))
    new_cache = {"c": c, "n": n, "h": h, "m": m} if cache is not None else None
    return out, new_cache
