from repro.sharding.axes import MeshCtx, Rules, make_ctx

__all__ = ["MeshCtx", "Rules", "make_ctx"]
