"""Logical-axis rules: map ParamDef logical axes onto mesh axes.

The production mesh is ('data', 'model') single-pod or ('pod', 'data',
'model') multi-pod; 'pod' simply extends the data-parallel axis. Tests run
with ctx=None (single device) — every module must work in that mode.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import EMBED, FSDP, NULL, STACK, TP, ParamDef


@dataclass(frozen=True)
class MeshCtx:
    mesh: Any                      # jax.sharding.Mesh
    batch_axes: Tuple[str, ...]    # axes that shard the batch (pod+data)
    tp_axis: str                   # tensor/expert-parallel axis
    fsdp_axis: str                 # optimizer/param fully-sharded axis

    @property
    def tp_size(self) -> int:
        return self.mesh.shape[self.tp_axis]

    @property
    def dp_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def n_devices(self) -> int:
        return int(np.prod(list(self.mesh.shape.values())))

    def batch_spec_for(self, batch: int):
        """Axis (or axes) to shard a batch dim of the given size, or None."""
        if batch % self.dp_size == 0:
            return self.batch_axes
        # try a prefix of the batch axes (e.g. batch=2 on pod axis only)
        for i in range(len(self.batch_axes) - 1, 0, -1):
            sz = int(np.prod([self.mesh.shape[a] for a in self.batch_axes[:i]]))
            if batch % sz == 0:
                return self.batch_axes[:i]
        return None

    def size_of(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        return int(np.prod([self.mesh.shape[a] for a in axes]))

    def data_spec(self, batch: int, ndim: int) -> P:
        """PartitionSpec for a (batch, ...) data array."""
        return P(self.batch_spec_for(batch), *([None] * (ndim - 1)))


def make_ctx(mesh: Optional[Mesh]) -> Optional[MeshCtx]:
    if mesh is None:
        return None
    names = mesh.axis_names
    if "pod" in names:
        return MeshCtx(mesh, ("pod", "data"), "model", "data")
    return MeshCtx(mesh, ("data",), "model", "data")


class Rules:
    """Resolve ParamDef logical axes to PartitionSpecs on a given ctx."""

    def __init__(self, ctx: Optional[MeshCtx], fsdp_params: bool = False):
        self.ctx = ctx
        self.fsdp_params = fsdp_params

    def spec_for(self, d: ParamDef) -> P:
        if self.ctx is None:
            return P()
        mapping = {
            TP: self.ctx.tp_axis,
            FSDP: self.ctx.fsdp_axis,
            EMBED: None,
            STACK: None,
            NULL: None,
        }
        axes = [mapping.get(a) for a in d.axes]
        # Drop shardings that do not divide the dim evenly.
        out = []
        for dim, ax in zip(d.shape, axes):
            if ax is not None and dim % self.ctx.mesh.shape[ax] != 0:
                ax = None
            out.append(ax)
        # Optional ZeRO-3/FSDP: additionally shard the largest unsharded dim
        # over the fsdp axis (used for very large param trees).
        if self.fsdp_params and self.ctx is not None:
            fs = self.ctx.mesh.shape[self.ctx.fsdp_axis]
            if self.ctx.fsdp_axis not in [a for a in out if a]:
                cand = [
                    (dim, i)
                    for i, (dim, ax) in enumerate(zip(d.shape, out))
                    if ax is None and dim % fs == 0 and dim >= 2 * fs
                ]
                if cand:
                    _, i = max(cand)
                    out[i] = self.ctx.fsdp_axis
        return P(*out)

    def spec_tree(self, defs: Any) -> Any:
        return jax.tree.map(
            self.spec_for, defs, is_leaf=lambda x: isinstance(x, ParamDef)
        )

    def sharding_tree(self, defs: Any) -> Any:
        if self.ctx is None:
            return jax.tree.map(
                lambda d: None, defs, is_leaf=lambda x: isinstance(x, ParamDef)
            )
        return jax.tree.map(
            lambda d: NamedSharding(self.ctx.mesh, self.spec_for(d)),
            defs,
            is_leaf=lambda x: isinstance(x, ParamDef),
        )
