"""JAX version compatibility for shard_map.

JAX >= 0.6 exposes ``jax.shard_map`` with the replication-check kwarg
``check_vma``; older releases only have ``jax.experimental.shard_map`` with
``check_rep``. Importing from here keeps every call site on one shim.

    from repro.sharding.compat import shard_map_nocheck
    fn = shard_map_nocheck(body, mesh=mesh, in_specs=..., out_specs=...)
"""
from __future__ import annotations

try:
    from jax import shard_map as _shard_map

    _CHECK_KW = "check_vma"
except ImportError:  # pragma: no cover — older JAX
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map_nocheck(fn, *, mesh, in_specs, out_specs):
    """shard_map with the (version-appropriate) replication check disabled."""
    return _shard_map(
        fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: False}
    )
