"""Static-analysis tier for the serving runtime's concurrency + kernel contracts.

After PRs 3-9 the repo is a genuinely concurrent runtime (~84 lock sites across
router / engine / scheduler / telemetry / tracing / prefix cache) whose
correctness rests on documented-but-unenforced contracts: "one stepper, many
submitters", lock-free ``capacity_now()`` snapshots, exactly-once hedge
accounting, and the kernel-family layout rules in ``kernels/__init__``.  This
package turns those contracts into machine-checked invariants:

- ``locklint``     lock-discipline linter: guarded fields only touched under
                   their lock; no blocking calls / device dispatch while a
                   strict lock is held.
- ``lockorder``    static may-acquire-under graph + cycle (deadlock) detection;
                   emits a dot/JSON artifact that doubles as documentation.
- ``witness``      runtime instrumented Lock/RLock recording *actual*
                   acquisition order during the concurrency soaks and checking
                   it against the static graph.  Static analysis proposes, the
                   witness disposes.
- ``kernelcheck``  kernel-family contract: kernel.py/ref.py/parity-test
                   triples, ``input_output_aliases`` on in-place pool writes,
                   no traced ops in index maps.

Everything is stdlib-``ast`` based -- no new dependencies.  Run the whole tier
with ``python -m repro.analysis`` (see ``scripts/ci.sh analyze``).
"""

from .common import Finding, SourceFile  # noqa: F401
