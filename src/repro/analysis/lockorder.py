"""Static may-acquire-under graph extraction + cycle (deadlock) detection.

For every function in the analyzed modules we record which locks it acquires
(``with self._lock`` / ``with b.cond``) and which calls happen while a lock is
held.  Method summaries are closed over the name-resolved call graph to a
fixpoint, so ``EngineLoop.submit`` holding ``EngineLoop._lock`` while calling
something that eventually takes ``Trace._lock`` yields the edge
``EngineLoop._lock -> Trace._lock`` even across modules.

Edges mean "may acquire B while holding A".  A cycle in that graph is a
potential deadlock; a self-edge on a *non-reentrant* Lock is a guaranteed one.
Self-edges on RLocks (the engine's coarse step lock) are recorded but legal.

Call resolution is by bare method name across the analyzed set -- deliberately
over-approximate for a lint (ambiguity widens the graph, never narrows it).
Container/stdlib method names (``get``/``pop``/``append``...) are excluded so
dict traffic can't alias onto our classes and fabricate cycles.

The graph is emitted as JSON + Graphviz dot (``docs/lock_order.*``) and doubles
as the documentation of the runtime's lock hierarchy; ``witness.py`` checks
recorded runtime orders against it.  Suppress an edge's source line with
``# lockorder: ok <reason>``.
"""

from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .common import Finding, SourceFile, suppression_reason, unparse
from .locklint import (
    ClassLocks,
    LockDecl,
    class_families,
    collect_lock_decls,
    family_lock_decls,
)

TOOL = "lockorder"

#: attr names never resolved to our methods: ubiquitous container/stdlib verbs
#: that would alias dict/list/deque traffic onto analyzed classes.
IGNORED_CALLEES = {
    "get", "set", "pop", "popleft", "append", "appendleft", "add", "discard",
    "update", "items", "keys", "values", "clear", "extend", "insert", "remove",
    "count", "index", "sort", "copy", "join", "split", "strip", "format",
    "read", "write", "flush", "close", "encode", "setdefault", "acquire",
    "release", "notify", "notify_all", "is_set", "put", "load", "dump",
    # threading.Condition/Event verbs: .wait() on a held condition is the
    # documented release-and-sleep, not an acquisition of someone's `wait`
    "wait",
}


@dataclass
class Edge:
    src: str
    dst: str
    path: str
    line: int
    via: str          # "nested-with" | "call:<name>"

    def key(self) -> Tuple[str, str]:
        return (self.src, self.dst)


@dataclass
class _FuncInfo:
    qname: str                   # "Class.method" or "module.func"
    src: SourceFile
    cls_name: str = ""           # owning class ("" for module functions)
    acquires: Set[str] = field(default_factory=set)
    # (held lock ids at the call, callee bare name, line, is self.X() call)
    calls: List[Tuple[Tuple[str, ...], str, int, bool]] = field(default_factory=list)
    nested: List[Edge] = field(default_factory=list)


class _FuncScanner(ast.NodeVisitor):
    def __init__(self, graph: "LockOrder", info: _FuncInfo, cls: Optional[ClassLocks]):
        self.g = graph
        self.info = info
        self.cls = cls
        self.held: List[str] = []

    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            lock_id = self.g.lock_id(item.context_expr, self.cls)
            if lock_id is not None:
                self.info.acquires.add(lock_id)
                for h in self.held:
                    self.info.nested.append(Edge(
                        src=h, dst=lock_id, path=self.info.src.path,
                        line=item.context_expr.lineno, via="nested-with"))
                self.held.append(lock_id)
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(pushed):
            self.held.pop()

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        return  # nested defs run later; scanned as their own functions

    visit_AsyncFunctionDef = visit_FunctionDef
    visit_Lambda = visit_FunctionDef

    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        name: Optional[str] = None
        selfcall = False
        if isinstance(node.func, ast.Attribute):
            name = node.func.attr
            base = node.func.value
            selfcall = (isinstance(base, ast.Name) and base.id == "self") or (
                isinstance(base, ast.Call) and isinstance(base.func, ast.Name)
                and base.func.id == "super")
        elif isinstance(node.func, ast.Name):
            name = node.func.id
        if not name or name in IGNORED_CALLEES or name.startswith("__"):
            return
        self.info.calls.append((tuple(self.held), name, node.lineno, selfcall))


class LockOrder:
    def __init__(self, sources: Sequence[SourceFile]):
        self.sources = list(sources)
        self.classes = collect_lock_decls(self.sources)
        self.families = class_families(self.classes)
        self.decls: Dict[str, LockDecl] = {}
        for info in self.classes.values():
            for decl in info.locks.values():
                self.decls[f"{self._family_owner(info.name, decl.attr)}.{decl.attr}"] = decl
        self.funcs: Dict[str, _FuncInfo] = {}
        self.edges: List[Edge] = []
        self.findings: List[Finding] = []

    # -- lock identity ------------------------------------------------------
    def _family_owner(self, cls_name: str, attr: str) -> str:
        """Canonical owner name for a lock attr: when several classes in one
        inheritance family declare it (both engines create ``self.lock``),
        collapse onto their common analyzed base so the graph has one node."""
        family = self.families.get(cls_name, {cls_name})
        declaring = [m for m in sorted(family)
                     if attr in self.classes.get(m, ClassLocks(m)).locks]
        if len(declaring) <= 1:
            return declaring[0] if declaring else cls_name
        for m in sorted(family):
            info = self.classes.get(m)
            if info is not None and all(
                m in self.classes.get(d, ClassLocks(d)).bases or m == d
                for d in declaring
            ):
                return m
        return declaring[0]

    def lock_id(self, expr: ast.AST, cls: Optional[ClassLocks]) -> Optional[str]:
        if not isinstance(expr, ast.Attribute):
            return None
        attr = expr.attr
        base = unparse(expr.value)
        owner: Optional[ClassLocks] = None
        if base == "self" and cls is not None:
            decls = family_lock_decls(self.classes, self.families, cls.name, attr)
            if decls:
                owner = self.classes[decls[0].cls]
        if owner is None:
            owners = [c for c in self.classes.values() if attr in c.locks]
            if len(owners) == 1:
                owner = owners[0]
        if owner is None:
            return None
        decl = owner.locks[attr]
        # a Condition and its base lock are one mutex: canonicalize on the
        # condition attr if one exists, else the lock attr.
        return self._canonical(owner, decl)

    def _canonical(self, owner: ClassLocks, decl: LockDecl) -> str:
        name = self._family_owner(owner.name, decl.attr)
        if decl.cond_base is not None:
            return f"{name}.{decl.attr}"
        for other in owner.locks.values():
            if other.cond_base == decl.attr:
                return f"{self._family_owner(owner.name, other.attr)}.{other.attr}"
        return f"{name}.{decl.attr}"

    def node_kind(self, lock_id: str) -> str:
        decl = self.decls.get(lock_id)
        if decl is None:
            return "Lock"
        if decl.cond_base is not None:
            base = self.decls.get(f"{decl.cls}.{decl.cond_base}")
            return base.kind if base is not None else "Lock"
        return decl.kind

    # -- extraction ---------------------------------------------------------
    def scan(self) -> None:
        for src in self.sources:
            for node in src.tree.body:
                if isinstance(node, ast.ClassDef):
                    cls = self.classes.get(node.name)
                    for child in node.body:
                        if isinstance(child, ast.FunctionDef):
                            self._scan_func(src, child, cls, f"{node.name}.{child.name}")
                elif isinstance(node, ast.FunctionDef):
                    self._scan_func(src, node, None, node.name)

    def _scan_func(self, src: SourceFile, fn: ast.FunctionDef,
                   cls: Optional[ClassLocks], qname: str) -> None:
        info = _FuncInfo(qname=qname, src=src, cls_name=cls.name if cls else "")
        scanner = _FuncScanner(self, info, cls)
        for stmt in fn.body:
            scanner.visit(stmt)
        self.funcs[qname] = info
        # nested defs (worker closures) as standalone functions
        for stmt in ast.walk(fn):
            if isinstance(stmt, ast.FunctionDef) and stmt is not fn:
                self._scan_func(src, stmt, cls, f"{qname}.<{stmt.name}>")

    # -- summaries + edges --------------------------------------------------
    def _resolve(self, name: str, cls_name: str = "", selfcall: bool = False) -> List[_FuncInfo]:
        """Callees for a bare name.  ``self.X()`` resolves only within the
        caller's inheritance family when the family defines X -- otherwise the
        engine's ``self.submit`` would alias onto the router's and fabricate
        cross-stack edges."""
        if selfcall and cls_name:
            family = self.families.get(cls_name, {cls_name})
            scoped = [f for q, f in self.funcs.items()
                      if f.cls_name in family and q.rsplit(".", 1)[-1] == name]
            if scoped:
                return scoped
        return [f for q, f in self.funcs.items()
                if q == name or q.rsplit(".", 1)[-1] == name
                or q.rsplit(".", 1)[-1] == f"<{name}>"]

    def build(self) -> List[Edge]:
        self.scan()
        # transitive acquires to a fixpoint over name-resolved calls
        summary: Dict[str, Set[str]] = {q: set(f.acquires) for q, f in self.funcs.items()}
        changed = True
        while changed:
            changed = False
            for q, f in self.funcs.items():
                for _, callee, _, selfcall in f.calls:
                    for target in self._resolve(callee, f.cls_name, selfcall):
                        extra = summary[target.qname] - summary[q]
                        if extra:
                            summary[q] |= extra
                            changed = True
        edges: Dict[Tuple[str, str], Edge] = {}
        for f in self.funcs.values():
            for e in f.nested:
                edges.setdefault(e.key(), e)
            for held, callee, line, selfcall in f.calls:
                if not held:
                    continue
                acquired: Set[str] = set()
                for target in self._resolve(callee, f.cls_name, selfcall):
                    acquired |= summary[target.qname]
                for h in held:
                    for lock in acquired:
                        e = Edge(src=h, dst=lock, path=f.src.path, line=line,
                                 via=f"call:{callee}")
                        edges.setdefault(e.key(), e)
        # reasoned suppressions drop the edge (and record nothing)
        kept = []
        for e in edges.values():
            src_file = next(s for s in self.sources if s.path == e.path)
            reason = suppression_reason(src_file, e.line, TOOL)
            if reason:
                continue
            kept.append(e)
        self.edges = sorted(kept, key=lambda e: (e.src, e.dst))
        return self.edges

    # -- cycle detection ----------------------------------------------------
    def check(self) -> List[Finding]:
        if not self.edges:
            self.build()
        adj: Dict[str, List[Edge]] = {}
        for e in self.edges:
            if e.src == e.dst:
                kind = self.node_kind(e.src)
                if kind != "RLock":
                    self.findings.append(Finding(
                        tool=TOOL, path=e.path, line=e.line, code="self-deadlock",
                        message=f"{e.src} ({kind}) may be re-acquired while already "
                                f"held (via {e.via}); only an RLock survives that"))
                continue
            adj.setdefault(e.src, []).append(e)
        for cycle in _find_cycles(adj):
            first = cycle[0]
            path = " -> ".join([e.src for e in cycle] + [cycle[0].src])
            self.findings.append(Finding(
                tool=TOOL, path=first.path, line=first.line, code="lock-cycle",
                message=f"lock-order cycle (potential deadlock): {path}"))
        return self.findings

    # -- artifacts ----------------------------------------------------------
    def to_json(self) -> dict:
        nodes = sorted({e.src for e in self.edges} | {e.dst for e in self.edges}
                       | set(self.decls.keys() - {
                           # conditions are canonicalized onto their own id;
                           # hide base-lock aliases from the node list
                           f"{d.cls}.{d.cond_base}" for d in self.decls.values()
                           if d.cond_base is not None}))
        return {
            "nodes": [{"id": n, "kind": self.node_kind(n)} for n in nodes],
            "edges": [{"src": e.src, "dst": e.dst, "path": e.path,
                       "line": e.line, "via": e.via}
                      for e in sorted(self.edges, key=lambda e: (e.src, e.dst))],
        }

    def to_dot(self) -> str:
        doc = self.to_json()
        lines = ["digraph lock_order {", '  rankdir=LR;',
                 '  node [shape=box, fontname="monospace"];']
        for n in doc["nodes"]:
            style = ' style=rounded' if n["kind"] == "RLock" else ""
            lines.append(f'  "{n["id"]}" [label="{n["id"]}\\n({n["kind"]})"{style}];')
        for e in doc["edges"]:
            lines.append(f'  "{e["src"]}" -> "{e["dst"]}" '
                         f'[label="{e["via"]}\\n{e["path"].rsplit("/", 1)[-1]}:{e["line"]}"];')
        lines.append("}")
        return "\n".join(lines) + "\n"


def _find_cycles(adj: Dict[str, List[Edge]]) -> List[List[Edge]]:
    """Distinct simple cycles via DFS back-edge detection (one per back edge)."""
    cycles: List[List[Edge]] = []
    seen_cycles: Set[Tuple[str, ...]] = set()
    state: Dict[str, int] = {}  # 0/absent=white, 1=gray, 2=black
    stack: List[Edge] = []

    def dfs(node: str) -> None:
        state[node] = 1
        for e in adj.get(node, []):
            if state.get(e.dst, 0) == 1:
                idx = next(i for i, se in enumerate(stack) if se.src == e.dst)
                cyc = stack[idx:] + [e]
                key = tuple(sorted(se.src for se in cyc))
                if key not in seen_cycles:
                    seen_cycles.add(key)
                    cycles.append(cyc)
            elif state.get(e.dst, 0) == 0:
                stack.append(e)
                dfs(e.dst)
                stack.pop()
        state[node] = 2

    for node in list(adj):
        if state.get(node, 0) == 0:
            dfs(node)
    return cycles


def analyze_files(paths: Sequence[str]) -> Tuple[LockOrder, List[Finding]]:
    graph = LockOrder([SourceFile.load(p) for p in paths])
    graph.build()
    return graph, graph.check()


def load_static_edges(graph_json_path: str) -> Set[Tuple[str, str]]:
    """Edge set from a committed lock_order.json, for the runtime witness."""
    with open(graph_json_path) as f:
        doc = json.load(f)
    return {(e["src"], e["dst"]) for e in doc["edges"]}
