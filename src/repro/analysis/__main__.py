"""CLI for the static-analysis tier: ``python -m repro.analysis``.

Runs locklint + lockorder + kernelcheck over the serving stack, prints every
finding (suppressed ones tagged with their reason), and exits nonzero if any
finding is unsuppressed.  ``--emit-graph DIR`` regenerates the lock-order
artifacts (``lock_order.json`` / ``lock_order.dot``); ``--check-graph FILE``
fails if the committed JSON artifact is stale relative to the tree.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List

from .common import Finding, SourceFile, render_report, unsuppressed
from .kernelcheck import KernelCheck
from .locklint import LockLint
from .lockorder import LockOrder

#: the concurrency surface: every module that creates or takes a lock
CONCURRENCY_MODULES = (
    "src/repro/core/router.py",
    "src/repro/core/telemetry.py",
    "src/repro/core/tracing.py",
    "src/repro/serving/scheduler.py",
    "src/repro/serving/engine.py",
    "src/repro/serving/prefix_cache.py",
)


def repo_root() -> Path:
    here = Path(__file__).resolve()
    for parent in here.parents:
        if (parent / "src" / "repro").is_dir() and (parent / "ROADMAP.md").exists():
            return parent
    return here.parents[3]


def load_concurrency_sources(root: Path) -> List[SourceFile]:
    """Load the concurrency modules with repo-relative paths, so findings and
    the committed graph artifact are machine-independent."""
    out = []
    for rel in CONCURRENCY_MODULES:
        p = root / rel
        if p.exists():
            out.append(SourceFile.from_text(rel, p.read_text()))
    return out


def run_all(root: Path, only: List[str]) -> tuple:
    """(findings, LockOrder graph) for the requested analyzers."""
    findings: List[Finding] = []
    sources = load_concurrency_sources(root)
    graph = None
    if "locklint" in only:
        findings += LockLint(sources).run()
    if "lockorder" in only:
        graph = LockOrder(sources)
        graph.build()
        findings += graph.check()
    if "kernelcheck" in only:
        findings += KernelCheck(str(root / "src/repro/kernels"),
                                str(root / "tests")).run()
    return findings, graph


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.analysis", description=__doc__)
    ap.add_argument("--only", default="locklint,lockorder,kernelcheck",
                    help="comma-separated analyzer subset")
    ap.add_argument("--json", action="store_true", help="machine-readable output")
    ap.add_argument("--emit-graph", metavar="DIR",
                    help="write lock_order.json + lock_order.dot into DIR")
    ap.add_argument("--check-graph", metavar="FILE",
                    help="fail if FILE differs from the freshly-extracted graph")
    ap.add_argument("--root", default=None, help="repo root (default: auto)")
    args = ap.parse_args(argv)

    root = Path(args.root).resolve() if args.root else repo_root()
    only = [t.strip() for t in args.only.split(",") if t.strip()]
    findings, graph = run_all(root, only)

    rc = 0
    if args.emit_graph or args.check_graph:
        if graph is None:
            graph = LockOrder(load_concurrency_sources(root))
            graph.build()
        doc = json.dumps(graph.to_json(), indent=2, sort_keys=True) + "\n"
        if args.emit_graph:
            out = Path(args.emit_graph)
            out.mkdir(parents=True, exist_ok=True)
            (out / "lock_order.json").write_text(doc)
            (out / "lock_order.dot").write_text(graph.to_dot())
            print(f"lock-order graph: {out}/lock_order.{{json,dot}} "
                  f"({len(graph.edges)} edges)", file=sys.stderr)
        if args.check_graph:
            committed = Path(args.check_graph)
            if not committed.exists() or committed.read_text() != doc:
                print(f"lock-order artifact {committed} is stale; regenerate with "
                      f"`python -m repro.analysis --emit-graph {committed.parent}`",
                      file=sys.stderr)
                rc = 1

    live = unsuppressed(findings)
    if args.json:
        print(json.dumps([f.to_json() for f in findings], indent=2))
    else:
        report = render_report(findings, show_suppressed=True)
        if report:
            print(report)
        n_sup = len(findings) - len(live)
        print(f"repro.analysis: {len(live)} finding(s), {n_sup} suppressed "
              f"({', '.join(only)})", file=sys.stderr)
    return 1 if live else rc


if __name__ == "__main__":
    raise SystemExit(main())
