"""Runtime lock-order witness: instrumented Lock/RLock wrappers.

The static graph in ``lockorder.py`` is an over-approximation of what *may*
happen; this module records what *does* happen.  Wrap the runtime's locks with
:class:`LockWitness` before a concurrency soak, run the soak, then call
``assert_consistent(static_edges)``:

- observed acquisition orders must themselves be acyclic (an A-under-B and
  B-under-A pair observed at runtime is an inversion even if the soak got
  lucky and never deadlocked), and
- combined with the static graph they must stay acyclic -- an observed edge
  whose reverse is statically possible is a latent deadlock.

Static analysis proposes, the witness disposes.

Wrappers are drop-in: they support the context-manager protocol,
``acquire(blocking, timeout)``/``release``, and the private hooks
``threading.Condition`` uses (``_is_owned``/``_release_save``/
``_acquire_restore``), so a ``Condition`` built on a witnessed lock keeps
working -- including the release-reacquire dance inside ``wait()``, which the
witness tracks as a real release and a real (ordered) re-acquire.

Instance names may carry an ``[instance]`` suffix (``Backend.cond[FLASK]``);
it distinguishes instances for cycle detection (holding one backend's
condition while taking another's is an ordering hazard even though the static
graph has a single ``Backend.cond`` node) and is stripped when comparing
against static node ids.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Set, Tuple


def base_name(name: str) -> str:
    """Strip the ``[instance]`` suffix: ``Backend.cond[FLASK]`` -> ``Backend.cond``."""
    return name.split("[", 1)[0]


@dataclass
class ObservedEdge:
    src: str
    dst: str
    count: int = 0
    thread: str = ""


class LockWitness:
    """Registry of witnessed locks + the acquisition-order edges they record."""

    def __init__(self) -> None:
        self._mu = threading.Lock()
        self._edges: Dict[Tuple[str, str], ObservedEdge] = {}
        self._acquires: Dict[str, int] = {}
        self._tls = threading.local()

    # -- wrapping -----------------------------------------------------------
    def wrap(self, name: str, *, reentrant: bool = False) -> "WitnessedLock":
        return WitnessedLock(self, name, reentrant=reentrant)

    # -- recording (called by WitnessedLock) --------------------------------
    def _held_stack(self) -> List[str]:
        stack = getattr(self._tls, "stack", None)
        if stack is None:
            stack = []
            self._tls.stack = stack
        return stack

    def on_acquired(self, name: str) -> None:
        stack = self._held_stack()
        with self._mu:
            self._acquires[name] = self._acquires.get(name, 0) + 1
        if stack:
            with self._mu:
                for held in stack:
                    key = (held, name)
                    e = self._edges.get(key)
                    if e is None:
                        e = self._edges[key] = ObservedEdge(held, name)
                    e.count += 1
                    e.thread = threading.current_thread().name
        stack.append(name)

    def on_released(self, name: str) -> None:
        stack = self._held_stack()
        # release may be out of LIFO order (hand-over-hand): drop the most
        # recent matching entry.
        for i in range(len(stack) - 1, -1, -1):
            if stack[i] == name:
                del stack[i]
                return

    # -- results ------------------------------------------------------------
    def acquire_counts(self) -> Dict[str, int]:
        """Outermost acquisitions seen per lock name — lets a soak assert it
        actually exercised the witnessed locks even when no nesting (and so
        no edge) was ever observed."""
        with self._mu:
            return dict(self._acquires)

    def edges(self) -> List[ObservedEdge]:
        with self._mu:
            return sorted(self._edges.values(), key=lambda e: (e.src, e.dst))

    def edge_set(self, *, strip_instances: bool = False) -> Set[Tuple[str, str]]:
        out = set()
        for e in self.edges():
            if strip_instances:
                out.add((base_name(e.src), base_name(e.dst)))
            else:
                out.add((e.src, e.dst))
        return out

    def assert_consistent(
        self,
        static_edges: Optional[Iterable[Tuple[str, str]]] = None,
        *,
        reentrant: Iterable[str] = (),
    ) -> None:
        """Raise AssertionError on any observed inversion.

        ``static_edges`` are (src, dst) pairs from the static graph (base
        names).  ``reentrant`` lists base names whose self-edges are legal
        (RLocks).
        """
        observed = self.edge_set()
        reent = set(reentrant)
        for a, b in observed:
            if a == b and base_name(a) not in reent:
                raise AssertionError(f"witness: non-reentrant lock {a} re-acquired while held")
        cycle = _find_cycle({(a, b) for a, b in observed if a != b})
        if cycle:
            raise AssertionError(f"witness: runtime lock-order cycle: {' -> '.join(cycle)}")
        if static_edges is not None:
            static = {(a, b) for a, b in static_edges if a != b}
            stripped = {(base_name(a), base_name(b)) for a, b in observed
                        if base_name(a) != base_name(b)}
            combined = static | stripped
            cycle = _find_cycle(combined)
            if cycle:
                raise AssertionError(
                    "witness: observed order inverts the static lock-order graph: "
                    + " -> ".join(cycle))

    def unknown_edges(self, static_edges: Iterable[Tuple[str, str]]) -> Set[Tuple[str, str]]:
        """Observed orderings the static graph never predicted (informational:
        usually a sign the static extraction should learn a new call path)."""
        static = set(static_edges)
        return {e for e in self.edge_set(strip_instances=True)
                if e not in static and e[0] != e[1]}


def _find_cycle(edges: Set[Tuple[str, str]]) -> Optional[List[str]]:
    adj: Dict[str, List[str]] = {}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
    state: Dict[str, int] = {}
    path: List[str] = []

    def dfs(n: str) -> Optional[List[str]]:
        state[n] = 1
        path.append(n)
        for m in adj.get(n, []):
            if state.get(m, 0) == 1:
                return path[path.index(m):] + [m]
            if state.get(m, 0) == 0:
                got = dfs(m)
                if got:
                    return got
        path.pop()
        state[n] = 2
        return None

    for n in list(adj):
        if state.get(n, 0) == 0:
            got = dfs(n)
            if got:
                return got
    return None


class WitnessedLock:
    """Drop-in Lock/RLock wrapper that reports acquisition order.

    Reentrant mode tracks per-thread depth so only the outermost
    acquire/release record edges (matching RLock semantics).
    """

    def __init__(self, witness: LockWitness, name: str, *, reentrant: bool = False):
        self._witness = witness
        self.name = name
        self.reentrant = reentrant
        self._inner = threading.RLock() if reentrant else threading.Lock()
        self._tls = threading.local()

    def _depth(self) -> int:
        return getattr(self._tls, "depth", 0)

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            d = self._depth()
            if d == 0:
                self._witness.on_acquired(self.name)
            self._tls.depth = d + 1
        return got

    def release(self) -> None:
        d = self._depth()
        self._inner.release()
        self._tls.depth = max(0, d - 1)
        if self._tls.depth == 0:
            self._witness.on_released(self.name)

    def __enter__(self) -> "WitnessedLock":
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        inner = self._inner
        return inner.locked() if hasattr(inner, "locked") else self._depth() > 0

    # Condition-protocol hooks: Python's Condition falls back to calling
    # acquire/release when these are missing, but defining _is_owned avoids
    # its try-acquire probe (which would record a spurious self-edge).
    def _is_owned(self) -> bool:
        return self._depth() > 0

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"<WitnessedLock {self.name} reentrant={self.reentrant}>"


# ---------------------------------------------------------------------------
# One-line wiring helpers for the runtime's objects.  Call while the object
# is idle (before start()/first traffic).
# ---------------------------------------------------------------------------


def instrument_router(router, witness: LockWitness) -> None:
    """Witness the router registry lock and every backend's condition."""
    router._lock = witness.wrap("StraightLineRouter._lock")
    for tier, b in router.backends.items():
        lk = witness.wrap(f"Backend.cond[{getattr(tier, 'name', tier)}]")
        b.lock = lk
        b.cond = threading.Condition(lk)


def instrument_engine(engine, witness: LockWitness, name: str = "_EngineBase.lock") -> None:
    """Witness an InferenceEngine/PagedInferenceEngine coarse step RLock.

    The default name matches the static graph's node id (the lock is declared
    on both engine classes; the extractor collapses them onto their common
    base), so observed edges line up with ``load_static_edges`` output."""
    engine.lock = witness.wrap(name, reentrant=True)


def instrument_loop(loop, witness: LockWitness) -> None:
    """Witness an EngineLoop registry lock (and its engine's step lock)."""
    loop._lock = witness.wrap("EngineLoop._lock")
    instrument_engine(loop.engine, witness)


def instrument_sampler(sampler, witness: LockWitness) -> None:
    sampler._lock = witness.wrap("MonitorSampler._lock")


def instrument_tracer(tracer, witness: LockWitness) -> None:
    tracer._lock = witness.wrap("Tracer._lock")
