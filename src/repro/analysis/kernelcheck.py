"""Kernel-family contract checker for ``src/repro/kernels/*``.

The repo's kernel layout rule (``kernels/__init__``): every family ships
``kernel.py`` (Pallas) + ``ref.py`` (pure-jnp oracle, possibly a re-export of
the model-side reference) + ``ops.py`` (model-facing wrapper), and a parity
test that imports both sides.  This checker enforces that, plus three Pallas
footguns that type-check fine and corrupt results on hardware:

- **in-place-no-alias**: a ``pallas_call`` whose ``out_shape`` mirrors an
  operand's ``(x.shape, x.dtype)`` is an in-place pool update and must declare
  ``input_output_aliases`` -- otherwise XLA materializes a full copy of the
  pool per step (or, with donation elsewhere, reads freed buffers).
- **traced-index-map**: ``jnp.*``/``jax.*`` calls inside a BlockSpec index-map
  lambda.  Index maps run at trace time over scalar-prefetch refs; a traced op
  there either fails at lowering or silently defeats prefetching.
- **shape-branch-in-kernel**: Python ``if``/``while`` on ``.shape`` inside a
  kernel body.  Shapes are static per bucket, so such branches bake the
  compiling bucket's decision into *every* bucket that shares the kernel --
  branch in the wrapper (``ops.py``) instead, where each shape re-traces.

Suppress a site with ``# kernelcheck: ok <reason>``.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .common import Finding, SourceFile, apply_suppression, dotted_name, unparse

TOOL = "kernelcheck"


@dataclass
class RefExports:
    """What a family's ref.py offers: local defs + re-exported (module, name)
    pairs, so a parity test may import either the ref module itself or the
    oracle the ref re-exports."""
    symbols: Set[str] = field(default_factory=set)
    origins: Set[Tuple[str, str]] = field(default_factory=set)  # (module, name)


def _ref_exports(src: SourceFile) -> RefExports:
    out = RefExports()
    for node in src.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.symbols.add(node.name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            for alias in node.names:
                out.symbols.add(alias.asname or alias.name)
                out.origins.add((node.module, alias.name))
        elif isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and not t.id.startswith("_"):
                    out.symbols.add(t.id)
    return out


@dataclass
class _TestImports:
    modules: Set[str] = field(default_factory=set)          # imported module paths
    from_names: Set[Tuple[str, str]] = field(default_factory=set)  # (module, name)


def _test_imports(src: SourceFile) -> _TestImports:
    out = _TestImports()
    for node in ast.walk(src.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                out.modules.add(alias.name)
        elif isinstance(node, ast.ImportFrom) and node.module:
            out.modules.add(node.module)
            for alias in node.names:
                out.from_names.add((node.module, alias.name))
    return out


class KernelCheck:
    def __init__(self, kernels_root: str, tests_root: str):
        self.kernels_root = Path(kernels_root)
        self.tests_root = Path(tests_root)
        self.findings: List[Finding] = []

    def run(self) -> List[Finding]:
        families = sorted(
            d for d in self.kernels_root.iterdir()
            if d.is_dir() and not d.name.startswith("_")
        )
        test_srcs = [SourceFile.load(p) for p in sorted(self.tests_root.glob("test_*.py"))]
        test_imports = [(s, _test_imports(s)) for s in test_srcs]
        for fam in families:
            self._check_family(fam, test_imports)
        return self.findings

    # -- per-family layout + parity-test checks -----------------------------
    def _check_family(self, fam: Path,
                      test_imports: List[Tuple[SourceFile, _TestImports]]) -> None:
        name = fam.name
        kernel_py = fam / "kernel.py"
        ref_py = fam / "ref.py"
        if not kernel_py.exists():
            self._raw(str(fam), 1, "missing-kernel", f"family {name} has no kernel.py")
            return
        ksrc = SourceFile.load(kernel_py)
        if not ref_py.exists():
            self._raw(str(kernel_py), 1, "missing-ref",
                      f"family {name} has no ref.py oracle to test parity against")
            exports = RefExports()
        else:
            rsrc = SourceFile.load(ref_py)
            exports = _ref_exports(rsrc)
            if not exports.symbols:
                self._report(rsrc, 1, "empty-ref",
                             f"family {name}: ref.py exports no symbols")

        fam_mod = f"repro.kernels.{name}"
        kernel_side = False
        ref_side = False
        for _, imps in test_imports:
            refs_kernel = any(
                m == fam_mod or m.startswith(fam_mod + ".") for m in imps.modules
            ) or any(m == fam_mod for m, _ in imps.from_names)
            refs_ref = (
                f"{fam_mod}.ref" in imps.modules
                or any(m == f"{fam_mod}.ref" for m, _ in imps.from_names)
                or any((m, n) in exports.origins for m, n in imps.from_names)
            )
            # a kernel-side reference must not be *only* the ref import
            refs_kernel_proper = any(
                m in (fam_mod, f"{fam_mod}.kernel", f"{fam_mod}.ops")
                or m.startswith(fam_mod + ".kernel") or m.startswith(fam_mod + ".ops")
                for m in imps.modules
            )
            if refs_kernel_proper:
                kernel_side = True
            if refs_ref and refs_kernel_proper:
                ref_side = True
        if ref_py.exists() and not ref_side:
            self._report(
                ksrc, 1, "missing-parity-test",
                f"family {name}: no test under {self.tests_root.name}/ imports both "
                f"the kernel/ops side and its ref oracle (parity is unguarded)",
            )
        elif not kernel_side:
            self._report(ksrc, 1, "missing-parity-test",
                         f"family {name}: no test imports the kernel at all")

        # -- Pallas footguns in kernel.py (and ops.py wrappers) -------------
        self._check_pallas(ksrc)
        ops_py = fam / "ops.py"
        if ops_py.exists():
            self._check_pallas(SourceFile.load(ops_py))

    def _check_pallas(self, src: SourceFile) -> None:
        kernel_bodies: Set[str] = set()
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) and _is_pallas_call(node):
                kernel_bodies |= self._check_one_call(src, node)
        if kernel_bodies:
            for node in src.tree.body:
                if isinstance(node, ast.FunctionDef) and node.name in kernel_bodies:
                    self._check_kernel_body(src, node)
        # index maps can appear anywhere a BlockSpec is built
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Call) and _callee_leaf(node) == "BlockSpec":
                for arg in list(node.args) + [kw.value for kw in node.keywords]:
                    if isinstance(arg, ast.Lambda):
                        self._check_index_map(src, arg)
                    elif isinstance(arg, ast.Name):
                        fn = _local_def(src, arg.id)
                        if fn is not None:
                            self._check_index_map(src, fn)

    def _check_one_call(self, src: SourceFile, call: ast.Call) -> Set[str]:
        """Check one pl.pallas_call(...) and return kernel-body names."""
        bodies: Set[str] = set()
        if call.args:
            first = call.args[0]
            if isinstance(first, ast.Name):
                bodies.add(first.id)
            elif isinstance(first, ast.Call) and _callee_leaf(first) == "partial":
                if first.args and isinstance(first.args[0], ast.Name):
                    bodies.add(first.args[0].id)

        kwargs = {kw.arg: kw.value for kw in call.keywords if kw.arg}
        has_aliases = "input_output_aliases" in kwargs

        inplace = _inplace_outputs(kwargs.get("out_shape"))
        if inplace and not has_aliases:
            self._report(
                src, call.lineno, "in-place-no-alias",
                f"pallas_call output(s) {sorted(inplace)} mirror operand shape/dtype "
                f"(in-place pool update) but declare no input_output_aliases; "
                f"XLA will copy the pool every step",
            )
        return bodies

    def _check_index_map(self, src: SourceFile, fn) -> None:
        body = fn.body if isinstance(fn, ast.Lambda) else fn
        for node in ast.walk(body if isinstance(body, ast.AST) else fn):
            if isinstance(node, ast.Call):
                name = dotted_name(node.func) or ""
                if name.startswith("jnp.") or name.startswith("jax."):
                    self._report(
                        src, node.lineno, "traced-index-map",
                        f"traced op {name}(...) inside a BlockSpec index map; "
                        f"index maps must be pure int arithmetic over "
                        f"scalar-prefetch refs",
                    )

    def _check_kernel_body(self, src: SourceFile, fn: ast.FunctionDef) -> None:
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                for sub in ast.walk(node.test):
                    if isinstance(sub, ast.Attribute) and sub.attr == "shape":
                        self._report(
                            src, node.lineno, "shape-branch-in-kernel",
                            f"shape-dependent Python branch on "
                            f"`{unparse(node.test)}` inside kernel body "
                            f"{fn.name}; branch in the ops.py wrapper instead",
                        )
                        break

    def _report(self, src: SourceFile, line: int, code: str, message: str) -> None:
        f = Finding(tool=TOOL, path=src.path, line=line, code=code, message=message)
        self.findings.append(apply_suppression(src, f))

    def _raw(self, path: str, line: int, code: str, message: str) -> None:
        self.findings.append(Finding(tool=TOOL, path=path, line=line,
                                     code=code, message=message))


def _callee_leaf(call: ast.Call) -> str:
    name = dotted_name(call.func) or ""
    return name.split(".")[-1]


def _is_pallas_call(call: ast.Call) -> bool:
    return _callee_leaf(call) == "pallas_call"


#: operand names that denote a persistent KV/state pool: an output declared as
#: ShapeDtypeStruct(<pool>.shape, <pool>.dtype) is an in-place pool update,
#: not a fresh result buffer (those mirror activations like q/x, not pools).
POOL_NAME = re.compile(r"(pool|cache|_kv|kv_|scales|state)", re.IGNORECASE)


def _inplace_outputs(out_shape: Optional[ast.AST]) -> Set[str]:
    """Pool-like operand names whose ShapeDtypeStruct(x.shape, x.dtype)
    appears in out_shape -- the in-place-update signature."""
    if out_shape is None:
        return set()
    hits: Set[str] = set()
    for node in ast.walk(out_shape):
        if not (isinstance(node, ast.Call) and _callee_leaf(node) == "ShapeDtypeStruct"):
            continue
        for arg in list(node.args) + [kw.value for kw in node.keywords]:
            if (isinstance(arg, ast.Attribute) and arg.attr in ("shape", "dtype")
                    and isinstance(arg.value, ast.Name)
                    and POOL_NAME.search(arg.value.id)):
                hits.add(arg.value.id)
    return hits


def _local_def(src: SourceFile, name: str) -> Optional[ast.FunctionDef]:
    for node in ast.walk(src.tree):
        if isinstance(node, ast.FunctionDef) and node.name == name:
            return node
    return None


def check(kernels_root: str, tests_root: str) -> List[Finding]:
    return KernelCheck(kernels_root, tests_root).run()
