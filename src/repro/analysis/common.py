"""Shared plumbing for the analyzers: findings, sources, suppressions.

Conventions enforced here and reused by every tool:

- A finding is pinned to (path, line) and carries a short ``code``; formatting
  is uniform so CI output greps the same way across analyzers.
- Inline suppressions are ``# <tool>: ok <reason>`` on the offending line.
  The reason is mandatory -- a bare ``# locklint: ok`` does *not* suppress, it
  converts the finding into a ``bad-suppression`` so unexplained exceptions
  can never land silently.
- Guarded-field declarations are ``# guarded by: <lock-attr>`` trailing the
  assignment (works for both ``self.x = ...`` in ``__init__`` and dataclass
  field lines), or a class-level ``_GUARDED = {"field": "_lock"}`` mapping.
"""

from __future__ import annotations

import ast
import io
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Sequence


@dataclass
class Finding:
    tool: str
    path: str
    line: int
    code: str
    message: str
    suppressed: bool = False
    reason: str = ""

    def format(self) -> str:
        tag = f"  [suppressed: {self.reason}]" if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.tool}/{self.code}: {self.message}{tag}"

    def to_json(self) -> dict:
        return {
            "tool": self.tool, "path": self.path, "line": self.line,
            "code": self.code, "message": self.message,
            "suppressed": self.suppressed, "reason": self.reason,
        }


@dataclass
class SourceFile:
    """A parsed module plus its comment map (line -> comment text sans '#')."""

    path: str
    text: str
    tree: ast.Module
    comments: Dict[int, str] = field(default_factory=dict)

    @classmethod
    def load(cls, path) -> "SourceFile":
        p = Path(path)
        text = p.read_text()
        return cls.from_text(str(p), text)

    @classmethod
    def from_text(cls, path: str, text: str) -> "SourceFile":
        tree = ast.parse(text, filename=path)
        return cls(path=path, text=text, tree=tree, comments=parse_comments(text))

    def comment_at(self, line: int) -> str:
        return self.comments.get(line, "")


def parse_comments(text: str) -> Dict[int, str]:
    """Map line number -> comment text, via tokenize so '#' inside strings
    never counts as a comment."""
    comments: Dict[int, str] = {}
    try:
        for tok in tokenize.generate_tokens(io.StringIO(text).readline):
            if tok.type == tokenize.COMMENT:
                comments[tok.start[0]] = tok.string.lstrip("#").strip()
    except tokenize.TokenError:  # unterminated block at EOF etc. -- best effort
        pass
    return comments


def suppression_reason(src: SourceFile, line: int, tool: str) -> Optional[str]:
    """Return the suppression reason on ``line`` for ``tool``, or None.

    An empty reason returns "" (caller must treat that as *not* suppressed and
    raise a bad-suppression finding instead).
    """
    comment = src.comment_at(line)
    marker = f"{tool}:"
    if not comment.startswith(marker):
        return None
    rest = comment[len(marker):].strip()
    if rest == "ok":
        return ""
    if rest.startswith("ok "):
        return rest[3:].strip()
    return None


def apply_suppression(src: SourceFile, finding: Finding) -> Finding:
    """Mark ``finding`` suppressed if its line carries a reasoned suppression;
    downgrade a reasonless suppression to a loud ``bad-suppression``."""
    reason = suppression_reason(src, finding.line, finding.tool)
    if reason is None:
        return finding
    if not reason:
        finding.code = "bad-suppression"
        finding.message += " (suppression comment present but missing a reason)"
        return finding
    finding.suppressed = True
    finding.reason = reason
    return finding


def unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:  # pragma: no cover - unparse is total on parsed trees
        return "<expr>"


def dotted_name(node: ast.AST) -> Optional[str]:
    """'a.b.c' for a pure Name/Attribute chain, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def guarded_decl(comment: str) -> Optional[str]:
    """Parse a ``# guarded by: <lock-attr>`` trailing comment."""
    marker = "guarded by:"
    if comment.startswith(marker):
        attr = comment[len(marker):].strip().split()[0] if comment[len(marker):].strip() else ""
        return attr or None
    return None


def load_sources(paths: Sequence[str]) -> List[SourceFile]:
    return [SourceFile.load(p) for p in paths]


def unsuppressed(findings: Sequence[Finding]) -> List[Finding]:
    return [f for f in findings if not f.suppressed]


def render_report(findings: Sequence[Finding], *, show_suppressed: bool = False) -> str:
    lines = [f.format() for f in findings if show_suppressed or not f.suppressed]
    return "\n".join(lines)
