"""Lock-discipline linter (stdlib-ast) for the concurrent serving runtime.

Two rules, both scoped by lightweight in-source declarations:

1. **Guarded fields.**  A field declared ``# guarded by: _lock`` on its
   assignment line (or listed in a class-level ``_GUARDED`` dict) may only be
   touched lexically inside ``with self._lock`` (holding the Condition built
   on a lock counts as holding the lock).  Exemptions: ``__init__`` /
   ``__post_init__`` (happens-before publication), methods named ``*_locked``
   (documented caller-holds-the-lock helpers), and reasoned inline
   suppressions.  Cross-object accesses (``b.queue`` from the router) are
   checked too when the field name is unambiguous across analyzed classes.

2. **No blocking under a strict lock.**  While a lock is held, calls that can
   block -- ``Condition.wait`` (on a *different* primitive), ``Future.result``,
   ``Thread.join``, ``time.sleep`` -- and jit/device dispatch
   (``step``/``generate``/``prefill*``/``decode*``/``verify*``/
   ``block_until_ready``) are findings.  This is what makes the engine's
   "never block the step-loop registry lock" rule and ``capacity_now()``'s
   lock-free-snapshot contract machine-checked.  A lock whose *contract* is to
   be held across device work (the engine's coarse step RLock: one stepper
   owns the donated buffers) opts out once, visibly, at its declaration with
   ``# locklint: blocking-ok <reason>``.

Suppress a single site with ``# locklint: ok <reason>``.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .common import (
    Finding,
    SourceFile,
    apply_suppression,
    dotted_name,
    guarded_decl,
    unparse,
)

TOOL = "locklint"

LOCK_FACTORIES = {"Lock", "RLock", "Condition", "Semaphore", "BoundedSemaphore"}
#: attribute names treated as lock-like even without a visible declaration
LOCKISH = re.compile(r"(^|_)(lock|cond|mutex)$")
BLOCKING_ATTRS = {"wait", "join", "result"}
#: jit/device dispatch: the names the serving stack uses for compiled calls
DEVICE_DISPATCH = re.compile(
    r"^(step|step_once|generate|block_until_ready|device_put"
    r"|_?prefill\w*|_?decode\w*|_?verify\w*|_install_carry|_copy_fork)$"
)


@dataclass
class LockDecl:
    cls: str               # owning class name ("" for module-level)
    attr: str              # attribute name on the instance
    kind: str              # Lock | RLock | Condition | ...
    line: int
    policy: str = "strict"          # strict | blocking-ok
    policy_reason: str = ""
    cond_base: Optional[str] = None  # for Condition(self.X): the lock attr X


@dataclass
class ClassLocks:
    name: str
    bases: List[str] = field(default_factory=list)
    locks: Dict[str, LockDecl] = field(default_factory=dict)
    guarded: Dict[str, str] = field(default_factory=dict)  # field -> lock attr

    def lock_group(self, attr: str) -> Set[str]:
        """All attr names equivalent to holding ``attr`` (a Condition and its
        base lock are the same underlying mutex)."""
        group = {attr}
        decl = self.locks.get(attr)
        if decl and decl.cond_base:
            group.add(decl.cond_base)
        for other in self.locks.values():
            if other.cond_base and other.cond_base in group:
                group.add(other.attr)
        return group


def collect_lock_decls(sources: Sequence[SourceFile]) -> Dict[str, ClassLocks]:
    """First pass over all modules: lock declarations, policies, guarded
    fields.  Keyed by class name (assumed unique across the analyzed set --
    true for this repo, and ambiguity would only widen checks)."""
    classes: Dict[str, ClassLocks] = {}
    for src in sources:
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.ClassDef):
                continue
            info = classes.setdefault(node.name, ClassLocks(node.name))
            for b in node.bases:
                base = dotted_name(b)
                if base:
                    info.bases.append(base.split(".")[-1])
            _collect_class(src, node, info)
    return classes


def class_families(classes: Dict[str, ClassLocks]) -> Dict[str, Set[str]]:
    """Union-find over inheritance among analyzed classes: ``self.lock`` in a
    base class resolves against declarations made anywhere in its family
    (e.g. ``_EngineBase`` methods use the RLock its subclasses create)."""
    parent: Dict[str, str] = {n: n for n in classes}

    def find(x: str) -> str:
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for name, info in classes.items():
        for b in info.bases:
            if b in classes:
                parent[find(name)] = find(b)
    families: Dict[str, Set[str]] = {}
    for name in classes:
        families.setdefault(find(name), set()).add(name)
    return {name: families[find(name)] for name in classes}


def family_lock_decls(classes: Dict[str, ClassLocks],
                      families: Dict[str, Set[str]],
                      cls_name: str, attr: str) -> List[LockDecl]:
    """All declarations of ``self.<attr>`` visible to ``cls_name`` through its
    inheritance family, declaring-class-sorted for determinism."""
    out = []
    for member in sorted(families.get(cls_name, {cls_name})):
        info = classes.get(member)
        if info is not None and attr in info.locks:
            out.append(info.locks[attr])
    return out


def _collect_class(src: SourceFile, cls: ast.ClassDef, info: ClassLocks) -> None:
    for stmt in cls.body:
        # class-level _GUARDED = {"field": "_lock"}
        if isinstance(stmt, ast.Assign) and any(
            isinstance(t, ast.Name) and t.id == "_GUARDED" for t in stmt.targets
        ):
            if isinstance(stmt.value, ast.Dict):
                for k, v in zip(stmt.value.keys, stmt.value.values):
                    if isinstance(k, ast.Constant) and isinstance(v, ast.Constant):
                        info.guarded[str(k.value)] = str(v.value)
        # dataclass field line: queue: Deque = field(...)  # guarded by: cond
        if isinstance(stmt, (ast.AnnAssign, ast.Assign)):
            target = stmt.target if isinstance(stmt, ast.AnnAssign) else (
                stmt.targets[0] if stmt.targets else None
            )
            if isinstance(target, ast.Name):
                lock_attr = guarded_decl(src.comment_at(stmt.lineno))
                if lock_attr:
                    info.guarded[target.id] = lock_attr

    for fn in [n for n in ast.walk(cls) if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        for stmt in ast.walk(fn):
            if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                target = stmt.target if isinstance(stmt, ast.AnnAssign) else (
                    stmt.targets[0] if len(getattr(stmt, "targets", [])) == 1 else None
                )
                if not (
                    isinstance(target, ast.Attribute)
                    and isinstance(target.value, ast.Name)
                    and target.value.id == "self"
                ):
                    continue
                attr = target.attr
                value = stmt.value
                decl = _lock_factory(value)
                if decl is not None:
                    kind, cond_base = decl
                    ld = LockDecl(cls=info.name, attr=attr, kind=kind,
                                  line=stmt.lineno, cond_base=cond_base)
                    comment = src.comment_at(stmt.lineno)
                    if comment.startswith(f"{TOOL}: blocking-ok"):
                        ld.policy = "blocking-ok"
                        ld.policy_reason = comment[len(f"{TOOL}: blocking-ok"):].strip()
                    info.locks[attr] = ld
                else:
                    lock_attr = guarded_decl(src.comment_at(stmt.lineno))
                    if lock_attr:
                        info.guarded[attr] = lock_attr


def _lock_factory(value: Optional[ast.AST]) -> Optional[Tuple[str, Optional[str]]]:
    """(kind, condition_base_attr) when ``value`` constructs a threading
    primitive, else None."""
    if not isinstance(value, ast.Call):
        return None
    name = dotted_name(value.func) or ""
    leaf = name.split(".")[-1]
    if leaf not in LOCK_FACTORIES:
        return None
    cond_base = None
    if leaf == "Condition" and value.args:
        arg = value.args[0]
        if (isinstance(arg, ast.Attribute) and isinstance(arg.value, ast.Name)
                and arg.value.id == "self"):
            cond_base = arg.attr
    return leaf, cond_base


@dataclass
class _Held:
    expr: str            # source text of the with item, e.g. "self._lock", "b.cond"
    base: str            # "self" / "b" / ...
    attr: str            # "_lock" / "cond"
    policy: str          # strict | blocking-ok


class _FunctionLinter(ast.NodeVisitor):
    """Walks one method body tracking the lexically-held lock set."""

    def __init__(self, analyzer: "LockLint", src: SourceFile,
                 cls: Optional[ClassLocks], fn: ast.FunctionDef):
        self.a = analyzer
        self.src = src
        self.cls = cls
        self.fn = fn
        self.held: List[_Held] = []
        self.reported: Set[Tuple[int, str]] = set()
        self.exempt_guarded = (
            fn.name in ("__init__", "__post_init__")
            or fn.name.endswith("_locked")
        )

    # -- lock scope tracking ------------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        pushed = 0
        for item in node.items:
            held = self.a.as_lock(item.context_expr, self.cls)
            if held is not None:
                self.held.append(held)
                pushed += 1
        for stmt in node.body:
            self.visit(stmt)
        for _ in range(pushed):
            self.held.pop()

    visit_AsyncWith = visit_With  # pragma: no cover - no async in the stack

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        # a nested def runs later, outside this lexical lock scope
        saved, self.held = self.held, []
        for stmt in node.body:
            self.visit(stmt)
        self.held = saved

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        saved, self.held = self.held, []
        self.visit(node.body)
        self.held = saved

    # -- rule 1: guarded fields --------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        self.generic_visit(node)
        base = unparse(node.value)
        lock_attr, owner = self.a.guard_for(base, node.attr, self.cls)
        if lock_attr is None or self.exempt_guarded:
            return
        group = owner.lock_group(lock_attr)
        if any(h.base == base and h.attr in group for h in self.held):
            return
        key = (node.lineno, f"guard:{base}.{node.attr}")
        if key in self.reported:
            return
        self.reported.add(key)
        self.a.report(
            self.src, node.lineno, "guarded-field",
            f"{base}.{node.attr} is guarded by {base}.{lock_attr} "
            f"(declared on {owner.name}) but accessed outside `with {base}.{lock_attr}` "
            f"in {self._where()}",
        )

    # -- rule 2: blocking under a strict lock ------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        self.generic_visit(node)
        strict = [h for h in self.held if h.policy == "strict"]
        if not strict:
            return
        label = self._blocking_label(node)
        if label is None:
            return
        key = (node.lineno, f"block:{label}")
        if key in self.reported:
            return
        self.reported.add(key)
        held_desc = ", ".join(h.expr for h in strict)
        self.a.report(
            self.src, node.lineno, "blocking-under-lock",
            f"{label} while holding {held_desc} in {self._where()}; "
            f"a strict lock must never be held across blocking or device work",
        )

    def _blocking_label(self, node: ast.Call) -> Optional[str]:
        func = node.func
        if isinstance(func, ast.Name):
            return "time.sleep(...)" if func.id == "sleep" else None
        if not isinstance(func, ast.Attribute):
            return None
        dotted = dotted_name(func) or ""
        if dotted == "time.sleep":
            return "time.sleep(...)"
        attr = func.attr
        if attr in BLOCKING_ATTRS:
            if attr == "wait":
                # waiting on the condition you hold releases it: the one
                # legal blocking wait under a lock.
                base = unparse(func.value)
                if any(base == h.expr for h in self.held):
                    return None
            return f"blocking call .{attr}(...)"
        if DEVICE_DISPATCH.match(attr):
            return f"device dispatch .{attr}(...)"
        return None

    def _where(self) -> str:
        owner = f"{self.cls.name}." if self.cls else ""
        return f"{owner}{self.fn.name}"


class LockLint:
    def __init__(self, sources: Sequence[SourceFile]):
        self.sources = list(sources)
        self.classes = collect_lock_decls(self.sources)
        self.families = class_families(self.classes)
        # field name -> owning ClassLocks, for cross-object checks; ambiguous
        # names (declared guarded in >1 class) are dropped rather than guessed.
        counts: Dict[str, List[ClassLocks]] = {}
        for info in self.classes.values():
            for f in info.guarded:
                counts.setdefault(f, []).append(info)
        self.global_guarded = {f: owners[0] for f, owners in counts.items()
                               if len(owners) == 1}
        self.findings: List[Finding] = []
        self._src: Optional[SourceFile] = None
        self._cls_stack: List[Optional[ClassLocks]] = []

    # -- declaration lookups ------------------------------------------------
    def as_lock(self, expr: ast.AST, cls: Optional[ClassLocks]) -> Optional[_Held]:
        """Classify a with-item as a held lock, resolving its policy."""
        if not isinstance(expr, ast.Attribute):
            return None
        base = unparse(expr.value)
        attr = expr.attr
        decls: List[LockDecl] = []
        if base == "self" and cls is not None:
            decls = family_lock_decls(self.classes, self.families, cls.name, attr)
        if not decls:
            owners = [c.locks[attr] for c in self.classes.values() if attr in c.locks]
            if len(owners) == 1:
                decls = owners
        if not decls and not LOCKISH.search(attr):
            return None
        # a lock is only blocking-ok if every declaration in scope says so
        policy = ("blocking-ok"
                  if decls and all(d.policy == "blocking-ok" for d in decls)
                  else "strict")
        return _Held(expr=unparse(expr), base=base, attr=attr, policy=policy)

    def guard_for(self, base: str, attr: str,
                  cls: Optional[ClassLocks]) -> Tuple[Optional[str], Optional[ClassLocks]]:
        if base == "self" and cls is not None:
            for member in sorted(self.families.get(cls.name, {cls.name})):
                info = self.classes.get(member)
                if info is not None and attr in info.guarded:
                    return info.guarded[attr], info
            return None, None
        owner = self.global_guarded.get(attr)
        if owner is not None and owner is not cls:
            return owner.guarded[attr], owner
        return None, None

    # -- driving ------------------------------------------------------------
    def run(self) -> List[Finding]:
        for src in self.sources:
            self._src = src
            self._lint_module(src)
        return self.findings

    def _lint_module(self, src: SourceFile) -> None:
        for node in src.tree.body:
            self._lint_node(src, node, cls=None)

    def _lint_node(self, src: SourceFile, node: ast.AST, cls: Optional[ClassLocks]) -> None:
        if isinstance(node, ast.ClassDef):
            info = self.classes.get(node.name)
            for child in node.body:
                self._lint_node(src, child, cls=info)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            linter = _FunctionLinter(self, src, cls, node)
            for stmt in node.body:
                linter.visit(stmt)

    def report(self, src: SourceFile, line: int, code: str, message: str) -> None:
        f = Finding(tool=TOOL, path=src.path, line=line, code=code, message=message)
        self.findings.append(apply_suppression(src, f))


def lint_files(paths: Sequence[str]) -> List[Finding]:
    return LockLint([SourceFile.load(p) for p in paths]).run()
