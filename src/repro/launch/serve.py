"""Serving launcher: StraightLine router over live engine tiers.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 32 [--F 10] [--D 4096] [--weights-int8] \
        [--workers 4] [--prewarm]

``--workers N`` runs the concurrent router runtime (N worker threads per
tier, bounded by each tier's capacity); 0 keeps the serial poll loop.
``--prewarm`` compiles every prefill bucket at startup so the first request
of each shape pays a warm dispatch instead of an XLA compile — and, because
the placer reads warm-up state (compile_events / total_buckets) through
each backend's ``stats_fn``, a prewarmed tier attracts traffic while a cold
one is still compiling.
"""
from __future__ import annotations

import argparse
import threading
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--F", type=float, default=10.0, help="frequency threshold")
    ap.add_argument("--D", type=float, default=4096.0, help="data-size threshold (bytes)")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--weights-int8", action="store_true")
    ap.add_argument("--hedge-after", type=float, default=None)
    ap.add_argument("--workers", type=int, default=0,
                    help="worker threads per tier (0 = serial poll loop)")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile all prefill buckets before accepting traffic")
    args = ap.parse_args()

    import numpy as np

    from repro.configs.registry import get_config
    from repro.core import Request, StraightLinePolicy, Thresholds, Tier
    from repro.core.router import Backend, StraightLineRouter
    from repro.models.quant import quantize_params
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_config(args.arch, smoke=True).replace(attn_chunk=64)
    t0 = time.time()
    interactive = InferenceEngine(cfg, EngineConfig(max_slots=1, max_len=96, max_new_tokens=args.max_new_tokens))
    params = interactive.params
    if args.weights_int8:
        cfg_q = cfg.replace(weights_int8=True)
        params = quantize_params(params)
        interactive = InferenceEngine(cfg_q, EngineConfig(max_slots=1, max_len=96, max_new_tokens=args.max_new_tokens), params=params)
        cfg = cfg_q
    batch_tier = InferenceEngine(cfg, EngineConfig(max_slots=4, max_len=96, max_new_tokens=args.max_new_tokens), params=params)
    print(f"tiers ready in {time.time()-t0:.1f}s (weights_int8={args.weights_int8})")

    if args.prewarm:
        t = time.time()
        for name, eng in (("interactive", interactive), ("batch", batch_tier)):
            warmed = eng.prewarm()
            snap = eng.capacity_now()
            print(
                f"  prewarmed {name}: buckets {warmed} "
                f"({snap['compile_events']}/{snap['total_buckets']} shapes warm)"
            )
        print(f"  prewarm took {time.time()-t:.1f}s")

    elastic: list = []
    elastic_lock = threading.Lock()

    def run_on(engine):
        def run(req):
            prompt = list(np.random.default_rng(req.rid).integers(1, cfg.vocab_size, 8))
            return engine.generate([prompt])[0].out
        return run

    def elastic_run(req):
        with elastic_lock:             # one cold start even under concurrency
            if not elastic:
                t = time.time()
                elastic.append(InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=96, max_new_tokens=args.max_new_tokens), params=params))
                print(f"  [elastic cold start {time.time()-t:.1f}s]")
        return run_on(elastic[0])(req)

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, run_on(interactive), capacity=1, queue_cap=8,
                                stats_fn=interactive.capacity_now),
            Tier.DOCKER: Backend(Tier.DOCKER, run_on(batch_tier), capacity=4, queue_cap=64,
                                 stats_fn=batch_tier.capacity_now),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, elastic_run, capacity=16),
        },
        policy=StraightLinePolicy(Thresholds(F=args.F, D=args.D)),
        window_s=10.0,
        hedge_after_s=args.hedge_after,
    )
    if args.workers > 0:
        router.start(args.workers)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        size = float(rng.choice([512.0, 16384.0], p=[0.8, 0.2]))
        router.submit(Request(rid=i, arrival_t=0.0, data_size=size, timeout_s=300.0))
    router.drain()
    wall = time.time() - t0
    if args.workers > 0:
        router.stop()
    m = router.metrics
    by_tier = {t.name: sum(1 for r in m.completed if r.tier == t) for t in Tier}
    mode = f"{args.workers} workers/tier" if args.workers > 0 else "serial poll loop"
    print(f"{args.requests} requests in {wall:.1f}s ({mode}): {m.summary()}")
    print(f"placement: {by_tier}")


if __name__ == "__main__":
    main()
