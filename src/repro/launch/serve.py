"""Serving launcher: StraightLine router over live engine tiers.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m \
        --requests 32 [--F 10] [--D 4096] [--weights-int8] \
        [--workers 4] [--prewarm] [--serialized]

``--workers N`` runs the concurrent router runtime (N worker threads per
tier, bounded by each tier's capacity); 0 keeps the serial poll loop.
``--chunk-tokens N`` enables chunked prefill on every engine tier: prompts
are absorbed N tokens per step under ``--step-budget`` (0 = auto,
2*chunk), so a long prompt landing on the interactive tier no longer
stalls every decoding slot for its whole prefill (see
benchmarks/chunked_prefill.py); 0 keeps whole-prompt prefill.
Engine tiers serve through continuous-batching step loops
(``serving.scheduler.EngineLoop``): router workers submit into a shared
per-engine loop and block on per-request futures, so concurrent requests
interleave inside one decode batch instead of serializing whole generations
on the engine lock (``--serialized`` restores the lock-holding ``generate``
path as a baseline). ``--prewarm`` compiles every prefill bucket at startup
so the first request of each shape pays a warm dispatch instead of an XLA
compile — and, because the placer reads warm-up state (compile_events /
total_buckets, weighted by the measured compile-cost EMA) through each
backend's ``stats_fn``, a prewarmed tier attracts traffic while a cold one
is still compiling.

Observability: ``--trace-out trace.json`` records every request's lifecycle
(placement inputs, queue wait, execution, hedges, per-token decode stamps)
and writes Chrome trace-event JSON — load it in Perfetto / chrome://tracing;
one process per request, one thread per lane. ``--metrics-interval S``
starts a ``MonitorSampler`` sweeping every tier's ``capacity_now`` probe
into per-tier time series at that period; ``--metrics-out metrics.prom``
dumps the process metrics registry (request counters, queue-wait / TTFT /
inter-token histograms, sampled tier gauges) in Prometheus text format at
exit. All of it is off (and costs nothing) unless the flags are given.
"""
from __future__ import annotations

import argparse
import threading
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--F", type=float, default=10.0, help="frequency threshold")
    ap.add_argument("--D", type=float, default=4096.0, help="data-size threshold (bytes)")
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--weights-int8", action="store_true")
    ap.add_argument("--hedge-after", type=float, default=None)
    ap.add_argument("--workers", type=int, default=0,
                    help="worker threads per tier (0 = serial poll loop)")
    ap.add_argument("--prewarm", action="store_true",
                    help="compile all prefill buckets before accepting traffic")
    ap.add_argument("--serialized", action="store_true",
                    help="bypass the engine step loops (lock-holding generate baseline)")
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="chunked prefill chunk size in tokens (0 = whole-prompt "
                         "prefill; MoE archs: expert capacity competes per CHUNK, "
                         "so greedy outputs can differ from whole-prompt prefill "
                         "when capacity binds — use 0 for exact parity there)")
    ap.add_argument("--step-budget", type=int, default=0,
                    help="per-step prefill+decode token budget (0 = auto)")
    ap.add_argument("--trace-out", default=None,
                    help="write per-request Chrome trace-event JSON here "
                         "(Perfetto-loadable); omit to disable tracing")
    ap.add_argument("--metrics-interval", type=float, default=0.0,
                    help="MonitorSampler period in seconds (0 = off): sweeps "
                         "every tier's capacity_now probe into time series")
    ap.add_argument("--metrics-out", default=None,
                    help="write the metrics registry as Prometheus text here")
    args = ap.parse_args()

    import numpy as np

    from repro.configs.registry import get_config
    from repro.core import (
        CapacityGauge,
        MonitorSampler,
        Request,
        StraightLinePolicy,
        Thresholds,
        Tier,
        Tracer,
        default_registry,
    )
    from repro.core.router import Backend, StraightLineRouter
    from repro.models.quant import quantize_params
    from repro.serving.engine import EngineConfig, InferenceEngine
    from repro.serving.scheduler import EngineLoop

    cfg = get_config(args.arch, smoke=True).replace(attn_chunk=64)

    def ecfg(slots):
        return EngineConfig(
            max_slots=slots, max_len=96, max_new_tokens=args.max_new_tokens,
            chunk_tokens=args.chunk_tokens, step_token_budget=args.step_budget,
        )

    t0 = time.time()
    interactive = InferenceEngine(cfg, ecfg(1))
    params = interactive.params
    if args.weights_int8:
        cfg_q = cfg.replace(weights_int8=True)
        params = quantize_params(params)
        interactive = InferenceEngine(cfg_q, ecfg(1), params=params)
        cfg = cfg_q
    batch_tier = InferenceEngine(cfg, ecfg(4), params=params)
    print(f"tiers ready in {time.time()-t0:.1f}s (weights_int8={args.weights_int8})")

    if args.prewarm:
        t = time.time()
        for name, eng in (("interactive", interactive), ("batch", batch_tier)):
            warmed = eng.prewarm()
            snap = eng.capacity_now()
            print(
                f"  prewarmed {name}: buckets {warmed} "
                f"({snap['compile_events']}/{snap['total_buckets']} shapes warm)"
            )
        print(f"  prewarm took {time.time()-t:.1f}s")

    tracer = Tracer() if args.trace_out else None
    gauge = CapacityGauge()
    sampler = None
    if args.metrics_interval > 0:
        sampler = MonitorSampler(
            gauge, interval_s=args.metrics_interval, registry=default_registry()
        )

    elastic: list = []
    elastic_lock = threading.Lock()

    def prompt_for(req):
        return list(np.random.default_rng(req.rid).integers(1, cfg.vocab_size, 8))

    def run_on(engine):
        def run(req):
            return engine.generate([prompt_for(req)])[0].out
        return run

    def elastic_run(req):
        with elastic_lock:             # one cold start even under concurrency
            if not elastic:
                t = time.time()
                eng = InferenceEngine(cfg, ecfg(2), params=params)
                elastic.append(
                    eng if args.serialized else EngineLoop(eng, name="elastic").start()
                )
                gauge.register_stats(
                    "elastic",
                    eng.capacity_now if args.serialized else elastic[0].capacity_now,
                )
                print(f"  [elastic cold start {time.time()-t:.1f}s]")
        if args.serialized:
            return run_on(elastic[0])(req)
        loop = elastic[0]
        return loop.wait(loop.submit(prompt_for(req), trace=req.trace), req.timeout_s).out

    loops: list = []

    def engine_backend(tier, engine, capacity, queue_cap):
        """Continuous-batching backend: workers submit into the engine's
        shared step loop and block on futures (capacity = max_slots so the
        pool keeps the decode batch fed); --serialized keeps the
        lock-holding generate path."""
        name = tier.name.lower()
        if args.serialized:
            gauge.register_stats(name, engine.capacity_now)
            return Backend(tier, run_on(engine), capacity=capacity, queue_cap=queue_cap,
                           stats_fn=engine.capacity_now)
        loop = EngineLoop(engine, name=name).start()
        loops.append(loop)
        gauge.register_stats(name, loop.capacity_now)
        return Backend(
            tier, run_on(engine), capacity=capacity, queue_cap=queue_cap,
            stats_fn=loop.capacity_now,
            submit_fn=lambda req: loop.submit(prompt_for(req), trace=req.trace),
            wait_fn=lambda sid, timeout: loop.wait(sid, timeout).out,
        )

    router = StraightLineRouter(
        {
            Tier.FLASK: engine_backend(Tier.FLASK, interactive, 1, 8),
            Tier.DOCKER: engine_backend(Tier.DOCKER, batch_tier, 4, 64),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, elastic_run, capacity=16),
        },
        policy=StraightLinePolicy(Thresholds(F=args.F, D=args.D)),
        window_s=10.0,
        hedge_after_s=args.hedge_after,
        tracer=tracer,
    )
    if sampler is not None:
        sampler.start()
    if args.workers > 0:
        router.start(args.workers)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        size = float(rng.choice([512.0, 16384.0], p=[0.8, 0.2]))
        router.submit(Request(rid=i, arrival_t=0.0, data_size=size, timeout_s=300.0))
    router.drain()
    wall = time.time() - t0
    if args.workers > 0:
        router.stop()
    for lp in loops + [e for e in elastic if isinstance(e, EngineLoop)]:
        lp.stop()
    if sampler is not None:
        sampler.stop()
        covered = {t: len(sampler.series(t)) for t in sampler.tiers()}
        print(f"monitor: {sampler.samples_taken} samples across tiers {covered}")
    if tracer is not None:
        tracer.export_chrome(args.trace_out)
        print(f"wrote {len(tracer)} traces to {args.trace_out} "
              f"(open in Perfetto / chrome://tracing)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(default_registry().prometheus_text())
        print(f"wrote metrics registry to {args.metrics_out}")
    m = router.metrics
    by_tier = {t.name: sum(1 for r in m.completed if r.tier == t) for t in Tier}
    mode = f"{args.workers} workers/tier" if args.workers > 0 else "serial poll loop"
    batching = "serialized generate" if args.serialized else "continuous-batching loops"
    prefill = f"chunked prefill ({args.chunk_tokens} tok)" if args.chunk_tokens else "whole-prompt prefill"
    print(f"{args.requests} requests in {wall:.1f}s ({mode}, {batching}, {prefill}): {m.summary()}")
    print(f"placement: {by_tier}")


if __name__ == "__main__":
    main()
