"""MODEL_FLOPS conventions (roofline 'useful compute' numerator).

train:   6 * N_active * tokens      (fwd 2ND + bwd 4ND)
prefill: 2 * N_active * tokens
decode:  2 * N_active * global_batch

N_active excludes the embedding table; MoE expert weights count at
top_k / n_experts. Enc-dec counts encoder params against encoder tokens
(B * n_ctx) and decoder params against decoder tokens separately.
"""
from __future__ import annotations

import numpy as np
import jax

from repro.models.common import ModelConfig, ParamDef


def _count(defs, cfg: ModelConfig, prefix=""):
    total = 0.0
    active = 0.0
    flat = jax.tree_util.tree_flatten_with_path(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )[0]
    for path, d in flat:
        keys = [str(getattr(k, "key", k)) for k in path]
        n = float(np.prod(d.shape))
        if "embedding" in keys:
            total += n
            continue  # excluded from N
        total += n
        frac = 1.0
        if cfg.moe is not None and any("_ffn" == k[-4:] and k.startswith("l") for k in keys):
            lkey = next(k for k in keys if k.endswith("_ffn"))
            pos = int(lkey[1:-4])
            if cfg.layer_has_moe(pos) and keys[-1] in ("w1", "w2", "w3"):
                frac = cfg.moe.top_k / cfg.moe.n_experts
        active += n * frac
    return total, active


def active_params(model) -> float:
    cfg = model.cfg
    defs = model.param_defs()
    if cfg.encoder is not None:
        _, a_dec = _count(defs["decoder"], cfg)
        _, a_enc = _count(defs["encoder"], cfg)
        return a_dec, a_enc
    _, a = _count(defs, cfg)
    return a, 0.0


def model_flops(model, shape_spec) -> float:
    cfg = model.cfg
    a_dec, a_enc = active_params(model)
    B, S = shape_spec.global_batch, shape_spec.seq_len
    if shape_spec.kind == "train":
        f = 6.0 * a_dec * B * S
        if cfg.encoder is not None:
            f += 6.0 * a_enc * B * cfg.encoder.n_ctx
        return f
    if shape_spec.kind == "prefill":
        f = 2.0 * a_dec * B * S
        if cfg.encoder is not None:
            f += 2.0 * a_enc * B * cfg.encoder.n_ctx
        return f
    if shape_spec.kind == "decode":
        return 2.0 * a_dec * B
    raise ValueError(shape_spec.kind)
