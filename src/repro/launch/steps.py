"""Jitted step builders + sharding trees for params / optimizer / batch / cache.

All shardings come from one place so the trainer, the serving engine and the
dry-run launcher lower the exact same programs.
"""
from __future__ import annotations

from typing import Any, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models.common import ParamDef
from repro.sharding.axes import MeshCtx, Rules
from repro.train.optimizer import OptConfig, adamw_update, opt_state_shapes


# ---------------------------------------------------------------------------
# Sharding trees
# ---------------------------------------------------------------------------


def param_shardings(model, ctx: Optional[MeshCtx], fsdp: bool):
    rules = Rules(ctx, fsdp_params=fsdp)
    return rules.sharding_tree(model.param_defs())


def _ns(ctx, spec: P):
    return NamedSharding(ctx.mesh, spec) if ctx is not None else None


def batch_shardings(ctx: Optional[MeshCtx], input_specs: Mapping, global_batch: int):
    if ctx is None:
        return {k: None for k in input_specs}
    out = {}
    for name, sds in input_specs.items():
        shp = sds.shape
        if name == "positions" and len(shp) == 3:
            out[name] = _ns(ctx, P(None, ctx.batch_spec_for(shp[1]), None))
        elif len(shp) >= 1 and shp and shp[0] == global_batch:
            out[name] = _ns(ctx, P(ctx.batch_spec_for(shp[0]), *([None] * (len(shp) - 1))))
        else:
            out[name] = _ns(ctx, P(*([None] * len(shp))))
    return out


def cache_shardings(ctx: Optional[MeshCtx], cache_defs: Any):
    """Decode caches: axis1 = batch; KV seq (attn) / channel dims (ssm) over TP."""
    if ctx is None:
        return jax.tree.map(lambda s: None, cache_defs)
    tp = ctx.tp_axis
    tpn = ctx.tp_size

    def spec(path, leaf):
        keys = [getattr(k, "key", "") for k in path]
        name = keys[-1] if keys else ""
        parent = keys[-2] if len(keys) >= 2 else ""
        nd = len(leaf.shape)
        ax = [None] * nd
        if nd >= 2:
            ax[1] = ctx.batch_spec_for(leaf.shape[1])
        if "cross" in parent:
            pass  # (n_sb, B, T_enc, H, hd): only batch-sharded (heads rarely divide)
        elif name in ("k", "v", "k_scale", "v_scale") and nd >= 3:
            if leaf.shape[2] % tpn == 0:
                ax[2] = tp
        elif name == "conv" and nd == 4:
            if leaf.shape[3] % tpn == 0:
                ax[3] = tp
        elif name == "ssm" and nd == 4:
            if leaf.shape[2] % tpn == 0:
                ax[2] = tp
        elif name in ("C", "n", "c", "h") and nd >= 4:
            if leaf.shape[3] % tpn == 0:
                ax[3] = tp
        return NamedSharding(ctx.mesh, P(*ax))

    return jax.tree_util.tree_map_with_path(spec, cache_defs)


def opt_shardings(model, ctx: Optional[MeshCtx], ocfg: OptConfig):
    """ZeRO-1: f32/bf16 states share the (fsdp-extended) param specs; int8
    blockwise states shard their (n_blocks, 128) layout over all mesh axes."""
    defs = model.param_defs()
    if ctx is None:
        none_tree = jax.tree.map(lambda d: None, defs, is_leaf=lambda x: isinstance(x, ParamDef))
        if ocfg.state_dtype == "int8":
            none_tree = jax.tree.map(
                lambda _: {"q": None, "scale": None}, none_tree, is_leaf=lambda x: x is None
            )
        return {"m": none_tree, "v": none_tree, "step": None}
    rules = Rules(ctx, fsdp_params=True)

    def leaf(d: ParamDef):
        spec = rules.spec_for(d)
        if ocfg.state_dtype in ("float32", "bfloat16"):
            return NamedSharding(ctx.mesh, spec)
        # int8 states are SHAPE-PRESERVING (optimizer.quantize_blockwise):
        # q shares the param's spec exactly (no resharding against grads);
        # the per-block scale drops the last-dim sharding (it is d//block).
        from repro.train.optimizer import _block_for

        s_spec = list(spec) + [None] * (len(d.shape) - len(spec))
        if _block_for(d.shape[-1] if d.shape else 1) == 0:
            s_spec = s_spec + [None]     # unquantizable leaf: scale = value[..., None]
        else:
            s_spec[-1] = None
        return {
            "q": NamedSharding(ctx.mesh, spec),
            "scale": NamedSharding(ctx.mesh, P(*s_spec)),
        }

    tree = jax.tree.map(leaf, defs, is_leaf=lambda x: isinstance(x, ParamDef))
    return {"m": tree, "v": tree, "step": NamedSharding(ctx.mesh, P())}


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(
    model, ctx: Optional[MeshCtx], ocfg: OptConfig, schedule=None, microbatches: int = 1
):
    """microbatches > 1: gradient accumulation — the global batch is split on
    axis 0 and scanned, bounding live activations/residuals to one microbatch
    (how the 400B-class train cells fit HBM; grads accumulate in bf16)."""

    def grad_fn(params, batch):
        def lf(p):
            return model.loss(ctx, p, batch)

        return jax.value_and_grad(lf, has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches) + x.shape[1:])
                if x.ndim >= 1 and x.shape[0] % microbatches == 0
                else jnp.broadcast_to(x, (microbatches,) + x.shape),
                batch,
            )

            def body(acc, b):
                (loss, metrics), grads = grad_fn(params, b)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(a.dtype) / microbatches, acc, grads
                )
                return acc, (loss, metrics)

            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)
            grads, (losses, ms) = jax.lax.scan(body, zero, mb)
            loss = losses.mean()
            metrics = jax.tree.map(lambda x: x.mean(axis=0), ms)
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        lr = schedule(opt_state["step"]) if schedule is not None else None
        new_params, new_opt, stats = adamw_update(grads, opt_state, params, ocfg, lr=lr)
        metrics = dict(metrics)
        metrics.update(stats)
        metrics["loss"] = loss
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(model, ctx: Optional[MeshCtx], cap: int = 0):
    def prefill_step(params, batch):
        return model.prefill(ctx, params, batch, cap=cap)

    return prefill_step


def make_decode_step(model, ctx: Optional[MeshCtx]):
    def decode_step(params, cache, batch):
        return model.decode(ctx, params, cache, batch)

    return decode_step


# ---------------------------------------------------------------------------
# Lowering helpers (shared by dryrun + launchers)
# ---------------------------------------------------------------------------


def lower_train(model, ctx, shape_spec, ocfg: OptConfig, microbatches: int = 1):
    pshapes = model.param_shapes()
    oshapes = opt_state_shapes(pshapes, ocfg)
    inputs = model.input_specs(shape_spec)
    psh = param_shardings(model, ctx, fsdp=True)
    osh = opt_shardings(model, ctx, ocfg)
    bsh = batch_shardings(ctx, inputs, shape_spec.global_batch)
    step = make_train_step(model, ctx, ocfg, microbatches=microbatches)
    jitted = jax.jit(
        step, in_shardings=(psh, osh, bsh), donate_argnums=(0, 1)
    )
    return jitted.lower(pshapes, oshapes, inputs)


def _serve_params(model, ctx):
    """(shapes, shardings) for serving — applies weight-int8 when enabled."""
    pshapes = model.param_shapes()
    psh = param_shardings(model, ctx, fsdp=False)
    if model.cfg.weights_int8:
        from repro.models.quant import quantized_shape_tree, quantized_sharding_tree

        psh = quantized_sharding_tree(psh, pshapes)
        pshapes = quantized_shape_tree(pshapes)
    return pshapes, psh


def lower_prefill(model, ctx, shape_spec):
    pshapes, psh = _serve_params(model, ctx)
    inputs = model.input_specs(shape_spec)
    bsh = batch_shardings(ctx, inputs, shape_spec.global_batch)
    cdefs = model.cache_defs(shape_spec.global_batch, shape_spec.seq_len)
    csh = cache_shardings(ctx, cdefs)
    tok_sh = (
        NamedSharding(ctx.mesh, P(ctx.batch_spec_for(shape_spec.global_batch)))
        if ctx is not None
        else None
    )
    step = make_prefill_step(model, ctx, cap=shape_spec.seq_len)
    jitted = jax.jit(step, in_shardings=(psh, bsh), out_shardings=(tok_sh, csh))
    return jitted.lower(pshapes, inputs)


def lower_decode(model, ctx, shape_spec):
    pshapes, psh = _serve_params(model, ctx)
    inputs = model.input_specs(shape_spec)
    bsh = batch_shardings(ctx, inputs, shape_spec.global_batch)
    cdefs = model.cache_defs(shape_spec.global_batch, shape_spec.seq_len)
    csh = cache_shardings(ctx, cdefs)
    tok_sh = (
        NamedSharding(ctx.mesh, P(ctx.batch_spec_for(shape_spec.global_batch)))
        if ctx is not None
        else None
    )
    step = make_decode_step(model, ctx)
    jitted = jax.jit(
        step, in_shardings=(psh, csh, bsh), out_shardings=(tok_sh, csh), donate_argnums=(1,)
    )
    return jitted.lower(pshapes, cdefs, inputs)
