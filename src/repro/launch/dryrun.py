import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first — jax locks the device count on first
init. Each cell lowers the real train/serve step against ShapeDtypeStruct
stand-ins (no allocation), compiles for the production mesh, and records:

  * memory_analysis()      — per-device bytes (proves it fits)
  * cost_analysis()        — XLA's own numbers (loop bodies counted once)
  * HloCost(...)           — trip-count-corrected flops / HBM bytes /
                             per-collective wire bytes (launch/hlo_analysis)
  * roofline terms         — compute / memory / collective seconds + bound
  * MODEL_FLOPS            — 6*N*D convention + useful-compute ratio

Usage:
  python -m repro.launch.dryrun --arch glm4-9b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --outdir benchmarks/results/dryrun
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


# Per-arch dry-run policies (documented in EXPERIMENTS.md §Dry-run):
#   opt_dtype  — optimizer-state dtype needed to fit HBM at this scale
#   kv_quant   — int8 KV for decode shapes (qwen1.5-32b: bf16 KV would need
#                21.5 GB/chip > 16 GB; int8 is the feasibility baseline)
POLICY = {
    "llama4-maverick-400b-a17b": {"opt_dtype": "int8"},
    "jamba-1.5-large-398b": {"opt_dtype": "bfloat16"},
    "dbrx-132b": {"opt_dtype": "float32"},
    "qwen1.5-32b": {"kv_quant_decode": True},
}

# Optional perf overrides applied on top of the baseline (see §Perf log);
# selected with --variant. Each maps cfg -> cfg.
VARIANTS = {
    "w8": lambda cfg, spec: cfg.replace(weights_int8=True),
    "moetok": lambda cfg, spec: cfg.replace(moe_token_gather=True),
    "w8+moetok": lambda cfg, spec: cfg.replace(weights_int8=True, moe_token_gather=True),
    "sbf16": lambda cfg, spec: cfg.replace(attn_scores_bf16=True),
    "remat0": lambda cfg, spec: cfg.replace(remat="none"),
    "sp": lambda cfg, spec: cfg.replace(seq_shard_activations=True),
    "sbf16+remat0": lambda cfg, spec: cfg.replace(attn_scores_bf16=True, remat="none"),
    "sbf16+sp": lambda cfg, spec: cfg.replace(attn_scores_bf16=True, seq_shard_activations=True),
    "kvbf16": lambda cfg, spec: cfg.replace(kv_quant=False),
    "unroll": lambda cfg, spec: cfg.replace(scan_unroll=cfg.n_superblocks),
    "mb4": lambda cfg, spec: cfg,   # microbatches handled in run_cell
    "mb4+sbf16": lambda cfg, spec: cfg.replace(attn_scores_bf16=True),
}


def adjust_config(cfg, shape_spec, variant: str = ""):
    """Shape-dependent knobs: bound transient attention scores ~<=1.5GB/device."""
    kind = shape_spec.kind
    pol = POLICY.get(cfg.name, {})
    if kind == "decode" and pol.get("kv_quant_decode"):
        cfg = cfg.replace(kv_quant=True)
    if kind in ("train", "prefill"):
        # est per-device score bytes: B_local * H * chunk * S * 4
        dp = 16
        b_local = max(1, shape_spec.global_batch // dp)
        S = shape_spec.seq_len
        H = cfg.n_heads
        chunk = cfg.attn_chunk
        while chunk > 128 and b_local * H * chunk * S * 4 > 1.5e9:
            chunk //= 2
        if chunk != cfg.attn_chunk:
            cfg = cfg.replace(attn_chunk=chunk)
    if kind != "train":
        cfg = cfg.replace(remat="none")
    return cfg


def run_cell(arch: str, shape_name: str, multi_pod: bool, variant: str = "") -> dict:
    from repro.configs.registry import get_config, skip_reason
    from repro.configs.shapes import SHAPES
    from repro.launch.flops import model_flops
    from repro.launch.hlo_analysis import HloCost, roofline_terms
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import lower_decode, lower_prefill, lower_train
    from repro.models import get_model
    from repro.sharding.axes import make_ctx
    from repro.train.optimizer import OptConfig

    spec = SHAPES[shape_name]
    rec = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant,
        "status": "ok",
    }
    skip = skip_reason(arch, shape_name)
    if skip:
        rec["status"] = "skip"
        rec["reason"] = skip
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_ctx(mesh)
    n_dev = ctx.n_devices
    cfg = adjust_config(get_config(arch), spec, variant)
    if variant:
        assert variant in VARIANTS, (variant, list(VARIANTS))
        cfg = VARIANTS[variant](cfg, spec)
    model = get_model(cfg)

    t0 = time.time()
    if spec.kind == "train":
        ocfg = OptConfig(state_dtype=POLICY.get(arch, {}).get("opt_dtype", "float32"))
        rec["opt_dtype"] = ocfg.state_dtype
        mb = 4 if variant.startswith("mb4") else 1
        lowered = lower_train(model, ctx, spec, ocfg, microbatches=mb)
    elif spec.kind == "prefill":
        lowered = lower_prefill(model, ctx, spec)
    else:
        rec["kv_quant"] = bool(cfg.kv_quant)
        lowered = lower_decode(model, ctx, spec)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    rec["lower_s"] = round(t1 - t0, 1)
    rec["compile_s"] = round(t2 - t1, 1)
    # Analytic per-device param bytes (for the CPU-backend f32-upcast temp
    # adjustment documented in EXPERIMENTS.md §Dry-run: CPU lowers bf16 dots
    # via hoisted f32 weight converts; TPU MXU consumes bf16 directly).
    from repro.models.common import ParamDef
    from repro.sharding.axes import Rules

    rules = Rules(ctx, fsdp_params=(spec.kind == "train"))

    def _leaf_bytes(d):
        n = int(np.prod(d.shape))
        sp = rules.spec_for(d)
        shards = 1
        for ax in sp:
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    shards *= ctx.mesh.shape[a]
        return n * jnp.dtype(cfg.param_dtype).itemsize / shards

    pdefs = model.param_defs()
    rec["params_bytes_per_dev"] = int(
        sum(
            _leaf_bytes(d)
            for d in jax.tree.leaves(pdefs, is_leaf=lambda x: isinstance(x, ParamDef))
        )
    )
    rec["mem"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "per_device_total": int(
            mem.argument_size_in_bytes + mem.temp_size_in_bytes
            + mem.output_size_in_bytes - mem.alias_size_in_bytes
        ),
    }
    try:
        ca = compiled.cost_analysis()
        rec["xla_cost"] = {"flops": ca.get("flops", 0.0), "bytes": ca.get("bytes accessed", 0.0)}
    except Exception as e:  # pragma: no cover
        rec["xla_cost"] = {"error": str(e)}

    txt = compiled.as_text()
    t3 = time.time()
    hc = HloCost(txt, n_dev)
    cost = hc.cost()
    rec["analyze_s"] = round(time.time() - t3, 1)
    rec["hlo_bytes"] = len(txt)
    rec["cost"] = {
        "flops_per_dev": cost["flops"],
        "mem_lo_bytes_per_dev": cost["mem_lo_bytes"],
        "mem_bytes_per_dev": cost["mem_bytes"],
        "coll_wire_bytes_per_dev": cost["coll_bytes"],
        "coll_by_type": cost["coll"],
        "n_collectives": cost["n_coll"],
        "while_trips": hc.while_trips[:32],
    }
    rec["roofline"] = roofline_terms(cost)
    mf = model_flops(model, spec)
    rec["model_flops_global"] = mf
    hlo_global = cost["flops"] * n_dev
    rec["useful_compute_ratio"] = (mf / hlo_global) if hlo_global else 0.0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="")
    ap.add_argument("--outdir", default="benchmarks/results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    from repro.configs.registry import list_archs
    from repro.configs.shapes import SHAPES

    outdir = Path(args.outdir)
    outdir.mkdir(parents=True, exist_ok=True)

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{'multi' if mp else 'single'}__{arch}__{shape}"
                tag += f"__{args.variant}" if args.variant else ""
                path = outdir / f"{tag}.json"
                if path.exists() and not args.force:
                    print(f"CACHED {tag}")
                    continue
                print(f"RUN    {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, shape, mp, args.variant)
                except Exception as e:
                    rec = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if mp else "16x16",
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc()[-4000:],
                    }
                    failures += 1
                path.write_text(json.dumps(rec, indent=1))
                status = rec["status"]
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (
                        f" bound={r['bound']} c={r['compute_s']*1e3:.2f}ms "
                        f"m={r['memory_s']*1e3:.2f}ms k={r['collective_s']*1e3:.2f}ms "
                        f"memGB={rec['mem']['per_device_total']/1e9:.2f} "
                        f"useful={rec['useful_compute_ratio']:.2f}"
                    )
                print(f"DONE   {tag}: {status}{extra}", flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
