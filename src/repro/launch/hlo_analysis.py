"""Exact-ish cost accounting over compiled HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified in
scripts/probe_xla.py), which would undercount scan-over-layers models by the
layer count. This analyzer parses the post-SPMD HLO text, builds the
computation call graph, reads ``known_trip_count`` off every while op, and
multiplies body costs through — yielding per-device:

  * flops            — 2*M*N*K for every dot (incl. inside fusions/loops)
  * mem_bytes        — sum of (operands + outputs) of top-level ops per
                       computation, fusions counted as single kernels (a
                       standard HBM-traffic model post-fusion)
  * collective wire bytes — ring-model per-device bytes per collective type:
        all-reduce      2*b*(g-1)/g        all-gather     out*(g-1)/g
        reduce-scatter  in*(g-1)/g         all-to-all     b*(g-1)/g
        collective-permute  b

All quantities are PER DEVICE (the SPMD module is the per-device program);
roofline terms divide by per-chip peaks, which matches the global formula
HLO_total / (chips * peak).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMMENT_RE = re.compile(r"/\*.*?\*/")


def _parse_op_line(line: str):
    """'%name = TYPE opcode(args), attrs' -> (name, type_str, opcode, rest).
    TYPE may be a tuple '(T1, T2, ...)' possibly containing /*index=N*/
    comments; attrs may contain '=' freely."""
    s = _COMMENT_RE.sub("", line).strip()
    if s.startswith("ROOT "):
        s = s[5:]
    if not s.startswith("%"):
        return None
    eq = s.find(" = ")
    if eq < 0:
        return None
    name = s[1:eq]
    rest = s[eq + 3 :]
    if rest.startswith("("):
        depth = 0
        i = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        type_str, tail = rest[: i + 1], rest[i + 1 :].strip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_str, tail = rest[:sp], rest[sp + 1 :].strip()
    m = re.match(r"([\w\-]+)\((.*)$", tail)
    if not m:
        return None
    return name, type_str, m.group(1), m.group(2)
def _comp_header(line: str) -> Optional[str]:
    """Computation headers are lines like '%name (args...) -> type {' (or with
    a leading ENTRY). Arg/ret types contain nested braces, so match loosely."""
    s = line.strip()
    if not s.endswith("{") or "->" not in s or "=" in s.split("(")[0]:
        return None
    tok = s.split("(")[0].strip()
    if tok.startswith("ENTRY"):
        tok = tok[len("ENTRY"):].strip()
    if not tok:
        return None
    return tok.lstrip("%") or None

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def _type_bytes(t: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(t):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _type_elems(t: str) -> int:
    m = _SHAPE_RE.search(t)
    if not m:
        return 0
    n = 1
    if m.group(2):
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
    return n


def _first_shape_dims(t: str) -> List[int]:
    m = _SHAPE_RE.search(t)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str          # args + attrs tail of the line
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    symtab: Dict[str, str] = field(default_factory=dict)  # op name -> type str
    by_name: Dict[str, Op] = field(default_factory=dict)


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            name = _comp_header(line)
            if name:
                cur = Computation(name)
                if line.lstrip().startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            parsed = _parse_op_line(line)
            if parsed is None:
                continue
            name, type_str, opcode, rest = parsed
            op = Op(name, type_str, opcode, rest)
            op.operands = re.findall(r"%([\w.\-]+)", rest.split(")", 1)[0])
            cur.symtab[op.name] = op.type_str
            cur.by_name[op.name] = op
            cur.ops.append(op)
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _type_elems(op.type_str)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m:
        return 0.0
    cdims = [int(x) for x in m.group(1).split(",") if x]
    lhs = op.operands[0] if op.operands else None
    lhs_t = comp.symtab.get(lhs, "")
    dims = _first_shape_dims(lhs_t)
    k = 1
    for c in cdims:
        if c < len(dims):
            k *= dims[c]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = _type_elems(op.type_str)
    if len(op.operands) < 2:
        return 0.0
    ker = _first_shape_dims(comp.symtab.get(op.operands[1], ""))
    k = 1
    for d in ker[:-1]:  # all but output-feature dim (approximate)
        k *= d
    return 2.0 * out_elems * k


def _group_size(op: Op, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", op.rest)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _operand_bytes(op: Op, comp: Computation) -> int:
    return sum(_type_bytes(comp.symtab.get(o, "")) for o in op.operands)


# HBM-traffic model: count operand+output bytes only for ops that would be
# kernel/materialization boundaries on TPU (elementwise chains, converts,
# broadcasts, reshapes fuse into their consumers and are NOT counted).
_MEM_OP_PREFIXES = (
    "dot", "convolution", "fusion", "custom-call", "copy",
    "dynamic-update-slice", "dynamic-slice", "gather", "scatter",
    "reduce", "sort", "select-and-scatter", "rng", "pad", "concatenate",
    "cholesky", "triangular-solve",
) + COLLECTIVES


def _is_mem_op(opcode: str) -> bool:
    return any(opcode.startswith(p) for p in _MEM_OP_PREFIXES) and not opcode.endswith("-done")


def _collective_wire(op: Op, comp: Computation, g: int) -> float:
    out_b = _type_bytes(op.type_str)
    in_b = _operand_bytes(op, comp)
    frac = (g - 1) / g if g > 1 else 0.0
    oc = op.opcode
    if oc.startswith("all-reduce"):
        return 2.0 * out_b * frac
    if oc.startswith("all-gather"):
        return out_b * frac
    if oc.startswith("reduce-scatter"):
        return in_b * frac
    if oc.startswith("all-to-all"):
        return out_b * frac
    if oc.startswith("collective-permute"):
        return float(out_b)
    return 0.0


class HloCost:
    def __init__(self, text: str, total_devices: int):
        self.comps, self.entry = parse_hlo(text)
        self.total_devices = total_devices
        self._memo: Dict[str, dict] = {}
        self.while_trips: List[Tuple[str, int]] = []

    def _trip_count(self, op: Op) -> int:
        m = re.search(r'known_trip_count[^\d]*(\d+)', op.rest)
        return int(m.group(1)) if m else 1

    def _is_pure_convert_fusion(self, op: Op, comp: Computation) -> bool:
        """Element-preserving single-source fusions (convert / transpose+
        convert / copy chains — CPU's f32 upcasts and int8 dequants): on TPU
        the consumer streams the SOURCE from HBM (bf16/int8 native), so
        traffic is charged at the source dtype by _src_bytes. Structural
        test: exactly one operand within 4x of the output size, and equal
        element counts (scales/indices in dequant fusions are tiny)."""
        if op.opcode != "fusion":
            return False
        out_b = _type_bytes(op.type_str)
        big = [
            o for o in op.operands
            if _type_bytes(comp.symtab.get(o, "")) > max(4, out_b // 4)
        ]
        return len(big) == 1 and _type_elems(op.type_str) == _type_elems(
            comp.symtab.get(big[0], "")
        )

    def _src_bytes(self, comp: Computation, name: str, depth: int = 0) -> float:
        """Bytes actually streamed from HBM for an operand: trace through
        converts / pure-convert fusions / layout ops back to the source."""
        op = comp.by_name.get(name)
        if op is None or depth > 4:
            return float(_type_bytes(comp.symtab.get(name, "")))
        if op.opcode in ("convert", "bitcast", "copy", "transpose", "reshape") and op.operands:
            return self._src_bytes(comp, op.operands[0], depth + 1)
        if self._is_pure_convert_fusion(op, comp):
            big = [o for o in op.operands if _type_bytes(comp.symtab.get(o, "")) > 4]
            return self._src_bytes(comp, big[0], depth + 1)
        return float(_type_bytes(comp.symtab.get(name, "")))

    def _op_traffic(self, op: Op, comp: Computation) -> float:
        """HBM bytes for one op. Slicing ops touch only the slice; fusions
        with dynamic-slice'd parameters touch only the slices (XLA fuses the
        slice into the kernel, the full operand is never streamed)."""
        oc = op.opcode
        out_b = _type_bytes(op.type_str)
        if oc in ("dot", "convolution"):
            return out_b + sum(self._src_bytes(comp, o) for o in op.operands)
        if oc == "fusion" and self._is_pure_convert_fusion(op, comp):
            return 0.0  # charged at the consuming dot via _src_bytes
        if oc.startswith(("dynamic-slice", "slice", "gather")):
            return 2.0 * out_b
        if oc.startswith("dynamic-update-slice"):
            upd = _type_bytes(comp.symtab.get(op.operands[1], "")) if len(op.operands) > 1 else out_b
            return 2.0 * upd
        if oc.startswith("scatter"):
            upd = _type_bytes(comp.symtab.get(op.operands[-1], "")) if op.operands else out_b
            return 3.0 * upd
        if oc == "fusion":
            mc = re.search(r"calls=%?([\w.\-]+)", op.rest)
            fc = self.comps.get(mc.group(1)) if mc else None
            if fc is None:
                return out_b + _operand_bytes(op, comp)
            # map parameter index -> consumers inside the fused computation
            pname_by_idx = {}
            for fop in fc.ops:
                if fop.opcode == "parameter":
                    mi = re.match(r"(\d+)", fop.rest)
                    if mi:
                        pname_by_idx[int(mi.group(1))] = fop.name
            total = float(out_b)
            for i, operand in enumerate(op.operands):
                pb = _type_bytes(comp.symtab.get(operand, ""))
                pn = pname_by_idx.get(i)
                if pn is not None:
                    consumers = [f for f in fc.ops if pn in f.operands and f.opcode != "parameter"]
                    if consumers and all(
                        f.opcode.startswith(("dynamic-slice", "slice", "gather")) for f in consumers
                    ):
                        pb = sum(_type_bytes(f.type_str) for f in consumers)
                total += pb
            return total
        return out_b + _operand_bytes(op, comp)

    def _called(self, op: Op) -> List[Tuple[str, float, bool]]:
        """(callee, multiplier, flops_only)."""
        out = []
        if op.opcode == "while":
            trip = self._trip_count(op)
            mb = re.search(r"body=%?([\w.\-]+)", op.rest)
            if mb:
                out.append((mb.group(1), float(trip), False))
                self.while_trips.append((mb.group(1), trip))
        elif op.opcode == "fusion":
            mc = re.search(r"calls=%?([\w.\-]+)", op.rest)
            if mc:
                out.append((mc.group(1), 1.0, True))  # flops only: fusion = 1 kernel
        elif op.opcode in ("call", "conditional", "custom-call"):
            for mm in re.finditer(r"(?:to_apply|calls|branch_computations=\{?)=?%?([\w.\-]+)", op.rest):
                name = mm.group(1)
                if name in self.comps:
                    out.append((name, 1.0, False))
        return out

    def cost(self, comp_name: Optional[str] = None) -> dict:
        comp_name = comp_name or self.entry
        if comp_name in self._memo:
            return self._memo[comp_name]
        comp = self.comps.get(comp_name)
        z = {"flops": 0.0, "mem_bytes": 0.0, "mem_lo_bytes": 0.0, "coll_bytes": 0.0,
             "coll": {c: 0.0 for c in COLLECTIVES}, "n_coll": 0}
        if comp is None:
            return z
        self._memo[comp_name] = z  # guard cycles
        for op in comp.ops:
            oc = op.opcode
            if oc == "dot":
                z["flops"] += _dot_flops(op, comp)
            elif oc == "convolution":
                z["flops"] += _conv_flops(op, comp)
            if _is_mem_op(oc):
                t = self._op_traffic(op, comp)
                z["mem_bytes"] += t
                # mem_lo: assume TPU fuses elementwise chains — skip fusion
                # kernels; dots/data-movement/collectives stay HBM-bound.
                if oc != "fusion":
                    z["mem_lo_bytes"] += t
            base = next((c for c in COLLECTIVES if oc == c or oc == c + "-start"), None)
            if base is not None:
                g = _group_size(op, self.total_devices)
                w = _collective_wire(op, comp, g)
                z["coll_bytes"] += w
                z["coll"][base] += w
                z["n_coll"] += 1
            for callee, mult, flops_only in self._called(op):
                sub = self.cost(callee)
                z["flops"] += mult * sub["flops"]
                if not flops_only:
                    z["mem_bytes"] += mult * sub["mem_bytes"]
                    z["mem_lo_bytes"] += mult * sub["mem_lo_bytes"]
                    z["coll_bytes"] += mult * sub["coll_bytes"]
                    z["n_coll"] += int(mult * sub["n_coll"])
                    for c in COLLECTIVES:
                        z["coll"][c] += mult * sub["coll"][c]
        return z


# TPU v5e constants (per chip)
PEAK_FLOPS = 197e12        # bf16
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s per ICI link


def roofline_terms(cost: dict) -> dict:
    """memory term uses the TPU-fused model (mem_lo); mem_hi (CPU-backend
    fusion boundaries) is reported alongside as the upper bound."""
    ct = cost["flops"] / PEAK_FLOPS
    mt = cost.get("mem_lo_bytes", cost["mem_bytes"]) / HBM_BW
    mt_hi = cost["mem_bytes"] / HBM_BW
    kt = cost["coll_bytes"] / LINK_BW
    dom = max((ct, "compute"), (mt, "memory"), (kt, "collective"))[1]
    return {
        "compute_s": ct,
        "memory_s": mt,
        "memory_hi_s": mt_hi,
        "collective_s": kt,
        "bound": dom,
        "step_s_lower_bound": max(ct, mt, kt),
    }
