"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --steps 100 \
        [--smoke] [--ckpt-dir DIR] [--microbatches N] [--opt-dtype float32]

Smoke configs execute on this host; FULL configs require the production
mesh (use repro.launch.dryrun to validate the sharded program first).
"""
from __future__ import annotations

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--opt-dtype", default="float32", choices=["float32", "bfloat16", "int8"])
    ap.add_argument("--data", default=None, help="token .bin file (default: synthetic)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs.registry import get_config
    from repro.models import get_model
    from repro.train.data import DataConfig
    from repro.train.optimizer import OptConfig
    from repro.train.schedule import WarmupCosine
    from repro.train.trainer import TrainConfig, Trainer

    cfg = get_config(args.arch, smoke=args.smoke).replace(attn_chunk=64, ce_chunks=2)
    model = get_model(cfg)
    trainer = Trainer(
        model,
        None,
        TrainConfig(
            steps=args.steps,
            ckpt_every=args.ckpt_every,
            ckpt_dir=args.ckpt_dir,
            log_every=10,
            opt=OptConfig(lr=args.lr, state_dtype=args.opt_dtype),
        ),
        DataConfig(
            batch_size=args.batch_size,
            seq_len=args.seq_len,
            vocab_size=cfg.vocab_size,
            path=args.data,
            seed=args.seed,
        ),
        schedule=WarmupCosine(peak_lr=args.lr, warmup_steps=max(5, args.steps // 10), total_steps=args.steps),
    )
    trainer.install_preemption_handler()
    r = trainer.run(seed=args.seed)
    h = r["history"]
    print(
        f"{args.arch}: {r['steps_done']} steps in {r['wall_s']:.1f}s | "
        f"loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f}"
        + (" | PREEMPTED (checkpoint saved)" if r["preempted"] else "")
    )


if __name__ == "__main__":
    main()
