"""Production meshes. Import must never touch jax device state — meshes are
built only inside functions."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16, 16) over ('data', 'model').
    Multi-pod: 2 pods = 512 chips (2, 16, 16) over ('pod', 'data', 'model');
    'pod' extends data parallelism across the inter-pod links (DCN/ICI)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_tier_mesh(n_devices: int, tp: int = 1):
    """Small serving-tier meshes (interactive/elastic slices). Uses the first
    n_devices available devices; data x model layout."""
    assert n_devices % tp == 0
    devs = jax.devices()[:n_devices]
    import numpy as np

    arr = np.array(devs).reshape(n_devices // tp, tp)
    return jax.sharding.Mesh(arr, ("data", "model"))
