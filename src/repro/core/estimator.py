"""Roofline-backed latency / memory estimator.

Service-time models for the discrete-event simulator and the SLO-aware
policy. Two sources, merged:

  * dry-run JSON records (benchmarks/results/dryrun/*.json) — per (arch,
    shape) roofline terms of the real compiled programs;
  * analytic fallback — 2*N_active*D / peak with memory/collective floors,
    for arbitrary request sizes between the measured shapes.

A tier's hardware profile scales the terms: an interactive slice with 8
chips has 8/256 of the pod's compute, the elastic tier pays a cold-start =
weight-load time (bytes(params)/HBM_bw) + slice allocation — mirroring the
paper's container-activation overhead.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


@dataclass(frozen=True)
class SliceProfile:
    """A compute slice backing a tier. speed_factor rescales per-chip peak —
    1.0 is a TPU v5e chip; the paper-calibrated testbed uses CPU-class
    factors so the reproduction matches the paper's measured latencies."""

    chips: int
    name: str = ""
    alloc_s: float = 0.0          # slice acquisition time (elastic tier)
    hbm_frac: float = 1.0         # memory class: fraction of chip HBM usable
                                   # (paper's Lambda 2GB vs 3GB analogue)
    speed_factor: float = 1.0     # effective peak = speed_factor * chips * PEAK


@dataclass(frozen=True)
class AppProfile:
    """Deployed-model profile consumed by the estimator."""

    name: str
    active_params: float           # N_active
    param_bytes: float             # weight bytes (cold-start load)
    flops_per_unit: float          # FLOPs per work unit (e.g. per token/image)
    bytes_per_unit: float          # HBM bytes per work unit
    base_overhead_s: float = 2e-3  # dispatch/step overhead


def xception_profile(width: int = 32, img: int = 299) -> AppProfile:
    # paper: 110.9 MB weights, 109.4 ms inference => calibrate to those.
    n = 22.9e6                       # Xception params
    return AppProfile(
        name="xception",
        active_params=n,
        param_bytes=110.9e6,
        flops_per_unit=9.1e9,        # ~FLOPs per 299x299 image
        bytes_per_unit=6 * n / 8,    # activation+weight traffic per image
        base_overhead_s=2e-3,
    )


def lm_profile(arch: str, active_params: float, param_bytes: float) -> AppProfile:
    return AppProfile(
        name=arch,
        active_params=active_params,
        param_bytes=param_bytes,
        flops_per_unit=2.0 * active_params,      # per token
        bytes_per_unit=2.0 * active_params * 0.02,  # KV+activation traffic/token
    )


class LatencyEstimator:
    def __init__(self, dryrun_dir: Optional[str] = None):
        self.records: Dict[tuple, dict] = {}
        if dryrun_dir and Path(dryrun_dir).exists():
            for p in Path(dryrun_dir).glob("single__*.json"):
                try:
                    r = json.loads(p.read_text())
                except Exception:
                    continue
                if r.get("status") == "ok":
                    self.records[(r["arch"], r["shape"])] = r

    def step_time(self, arch: str, shape: str, chips_frac: float = 1.0) -> Optional[float]:
        """Roofline lower bound from a measured dry-run cell, rescaled to a
        smaller slice (compute/memory scale with chips; collectives shrink)."""
        r = self.records.get((arch, shape))
        if not r:
            return None
        t = r["roofline"]
        return max(
            t["compute_s"] / chips_frac,
            t["memory_s"] / chips_frac,
            t["collective_s"],
        )

    @staticmethod
    def service_time(app: AppProfile, work_units: float, slice_: SliceProfile) -> float:
        """Analytic per-request service time on a given slice. Weights are
        resident (loaded once at cold start), so only activation/KV traffic
        counts here."""
        peak = slice_.speed_factor * slice_.chips * PEAK_FLOPS
        bw = slice_.speed_factor * slice_.chips * HBM_BW * slice_.hbm_frac
        compute = app.flops_per_unit * work_units / peak
        memory = app.bytes_per_unit * work_units / bw
        return app.base_overhead_s + max(compute, memory)

    LOAD_BW = 150e6  # container-image pull + weight staging bandwidth

    @staticmethod
    def cold_start(app: AppProfile, slice_: SliceProfile) -> float:
        """Paper's container-activation analogue: slice/instance allocation +
        weight staging (image pull), ~1 s for the 110.9 MB Xception."""
        return slice_.alloc_s + app.param_bytes / max(1, slice_.chips) / LatencyEstimator.LOAD_BW


def transfer_time(data_size_bytes: float, bw_bytes_s: float = 10e6) -> float:
    """Client->tier upload time; the paper's reason to keep small payloads
    off remote tiers (maxBandwidth on IIS, Lambda ingress)."""
    return data_size_bytes / bw_bytes_s
