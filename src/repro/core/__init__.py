"""StraightLine core: the paper's primary contribution.

Empirical Dynamic Placing (Algorithm 1), telemetry, tier models, the
discrete-event hybrid-infrastructure simulator, and the online router.
"""
from repro.core.placing import (
    AdaptiveThresholds,
    RandomPolicy,
    RoundRobinPolicy,
    SLOAwarePolicy,
    StaticPolicy,
    StraightLinePolicy,
    Thresholds,
    placing_batch_jax,
)
from repro.core.request import PlacementDecision, Request, Tier
from repro.core.simulator import SimConfig, Simulation
from repro.core.telemetry import (
    CapacityGauge,
    Counter,
    FrequencyEstimator,
    Gauge,
    Histogram,
    Metrics,
    MetricsRegistry,
    MonitorSampler,
    batch_occupancy,
    default_registry,
    log_buckets,
    prefill_backlog,
    queue_depth,
    warm_fraction,
)
from repro.core.tiers import TierConfig, TierSim
from repro.core.tracing import NULL_TRACER, Trace, Tracer, trace_now

__all__ = [
    "AdaptiveThresholds",
    "CapacityGauge",
    "Counter",
    "FrequencyEstimator",
    "Gauge",
    "Histogram",
    "Metrics",
    "MetricsRegistry",
    "MonitorSampler",
    "NULL_TRACER",
    "PlacementDecision",
    "RandomPolicy",
    "Request",
    "RoundRobinPolicy",
    "SLOAwarePolicy",
    "SimConfig",
    "Simulation",
    "StaticPolicy",
    "StraightLinePolicy",
    "Thresholds",
    "Tier",
    "TierConfig",
    "TierSim",
    "Trace",
    "Tracer",
    "batch_occupancy",
    "default_registry",
    "log_buckets",
    "placing_batch_jax",
    "prefill_backlog",
    "queue_depth",
    "trace_now",
    "warm_fraction",
]
