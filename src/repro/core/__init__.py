"""StraightLine core: the paper's primary contribution.

Empirical Dynamic Placing (Algorithm 1), telemetry, tier models, the
discrete-event hybrid-infrastructure simulator, and the online router.
"""
from repro.core.placing import (
    AdaptiveThresholds,
    RandomPolicy,
    RoundRobinPolicy,
    SLOAwarePolicy,
    StaticPolicy,
    StraightLinePolicy,
    Thresholds,
    placing_batch_jax,
)
from repro.core.request import PlacementDecision, Request, Tier
from repro.core.simulator import SimConfig, Simulation
from repro.core.telemetry import (
    CapacityGauge,
    FrequencyEstimator,
    Metrics,
    batch_occupancy,
    queue_depth,
    warm_fraction,
)
from repro.core.tiers import TierConfig, TierSim

__all__ = [
    "AdaptiveThresholds",
    "CapacityGauge",
    "FrequencyEstimator",
    "Metrics",
    "PlacementDecision",
    "RandomPolicy",
    "Request",
    "RoundRobinPolicy",
    "SLOAwarePolicy",
    "SimConfig",
    "Simulation",
    "StaticPolicy",
    "StraightLinePolicy",
    "Thresholds",
    "Tier",
    "TierConfig",
    "TierSim",
    "batch_occupancy",
    "placing_batch_jax",
    "queue_depth",
    "warm_fraction",
]
