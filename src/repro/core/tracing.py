"""Per-request lifecycle tracing: spans, events, per-token timelines.

The paper's headline claims (lower response time / failure rate from
resource-aware placement) were measured as end-of-run aggregates; a trace
answers *why one request was slow*. Each submitted request carries a
``Trace`` (on ``Request.trace`` / ``Sequence.trace``) from
``StraightLineRouter.submit`` through placement, backend queueing, worker
execution (including hedge races — the duplicate copy shares the original's
trace and records on its own *lane*), the ``EngineLoop`` admit→resolve
cycle, and the engines' chunked-prefill / preemption / per-token decode
machinery. Prefix-cache engines add instants on the sequence's engine lane:
``prefix_hit`` / ``prefix_miss`` at admission (with ``matched_tokens``, so
a Perfetto view shows exactly how much prefill was skipped) and
``prefix_evict`` when cold cached leaves are reclaimed to cover an
allocation (with ``freed_pages``). Speculating engines add one instant per
verify step on the same lane: ``spec_accept`` when at least one drafted
token survived verification, ``spec_reject`` when the whole draft was
thrown away (both carry ``slot`` / ``proposed`` / ``accepted``, so a trace
shows exactly where the n-gram proposer paid off). The result is a bounded
ring of finished traces exportable two ways:

* ``Tracer.traces()`` — structured dicts (the test/forecaster surface);
* ``Tracer.chrome_trace()`` / ``export_chrome(path)`` — Chrome trace-event
  JSON, loadable in Perfetto / ``chrome://tracing`` (one *process* per
  request, one *thread* per lane, so a hedged request renders as two racing
  execution tracks under one request group).

Zero-cost when disabled: a ``Tracer(enabled=False)`` (or no tracer at all)
makes ``begin()`` return None, and every instrumentation site in the
router/scheduler/engines is guarded by ``if trace is not None`` — the only
residual work is that branch. ``benchmarks/observability_overhead.py``
gates this in CI.

Timestamp contract: every span/event/token time is ``time.monotonic()``
(`trace_now`), the same clock the router uses — timestamps from different
components of one trace are directly comparable. The simulator records
sim-time traces instead; a trace is internally consistent, never mix the
two bases within one tracer.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager
from typing import Deque, Dict, List, Optional

trace_now = time.monotonic


class Trace:
    """One request's lifecycle: spans (named intervals), events (named
    instants), and per-lane token timelines. A *lane* is one execution
    track — "router" for placement/bookkeeping, a tier name for a worker
    execution, a per-sid lane for engine-side work — and becomes a thread
    row in the Chrome export, so a hedged request's racing copies render
    side by side. Appends are lock-guarded: hedged copies and the engine
    step thread record concurrently."""

    __slots__ = ("rid", "attrs", "spans", "events", "tokens", "t0", "_lock", "finished")

    def __init__(self, rid: int, t0: Optional[float] = None, **attrs):
        self.rid = rid
        self.attrs = dict(attrs)
        self.t0 = trace_now() if t0 is None else t0
        self.spans: List[tuple] = []      # (name, lane, t0, t1, attrs)
        self.events: List[tuple] = []     # (name, lane, t, attrs)
        self.tokens: Dict[str, List[float]] = {}   # lane -> token timestamps
        self._lock = threading.Lock()
        self.finished = False

    # -- recording -----------------------------------------------------------
    def add_span(self, name: str, t0: float, t1: float, lane: str = "router", **attrs) -> None:
        with self._lock:
            self.spans.append((name, lane, t0, t1, attrs))

    @contextmanager
    def span(self, name: str, lane: str = "router", **attrs):
        t0 = trace_now()
        try:
            yield self
        finally:
            self.add_span(name, t0, trace_now(), lane=lane, **attrs)

    def event(self, name: str, lane: str = "router", t: Optional[float] = None, **attrs) -> None:
        with self._lock:
            self.events.append((name, lane, trace_now() if t is None else t, attrs))

    def add_tokens(self, lane: str, times: List[float]) -> None:
        """Attach a finished execution's per-token decode timestamps (one
        lane per engine-side sequence; a hedged request contributes two)."""
        with self._lock:
            self.tokens.setdefault(lane, []).extend(times)

    # -- derived / export ------------------------------------------------------
    def lanes(self) -> List[str]:
        with self._lock:
            seen = dict.fromkeys(
                [lane for _, lane, *_ in self.spans]
                + [lane for _, lane, *_ in self.events]
                + list(self.tokens)
            )
        return list(seen)

    def ttft_s(self, lane: Optional[str] = None) -> Optional[float]:
        """First-token latency from trace start for ``lane`` (earliest lane
        with tokens when None) — None until a token lands."""
        with self._lock:
            pools = [self.tokens[lane]] if lane else list(self.tokens.values())
        firsts = [ts[0] for ts in pools if ts]
        return min(firsts) - self.t0 if firsts else None

    def itl_s(self, lane: Optional[str] = None) -> List[float]:
        """Inter-token gaps for ``lane`` (all lanes when None)."""
        with self._lock:
            pools = [self.tokens.get(lane, [])] if lane else list(self.tokens.values())
        out: List[float] = []
        for ts in pools:
            out.extend(b - a for a, b in zip(ts, ts[1:]))
        return out

    def to_dict(self) -> dict:
        with self._lock:
            return {
                "rid": self.rid,
                "t0": self.t0,
                "attrs": dict(self.attrs),
                "spans": [
                    {"name": n, "lane": lane, "t0": a, "t1": b, "attrs": dict(at)}
                    for n, lane, a, b, at in self.spans
                ],
                "events": [
                    {"name": n, "lane": lane, "t": t, "attrs": dict(at)}
                    for n, lane, t, at in self.events
                ],
                "tokens": {lane: list(ts) for lane, ts in self.tokens.items()},
            }


class Tracer:
    """Thread-safe bounded ring of request traces.

    ``begin(rid)`` hands out a live ``Trace`` (or None when disabled — the
    zero-cost path); ``finish(trace)`` stamps summary attrs and moves it
    into the ring, evicting the oldest past ``capacity``. Export any time:
    finished traces are immutable-by-convention (late events from a losing
    hedge copy may still land; they simply appear in the export)."""

    def __init__(self, capacity: int = 4096, enabled: bool = True):
        self.capacity = capacity
        self.enabled = enabled
        self._lock = threading.Lock()
        self._ring: Deque[Trace] = deque(maxlen=capacity)  # guarded by: _lock
        self._closed = False            # guarded by: _lock

    def begin(self, rid: int, **attrs) -> Optional[Trace]:
        if not self.enabled:
            return None
        return Trace(rid, **attrs)

    def finish(self, trace: Optional[Trace], **attrs) -> None:
        if trace is None:
            return
        trace.attrs.update(attrs)
        with self._lock:
            if trace.finished or self._closed:
                return               # exactly-once: hedge copies both settle
            trace.finished = True
            self._ring.append(trace)

    def close(self) -> None:
        """Idempotent shutdown: disable ``begin`` and stop accepting late
        ``finish`` calls, so in-flight losers of a hedge race settling after
        shutdown cannot grow the ring. Finished traces stay exportable;
        calling ``close`` any number of times (from any thread) is safe."""
        self.enabled = False
        with self._lock:
            self._closed = True

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def traces(self) -> List[dict]:
        """Finished traces as structured dicts, oldest first."""
        with self._lock:
            ring = list(self._ring)
        return [t.to_dict() for t in ring]

    def drain(self) -> List[dict]:
        with self._lock:
            ring = list(self._ring)
            self._ring.clear()
        return [t.to_dict() for t in ring]

    # -- Chrome trace-event export ---------------------------------------------
    def chrome_trace(self) -> dict:
        """Chrome trace-event JSON (Perfetto-loadable): one pid per request,
        one tid per lane (named via thread_name metadata), spans as complete
        ("X") events, instants as "i", tokens as named instants on their
        execution lane. Timestamps are microseconds on the shared monotonic
        base."""
        out: List[dict] = []
        for t in self.traces():
            pid = t["rid"]
            tids = {lane: i for i, lane in enumerate(
                dict.fromkeys(
                    [s["lane"] for s in t["spans"]]
                    + [e["lane"] for e in t["events"]]
                    + list(t["tokens"])
                )
            )}
            out.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": f"request {pid}"},
            })
            for lane, tid in tids.items():
                out.append({
                    "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
                    "args": {"name": lane},
                })
            for s in t["spans"]:
                out.append({
                    "ph": "X", "name": s["name"], "pid": pid, "tid": tids[s["lane"]],
                    "ts": s["t0"] * 1e6, "dur": max(0.0, (s["t1"] - s["t0"]) * 1e6),
                    "args": s["attrs"],
                })
            for e in t["events"]:
                out.append({
                    "ph": "i", "s": "t", "name": e["name"], "pid": pid,
                    "tid": tids[e["lane"]], "ts": e["t"] * 1e6, "args": e["attrs"],
                })
            for lane, ts in t["tokens"].items():
                for k, tk in enumerate(ts):
                    out.append({
                        "ph": "i", "s": "t", "name": "token", "pid": pid,
                        "tid": tids[lane], "ts": tk * 1e6, "args": {"i": k},
                    })
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


NULL_TRACER = Tracer(enabled=False)
"""Shared disabled tracer: ``begin()`` always returns None, so components
that want an always-present tracer attribute can default to this without
paying for tracing."""
