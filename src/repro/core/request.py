"""Request / placement types for the StraightLine scheduler."""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional


class Tier(enum.IntEnum):
    """Execution tiers. Names follow the paper; the TPU-pod analogue is in
    parentheses (DESIGN.md §2)."""

    FLASK = 0       # local web server  (interactive slice)
    DOCKER = 1      # container/RESTful (batch slice, continuous batching)
    SERVERLESS = 2  # AWS Lambda        (elastic on-demand slices)


@dataclass
class Request:
    rid: int
    arrival_t: float
    data_size: float             # bytes of input payload (paper's r_d)
    model: str = "xception"      # which deployed model this request targets
    work_units: float = 1.0      # estimator cost units (e.g. tokens, pixels)
    timeout_s: float = 50.0      # paper: 50 s on both web server and Lambda
    slo_s: Optional[float] = None  # optional SLO target (beyond-paper policies)

    # filled by the router/simulator
    tier: Optional[Tier] = None
    start_t: Optional[float] = None
    finish_t: Optional[float] = None
    failed: bool = False
    fail_reason: str = ""
    hedged: bool = False
    # lifecycle trace context (core/tracing.Trace), set by the router when a
    # tracer is attached; a hedged copy (copy.copy) SHARES it — both racing
    # executions record onto the same trace, on distinct lanes
    trace: Optional[object] = field(default=None, repr=False, compare=False)

    @property
    def wait_s(self) -> float:
        return (self.start_t - self.arrival_t) if self.start_t is not None else 0.0

    @property
    def response_s(self) -> Optional[float]:
        """Paper's 'response time' (and 'session length' = time in system)."""
        return (self.finish_t - self.arrival_t) if self.finish_t is not None else None


@dataclass
class PlacementDecision:
    rid: int
    tier: Tier
    reason: str = ""
