"""Empirical Dynamic Placing Algorithm (paper Algorithm 1) + variants.

Faithful control flow::

    if f_t > F and r_d < D:   serverless     # burst of small payloads
    elif r_d > D:             docker         # large payload, latency-tolerant
    elif S_F available:       flask          # moderate -> lowest latency
    elif S_D available:       docker
    else:                     serverless

Variants (paper §IV future work, implemented here as beyond-paper features):
  * SLOAwarePolicy        — picks argmin estimated-completion subject to SLO
  * AdaptiveThresholds    — F/D re-fit online from telemetry + tier models
  * placing_batch_jax     — vectorized jnp version for high-rate routers
"""
from __future__ import annotations

import inspect
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.request import PlacementDecision, Request, Tier


@dataclass
class Thresholds:
    F: float = 1200.0   # requests / window — the paper's interactive-tier knee
    D: float = 1.0e6    # bytes — payloads above this go to the batch tier


def takes_warmup(policy) -> bool:
    """Whether ``policy.place`` accepts the ``warmup`` kwarg. Only policies
    that *consume* warm-up state declare it (StraightLinePolicy); the
    warmup-blind ones keep the 4-arg signature so ``place_compat`` skips
    the stats probes entirely for them."""
    try:
        return "warmup" in inspect.signature(policy.place).parameters
    except (TypeError, ValueError):
        return False


def place_compat(
    policy,
    req: Request,
    f_t: float,
    flask_free: int,
    docker_free: int,
    warmup_fn: Callable[[], Optional[dict]],
    warmup_capable: bool,
) -> PlacementDecision:
    """The one placement call site shared by the router and the simulator:
    passes warm-up state only when the policy accepts it (``warmup_capable``
    is the cached ``takes_warmup(policy)``), evaluating ``warmup_fn`` lazily
    so warmup-blind policies never pay for stats probes."""
    if warmup_capable:
        return policy.place(req, f_t, flask_free, docker_free, warmup=warmup_fn())
    return policy.place(req, f_t, flask_free, docker_free)


def _warm_info(warmup: Optional[dict], tier: Tier):
    """(warm_fraction, compile_cost_s) for a tier. Entries may be a bare
    float (cost unknown) or a dict {"warmth": f, "compile_cost_s": s} built
    from the engine's measured compile-time EMA. Tiers without warm-up state
    (static backends, no probe) are treated as fully warm."""
    if warmup is None:
        return 1.0, None
    v = warmup.get(tier)
    if v is None:
        return 1.0, None
    if isinstance(v, dict):
        return float(v.get("warmth", 1.0)), v.get("compile_cost_s")
    return float(v), None


class StraightLinePolicy:
    """Algorithm 1, line-for-line — plus warm-up-aware availability.

    ``warmup`` (optional) maps tiers to their bucket-compilation progress in
    [0, 1] (``compile_events / total_buckets`` from ``capacity_now()``) —
    either bare, or wrapped with the engine's measured per-compile cost
    (``{"warmth": f, "compile_cost_s": s}`` from the ``compile_ema_s`` EMA).
    While a tier is still compiling its prefill buckets, a request routed
    there may hit an XLA compile instead of a warm kernel; when both
    interactive and batch tiers are available, the policy therefore prefers
    the *warmer* one — but only when the detour is worth it: with a measured
    compile cost, the expected cold penalty ``(1 - warmth) *
    compile_cost_s`` must exceed ``hop_cost_s`` (the latency price of
    hopping interactive -> batch) or the warmth gap is ignored (a one-bucket
    gap on a tiny model is not worth a tier hop). The faithful lines 3/6
    (burst and large-payload) and the fall-through order are untouched; with
    ``warmup=None`` the decision is byte-identical to the paper's
    Algorithm 1."""

    name = "straightline"

    def __init__(self, thresholds: Thresholds = Thresholds(), hop_cost_s: float = 0.05):
        self.th = thresholds
        self.hop_cost_s = hop_cost_s

    def place(
        self,
        req: Request,
        f_t: float,
        flask_free: int,
        docker_free: int,
        warmup: Optional[dict] = None,
    ) -> PlacementDecision:
        th = self.th
        if f_t > th.F and req.data_size < th.D:                      # line 3
            return PlacementDecision(req.rid, Tier.SERVERLESS, "f_t>F and r_d<D")
        if req.data_size > th.D:                                     # line 6
            return PlacementDecision(req.rid, Tier.DOCKER, "r_d>D")
        if flask_free > 0:                                           # line 10
            wf, cf = _warm_info(warmup, Tier.FLASK)
            wd, _ = _warm_info(warmup, Tier.DOCKER)
            if docker_free > 0 and wd > wf and self._hop_pays(wf, cf):
                # both available but flask is still compiling its buckets
                # (and the expected compile stall outweighs the tier hop):
                # route to the warmer batch tier until flask catches up
                return PlacementDecision(
                    req.rid, Tier.DOCKER, f"S_F cold (warm {wf:.2f}<{wd:.2f}), S_D warmer"
                )
            return PlacementDecision(req.rid, Tier.FLASK, "S_F non-empty")
        if docker_free > 0:                                          # line 14
            return PlacementDecision(req.rid, Tier.DOCKER, "S_F empty, S_D non-empty")
        return PlacementDecision(req.rid, Tier.SERVERLESS, "all busy")  # line 18

    def _hop_pays(self, warmth: float, compile_cost_s: Optional[float]) -> bool:
        """Is detouring off the interactive tier worth its remaining warm-up?
        With no measured compile cost the gap alone decides (original
        behavior); with one, the expected stall of a cold bucket —
        ``(1 - warmth) * compile_cost_s`` — must exceed the tier-hop price."""
        if compile_cost_s is None:
            return True
        return (1.0 - warmth) * float(compile_cost_s) > self.hop_cost_s

    def place_all(
        self,
        reqs: Sequence[Request],
        f_t: float,
        flask_free: int,
        docker_free: int,
        warmup: Optional[dict] = None,
    ):
        """Paper's batch form: place a waiting queue R, consuming availability.
        Every docker placement consumes docker availability — including the
        unconditional large-payload path — keyed on the decision tier."""
        out: List[PlacementDecision] = []
        ff, df = flask_free, docker_free
        for r in reqs:
            d = self.place(r, f_t, ff, df, warmup=warmup)
            if d.tier == Tier.FLASK:
                ff -= 1
            elif d.tier == Tier.DOCKER:
                df -= 1
            out.append(d)
        return out


class StaticPolicy:
    """Everything to one tier — the paper's per-platform evaluation curves."""

    def __init__(self, tier: Tier):
        self.tier = tier
        self.name = f"static-{tier.name.lower()}"

    def place(self, req, f_t, flask_free, docker_free):
        return PlacementDecision(req.rid, self.tier, "static")


class RoundRobinPolicy:
    name = "round-robin"

    def __init__(self):
        self._i = 0

    def place(self, req, f_t, flask_free, docker_free):
        t = Tier(self._i % 3)
        self._i += 1
        return PlacementDecision(req.rid, t, "rr")


class RandomPolicy:
    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = np.random.default_rng(seed)

    def place(self, req, f_t, flask_free, docker_free):
        return PlacementDecision(req.rid, Tier(int(self.rng.integers(0, 3))), "random")


class SLOAwarePolicy:
    """Beyond-paper (paper future-work §2): choose the cheapest tier whose
    estimated completion meets the request SLO; fall back to fastest."""

    name = "slo-aware"

    def __init__(self, tier_models, cost=(1.0, 0.6, 0.3)):
        self.tier_models = tier_models  # Tier -> callable(req, f_t) -> est seconds
        self.cost = cost                 # relative $ cost per tier

    def place(self, req, f_t, flask_free, docker_free):
        free = {Tier.FLASK: flask_free > 0, Tier.DOCKER: docker_free > 0, Tier.SERVERLESS: True}
        ests = {t: m(req, f_t) for t, m in self.tier_models.items()}
        slo = req.slo_s if req.slo_s is not None else req.timeout_s
        ok = [t for t in Tier if free[t] and ests[t] <= slo]
        if ok:
            pick = min(ok, key=lambda t: self.cost[int(t)])
            return PlacementDecision(req.rid, pick, f"slo est={ests[pick]:.3f}s")
        pick = min([t for t in Tier if free[t]], key=lambda t: ests[t])
        return PlacementDecision(req.rid, pick, "slo-miss fastest")


class AdaptiveThresholds:
    """Beyond-paper (paper future-work §3): re-fit F to the observed
    interactive-tier saturation knee and D to the tier crossover point."""

    def __init__(self, base: Thresholds, interactive_capacity_rps: float, window_s: float = 180.0):
        self.th = Thresholds(base.F, base.D)
        self.cap = interactive_capacity_rps
        self.window_s = window_s
        self._ewma_util = 0.0

    def update(self, interactive_utilization: float, docker_service_s: float, flask_service_s: float, link_bw: float = 10e6):
        # F: keep interactive below ~85% utilization of its measured capacity.
        self._ewma_util = 0.9 * self._ewma_util + 0.1 * interactive_utilization
        self.th.F = max(10.0, 0.85 * self.cap * self.window_s * (1.5 - self._ewma_util))
        # D: payload size where upload time starts to dominate the service gap.
        self.th.D = max(1e4, (docker_service_s - flask_service_s) * link_bw)
        return self.th


def placing_batch_jax(
    f_t: jnp.ndarray,        # () or (N,) requests/window
    r_d: jnp.ndarray,        # (N,) data sizes
    flask_free: jnp.ndarray, # () int — availability snapshot
    docker_free: jnp.ndarray,
    F: float,
    D: float,
) -> jnp.ndarray:
    """Vectorized Algorithm 1 (availability consumed in arrival order):
    returns int tier ids (N,). Used by the high-rate router front-end and
    property-tested against the python loop."""
    N = r_d.shape[0]
    f_t = jnp.broadcast_to(jnp.asarray(f_t, jnp.float32), (N,))
    burst = (f_t > F) & (r_d < D)
    big = r_d > D
    # availability is consumed by earlier requests in the batch
    want_flask = ~burst & ~big
    flask_rank = jnp.cumsum(want_flask.astype(jnp.int32)) - 1
    got_flask = want_flask & (flask_rank < flask_free)
    want_docker2 = want_flask & ~got_flask
    # docker availability is consumed by every docker placement — large
    # payloads included. A docker2 candidate succeeds iff prior docker
    # consumers (bigs + earlier candidates, all of which succeed until the
    # pool is dry and none after) leave headroom.
    docker_rank = jnp.cumsum((big | want_docker2).astype(jnp.int32)) - 1
    got_docker2 = want_docker2 & (docker_rank < docker_free)
    tier = jnp.where(
        burst,
        int(Tier.SERVERLESS),
        jnp.where(
            big,
            int(Tier.DOCKER),
            jnp.where(
                got_flask,
                int(Tier.FLASK),
                jnp.where(got_docker2, int(Tier.DOCKER), int(Tier.SERVERLESS)),
            ),
        ),
    )
    return tier.astype(jnp.int32)
