"""Request-frequency estimation, live capacity feedback, metrics aggregation,
and the fleet observability plane (metrics registry + gauge time series).

The paper's Algorithm 1 consumes f_t — "request frequency at time t" — and
the availability sets S_F / S_D. We estimate f_t two ways (selectable): a
sliding count window (matches the paper's 'requests per 180 s' load metric)
and an EWMA of instantaneous rate (smoother under bursts). ``CapacityGauge``
closes the availability side of the loop: serving engines register live
probes (``free_pages()`` / ``capacity_now()`` from the paged engine) and the
router/tier models pull through the gauge, so S_F/S_D reflect the machine
rather than static capacity constants. Percentile aggregation serves the
evaluation figures.

Beyond the per-run aggregates, two continuous surfaces:

* ``MetricsRegistry`` — counters / gauges / fixed-log-bucket histograms
  (mergeable across threads), with a Prometheus-style text exposition
  (``prometheus_text``). The router, EngineLoop and launchers record into
  one shared ``default_registry()`` instead of ad-hoc counters, so every
  run exposes requests/failures/hedges per tier plus TTFT and inter-token
  latency histograms in one scrape.

* ``MonitorSampler`` — a background thread sampling every registered
  ``CapacityGauge`` stats probe at a fixed interval into per-tier
  ring-buffer time series (occupancy, free pages, queue depth, prefill
  backlog, warmth). ``window(tier, last_s)`` reads a recent slice — this
  is the resource-usage depository the predictive placer (ROADMAP item 5)
  forecasts from.
"""
from __future__ import annotations

import math
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Iterable, List, Optional, Sequence, Tuple


def batch_occupancy(stats: Optional[dict]) -> Optional[float]:
    """Decode-batch occupancy in [0, 1] from a ``capacity_now()``-style
    snapshot: active sequences / ``num_slots``. With a continuous-batching
    step loop (serving/scheduler.py) this is the fraction of the shared
    decode batch actually interleaving work — the utilization the placer's
    capacity feedback ultimately buys. Returns None when the snapshot is
    missing or exports no slot total."""
    if not stats:
        return None
    total = stats.get("num_slots") or 0
    if total <= 0:
        return None
    active = stats.get("active_slots")
    if active is None:
        free = stats.get("free_slots")
        if free is None:
            return None
        active = total - free
    return min(1.0, max(0.0, active / total))


def queue_depth(stats: Optional[dict]) -> Optional[int]:
    """Admitted-but-waiting sequences from a ``capacity_now()``-style
    snapshot (``queue_depth`` from an EngineLoop, else the engine's raw
    ``waiting``), or None when unknown."""
    if not stats:
        return None
    d = stats.get("queue_depth", stats.get("waiting"))
    return None if d is None else int(d)


def prefill_backlog(stats: Optional[dict]) -> Optional[int]:
    """Prompt tokens not yet absorbed by the engine's (chunked) prefill
    phase from a ``capacity_now()``-style snapshot, or None when the
    snapshot is missing or predates the chunked-prefill export."""
    if not stats:
        return None
    b = stats.get("prefill_backlog_tokens")
    return None if b is None else int(b)


def warm_fraction(stats: Optional[dict]) -> Optional[float]:
    """Bucket-compilation progress in [0, 1] from a ``capacity_now()``-style
    snapshot: ``compile_events / total_buckets``. Returns None when the
    snapshot is missing or exports no bucket total (unbucketed engines,
    static tiers) — callers treat unknown warm-up as "always warm"."""
    if not stats:
        return None
    total = stats.get("total_buckets") or 0
    if total <= 0:
        return None
    return min(1.0, max(0.0, stats.get("compile_events", 0) / total))


def cached_pages(stats: Optional[dict]) -> Optional[int]:
    """Pages held warm by the engine's cross-request prefix cache from a
    ``capacity_now()``-style snapshot, or None when the snapshot is missing
    or the engine runs without a prefix cache (the key is then absent)."""
    if not stats:
        return None
    c = stats.get("cached_pages")
    return None if c is None else int(c)


def prefix_hit_rate(stats: Optional[dict]) -> Optional[float]:
    """Fraction of admissions whose prompt matched >= 1 cached page, from a
    ``capacity_now()``-style snapshot; None when no prefix cache exports."""
    if not stats:
        return None
    r = stats.get("prefix_hit_rate")
    return None if r is None else min(1.0, max(0.0, float(r)))


def kv_bytes_per_token(stats: Optional[dict]) -> Optional[float]:
    """KV-cache bytes per cached token from a ``capacity_now()``-style
    snapshot (values + scales for int8 pools) — lets the placer convert an
    engine's free-token headroom into bytes regardless of storage format.
    None when the snapshot is missing or the engine predates the export."""
    if not stats:
        return None
    b = stats.get("kv_bytes_per_token")
    return None if b is None else float(b)


def kv_cache_dtype(stats: Optional[dict]) -> Optional[str]:
    """The engine's KV-cache storage dtype name ("int8", "bfloat16", ...),
    or None when the snapshot is missing or the key is absent."""
    if not stats:
        return None
    d = stats.get("kv_cache_dtype")
    return None if d is None else str(d)


def spec_acceptance(stats: Optional[dict]) -> Optional[float]:
    """Speculative-decode acceptance rate — accepted draft tokens over
    proposed draft tokens — from a ``capacity_now()``-style snapshot. None
    when speculation is off or the engine has proposed nothing yet (no
    signal beats a fake 0.0 during warm-up)."""
    if not stats:
        return None
    proposed = stats.get("spec_proposed")
    if not proposed:
        return None
    return min(1.0, max(0.0, stats.get("spec_accepted", 0) / proposed))


def reclaimable_pages(stats: Optional[dict]) -> Optional[int]:
    """The placer's free-ish page view: truly free pages plus evictable
    (unpinned) prefix-cache pages, which the engine reclaims before ever
    preempting a live sequence. Falls back to plain ``free_pages`` when the
    engine has no prefix cache; None when the snapshot exports neither."""
    if not stats:
        return None
    free = stats.get("free_pages")
    if free is None:
        return None
    return int(free) + int(stats.get("evictable_pages") or 0)


class FrequencyEstimator:
    """Thread-safe f_t estimator: ``observe``/``frequency`` may be called
    from any thread (the concurrent router's workers observe while the
    placer reads). Both paths mutate ``_times`` — ``frequency`` prunes the
    window on the read side — so both hold the estimator's own lock."""

    def __init__(self, window_s: float = 180.0, mode: str = "window", halflife_s: float = 5.0):
        self.window_s = window_s
        self.mode = mode
        self.halflife_s = halflife_s
        self._times: Deque[float] = deque()
        self._rate = 0.0
        self._last_t: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, t: float) -> None:
        with self._lock:
            self._times.append(t)
            cutoff = t - self.window_s
            while self._times and self._times[0] < cutoff:
                self._times.popleft()
            if self._last_t is not None:
                dt = max(t - self._last_t, 1e-9)
                inst = 1.0 / dt
                alpha = 1.0 - 0.5 ** (dt / self.halflife_s)
                self._rate += alpha * (inst - self._rate)
            self._last_t = t

    def frequency(self, t: float) -> float:
        """f_t: requests per window (paper's unit: sessions / 180 s)."""
        with self._lock:
            if self.mode == "ewma":
                return self._rate * self.window_s
            cutoff = t - self.window_s
            while self._times and self._times[0] < cutoff:
                self._times.popleft()
            return float(len(self._times))


class CapacityGauge:
    """Registry of live per-tier capacity probes.

    A probe is a zero-arg callable returning "requests admittable right now"
    (e.g. ``lambda: engine.admission_capacity(est_tokens)`` — slots bounded
    by free KV pages for the paged engine). The router's ``Backend`` and the
    simulator's ``TierSim`` consult the gauge when a probe is registered and
    fall back to their static models otherwise, so Algorithm 1's S_F / S_D
    availability checks track the actual cache state of the serving tier.
    """

    def __init__(self):
        self._probes: Dict[str, Callable[[], int]] = {}
        self._stats: Dict[str, Callable[[], dict]] = {}

    def register(self, name: str, probe: Callable[[], int]) -> None:
        self._probes[name] = probe

    def register_stats(self, name: str, probe: Callable[[], dict]) -> None:
        """Bind a rich snapshot probe (``engine.capacity_now``) so consumers
        can read warm-up state, not just a free-capacity integer."""
        self._stats[name] = probe

    def unregister(self, name: str) -> None:
        self._probes.pop(name, None)
        self._stats.pop(name, None)

    def free(self, name: str) -> Optional[int]:
        """Live free capacity for ``name``, or None when no probe is bound."""
        probe = self._probes.get(name)
        if probe is None:
            return None
        return max(0, int(probe()))

    def stats(self, name: str) -> Optional[dict]:
        probe = self._stats.get(name)
        return probe() if probe is not None else None

    def stat_names(self) -> List[str]:
        """Tiers with a rich stats probe bound — what ``MonitorSampler``
        sweeps."""
        return list(self._stats)

    def warmth(self, name: str) -> Optional[float]:
        """Warm-up fraction for ``name`` (compile progress), or None."""
        return warm_fraction(self.stats(name))

    def occupancy(self, name: str) -> Optional[float]:
        """Decode-batch occupancy for ``name`` (continuous-batching
        interleaving), or None when the stats probe exports no slots."""
        return batch_occupancy(self.stats(name))

    def queue_depth(self, name: str) -> Optional[int]:
        """Admitted-but-waiting depth behind ``name``'s step loop, or None."""
        return queue_depth(self.stats(name))

    def prefill_backlog(self, name: str) -> Optional[int]:
        """Unabsorbed prompt tokens behind ``name``'s chunked prefill, or
        None when the stats probe does not export a backlog."""
        return prefill_backlog(self.stats(name))

    def cached_pages(self, name: str) -> Optional[int]:
        """Prefix-cache pages held warm by ``name``, or None (no cache)."""
        return cached_pages(self.stats(name))

    def prefix_hit_rate(self, name: str) -> Optional[float]:
        """Prefix-cache hit rate for ``name``, or None (no cache)."""
        return prefix_hit_rate(self.stats(name))

    def reclaimable_pages(self, name: str) -> Optional[int]:
        """Free + evictable-cache pages for ``name`` — the capacity view
        that counts cold prefix-cache leaves as reclaimable."""
        return reclaimable_pages(self.stats(name))

    def spec_acceptance(self, name: str) -> Optional[float]:
        """Speculative-decode acceptance rate for ``name``, or None when
        speculation is off or nothing has been proposed yet."""
        return spec_acceptance(self.stats(name))

    def kv_bytes_per_token(self, name: str) -> Optional[float]:
        """KV-cache bytes per cached token for ``name``, or None."""
        return kv_bytes_per_token(self.stats(name))

    def kv_cache_dtype(self, name: str) -> Optional[str]:
        """KV-cache storage dtype name for ``name``, or None."""
        return kv_cache_dtype(self.stats(name))

    def snapshot(self) -> Dict[str, int]:
        return {name: max(0, int(p())) for name, p in self._probes.items()}


def percentile(xs: Sequence[float], p: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(math.ceil(p / 100.0 * len(s))) - 1))
    return s[k]


@dataclass
class Metrics:
    """Aggregates matching the paper's figures: failed rate, session length,
    response time (median/p95), per-tier breakdowns. ``record`` is atomic
    (lock-guarded) so the concurrent router's workers can report from any
    thread; the read-side properties take instantaneous snapshots."""

    completed: List = field(default_factory=list)
    failed: List = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False, compare=False)

    def record(self, req) -> None:
        with self._lock:
            (self.failed if req.failed else self.completed).append(req)

    @property
    def total(self) -> int:
        with self._lock:
            return len(self.completed) + len(self.failed)

    @property
    def failure_rate(self) -> float:
        with self._lock:
            total = len(self.completed) + len(self.failed)
            return len(self.failed) / total if total else 0.0

    def response_times(self, tier=None) -> List[float]:
        with self._lock:
            completed = list(self.completed)
        return [
            r.response_s
            for r in completed
            if r.response_s is not None and (tier is None or r.tier == tier)
        ]

    def summary(self) -> Dict[str, float]:
        rts = self.response_times()
        with self._lock:
            total = len(self.completed) + len(self.failed)
            n_failed = len(self.failed)
        return {
            "total": total,
            "failed": n_failed,
            "failure_rate": round(n_failed / total, 4) if total else 0.0,
            "median_response_s": round(percentile(rts, 50), 4) if rts else float("nan"),
            "p95_response_s": round(percentile(rts, 95), 4) if rts else float("nan"),
            "p99_response_s": round(percentile(rts, 99), 4) if rts else float("nan"),
            "mean_response_s": round(sum(rts) / len(rts), 4) if rts else float("nan"),
        }


# ---------------------------------------------------------------------------
# Metrics registry: counters / gauges / histograms + Prometheus exposition
# ---------------------------------------------------------------------------


class Counter:
    """Monotonic counter; ``inc`` is lock-guarded so any thread may record."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge:
    """Last-write-wins instantaneous value (e.g. a sampled occupancy)."""

    __slots__ = ("_value", "_lock")

    def __init__(self):
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


def log_buckets(start: float = 1e-4, factor: float = 2.0, count: int = 24) -> Tuple[float, ...]:
    """Fixed log-spaced histogram bounds: ``start * factor**i``. The default
    spans 100 µs … ~28 min — TTFT, inter-token gaps, queue waits and whole
    responses all land inside it with ~2x resolution."""
    return tuple(start * factor**i for i in range(count))


class Histogram:
    """Fixed-bucket histogram (log-spaced by default), mergeable across
    threads: every instance with the same bounds can ``merge`` into another
    by adding bucket counts — no rebinning, no loss. ``bucket_counts`` are
    non-cumulative (the Prometheus exposition cumulates them); the implicit
    +Inf bucket catches overflow."""

    __slots__ = ("bounds", "counts", "total", "sum", "_lock")

    def __init__(self, bounds: Optional[Iterable[float]] = None):
        self.bounds = tuple(bounds) if bounds is not None else log_buckets()
        self.counts = [0] * (len(self.bounds) + 1)    # last = +Inf overflow
        self.total = 0
        self.sum = 0.0
        self._lock = threading.Lock()

    def _index(self, x: float) -> int:
        lo, hi = 0, len(self.bounds)
        while lo < hi:                  # first bound >= x (le semantics)
            mid = (lo + hi) // 2
            if self.bounds[mid] >= x:
                hi = mid
            else:
                lo = mid + 1
        return lo

    def observe(self, x: float) -> None:
        i = self._index(x)
        with self._lock:
            self.counts[i] += 1
            self.total += 1
            self.sum += x

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other``'s counts into self (same bounds required)."""
        if other.bounds != self.bounds:
            raise ValueError("cannot merge histograms with different bounds")
        with other._lock:
            counts, total, s = list(other.counts), other.total, other.sum
        with self._lock:
            for i, c in enumerate(counts):
                self.counts[i] += c
            self.total += total
            self.sum += s
        return self

    def percentile(self, p: float) -> float:
        """Approximate percentile: upper bound of the bucket holding the
        p-th observation (NaN when empty; +Inf overflow reports the top
        bound)."""
        with self._lock:
            total, counts = self.total, list(self.counts)
        if total == 0:
            return float("nan")
        target = max(1, math.ceil(p / 100.0 * total))
        seen = 0
        for i, c in enumerate(counts):
            seen += c
            if seen >= target:
                return self.bounds[i] if i < len(self.bounds) else self.bounds[-1]
        return self.bounds[-1]

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "bounds": self.bounds,
                "counts": list(self.counts),
                "total": self.total,
                "sum": self.sum,
            }


def _label_key(labels: Optional[Dict[str, str]]) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((labels or {}).items()))


def _label_str(key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return ""
    return "{" + ",".join(f'{k}="{v}"' for k, v in key) + "}"


class MetricsRegistry:
    """Get-or-create registry of named, labeled instruments with a
    Prometheus-style text exposition. One shared ``default_registry()``
    replaces the ad-hoc counters scattered across router/scheduler/engine;
    tests may construct private registries."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[Tuple[str, str], Dict[Tuple, object]] = {}

    def _get(self, kind: str, name: str, labels: Optional[Dict[str, str]], make):
        with self._lock:
            fam = self._metrics.setdefault((kind, name), {})
            key = _label_key(labels)
            inst = fam.get(key)
            if inst is None:
                inst = fam[key] = make()
            return inst

    def counter(self, name: str, labels: Optional[Dict[str, str]] = None) -> Counter:
        return self._get("counter", name, labels, Counter)

    def gauge(self, name: str, labels: Optional[Dict[str, str]] = None) -> Gauge:
        return self._get("gauge", name, labels, Gauge)

    def histogram(
        self,
        name: str,
        labels: Optional[Dict[str, str]] = None,
        bounds: Optional[Iterable[float]] = None,
    ) -> Histogram:
        return self._get("histogram", name, labels, lambda: Histogram(bounds))

    def merged_histogram(self, name: str) -> Optional[Histogram]:
        """All label-series of ``name`` merged into one fresh histogram
        (None when the family does not exist) — the cross-tier view."""
        with self._lock:
            fam = self._metrics.get(("histogram", name))
            insts = list(fam.values()) if fam else []
        if not insts:
            return None
        out = Histogram(insts[0].bounds)
        for h in insts:
            out.merge(h)
        return out

    def snapshot(self) -> Dict[str, dict]:
        """{"kind:name{labels}": value-or-histogram-snapshot} for tests."""
        with self._lock:
            fams = {k: dict(v) for k, v in self._metrics.items()}
        out: Dict[str, dict] = {}
        for (kind, name), fam in sorted(fams.items()):
            for key, inst in sorted(fam.items()):
                label = _label_str(key)
                if kind == "histogram":
                    out[f"{kind}:{name}{label}"] = inst.snapshot()
                else:
                    out[f"{kind}:{name}{label}"] = {"value": inst.value}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format v0.0.4: counters/gauges as
        plain samples, histograms as cumulative ``_bucket{le=...}`` series
        plus ``_sum``/``_count``."""
        lines: List[str] = []
        with self._lock:
            fams = {k: dict(v) for k, v in self._metrics.items()}
        for (kind, name), fam in sorted(fams.items()):
            lines.append(f"# TYPE {name} {kind}")
            for key, inst in sorted(fam.items()):
                if kind != "histogram":
                    lines.append(f"{name}{_label_str(key)} {inst.value:g}")
                    continue
                snap = inst.snapshot()
                cum = 0
                for bound, c in zip(snap["bounds"], snap["counts"]):
                    cum += c
                    bkey = key + (("le", f"{bound:g}"),)
                    lines.append(f"{name}_bucket{_label_str(bkey)} {cum}")
                bkey = key + (("le", "+Inf"),)
                lines.append(f"{name}_bucket{_label_str(bkey)} {snap['total']}")
                lines.append(f"{name}_sum{_label_str(key)} {snap['sum']:g}")
                lines.append(f"{name}_count{_label_str(key)} {snap['total']}")
        return "\n".join(lines) + "\n"


_DEFAULT_REGISTRY = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """The process-wide registry the router/scheduler/launchers record into
    when not handed a private one."""
    return _DEFAULT_REGISTRY


# ---------------------------------------------------------------------------
# MonitorSampler: per-tier gauge time series (the resource-usage depository)
# ---------------------------------------------------------------------------


class MonitorSampler:
    """Background sampler over a ``CapacityGauge``'s stats probes.

    Every ``interval_s`` it snapshots each registered rich probe
    (``capacity_now``-style dicts) into a bounded per-tier ring buffer of
    ``{"t", "occupancy", "free_pages", "free_slots", "queue_depth",
    "prefill_backlog", "warmth", "cached_pages", "prefix_hit_rate"}``
    samples — the time series ROADMAP item
    5's short-horizon forecaster consumes. ``window(tier, last_s)`` returns
    the recent slice; reads and the sampling thread share a lock, so
    windows are consistent under concurrent sampling. When a registry is
    attached, each sample also updates ``tier_*`` gauges so the series'
    current point rides the Prometheus exposition."""

    def __init__(
        self,
        gauge: CapacityGauge,
        interval_s: float = 0.05,
        capacity: int = 4096,
        registry: Optional[MetricsRegistry] = None,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.gauge = gauge
        self.interval_s = interval_s
        self.capacity = capacity
        self.registry = registry
        self.clock = clock
        self._lock = threading.Lock()
        self._series: Dict[str, Deque[dict]] = {}  # guarded by: _lock
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.samples_taken = 0

    # -- lifecycle -----------------------------------------------------------
    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "MonitorSampler":
        with self._lock:
            if self._thread is not None:
                raise RuntimeError("monitor sampler already started")
            self._stop.clear()
            t = self._thread = threading.Thread(
                target=self._run, daemon=True, name="monitor-sampler")
        t.start()
        return self

    def stop(self) -> None:
        """Idempotent and re-entrancy-safe: the thread handle is swapped out
        under the ring lock, so of N concurrent stops exactly one joins (the
        rest see None); the join itself runs with no lock held — a stop
        racing a mid-sweep ``sample_once`` must never wait on a thread that
        is about to take the lock we hold. Safe to call from the sampler
        thread itself (a probe that stops its own sampler cannot self-join)."""
        self._stop.set()
        with self._lock:
            t, self._thread = self._thread, None
        if t is not None and t is not threading.current_thread():
            t.join()

    def __enter__(self) -> "MonitorSampler":
        if self._thread is None:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def _run(self) -> None:
        while not self._stop.is_set():
            self.sample_once()
            self._stop.wait(self.interval_s)

    # -- sampling -------------------------------------------------------------
    def sample_once(self, t: Optional[float] = None) -> Dict[str, dict]:
        """One synchronous sweep over every stats probe (tests drive this
        instead of ``start()``); returns {tier: sample}. A probe that raises
        is skipped for this tick — a flapping tier must not kill the
        sampler."""
        now = self.clock() if t is None else t
        out: Dict[str, dict] = {}
        for tier in self.gauge.stat_names():
            try:
                stats = self.gauge.stats(tier)
            except Exception:
                continue
            if stats is None:
                continue
            sample = {
                "t": now,
                "occupancy": batch_occupancy(stats),
                "free_pages": stats.get("free_pages"),
                "free_slots": stats.get("free_slots"),
                "queue_depth": queue_depth(stats),
                "prefill_backlog": prefill_backlog(stats),
                "warmth": warm_fraction(stats),
                "cached_pages": cached_pages(stats),
                "prefix_hit_rate": prefix_hit_rate(stats),
                # storage format rides along so a dashboard can annotate the
                # byte-capacity series; the dtype STRING stays out of the
                # numeric registry loop below
                "kv_bytes_per_token": kv_bytes_per_token(stats),
                "kv_cache_dtype": kv_cache_dtype(stats),
            }
            with self._lock:
                ring = self._series.get(tier)
                if ring is None:
                    ring = self._series[tier] = deque(maxlen=self.capacity)
                ring.append(sample)
                self.samples_taken += 1
            out[tier] = sample
            if self.registry is not None:
                labels = {"tier": tier}
                for key in ("occupancy", "queue_depth", "prefill_backlog", "warmth",
                            "free_pages", "free_slots", "cached_pages",
                            "prefix_hit_rate", "kv_bytes_per_token"):
                    v = sample[key]
                    if v is not None:
                        self.registry.gauge(f"tier_{key}", labels).set(float(v))
        return out

    # -- reads ----------------------------------------------------------------
    def tiers(self) -> List[str]:
        with self._lock:
            return list(self._series)

    def series(self, tier: str) -> List[dict]:
        with self._lock:
            ring = self._series.get(tier)
            return list(ring) if ring else []

    def latest(self, tier: str) -> Optional[dict]:
        with self._lock:
            ring = self._series.get(tier)
            return ring[-1] if ring else None

    def window(self, tier: str, last_s: float) -> List[dict]:
        """Samples for ``tier`` within the trailing ``last_s`` seconds
        (consistent snapshot under concurrent sampling)."""
        cutoff = self.clock() - last_s
        with self._lock:
            ring = self._series.get(tier)
            return [s for s in ring if s["t"] >= cutoff] if ring else []
