"""Request-frequency estimation, live capacity feedback, metrics aggregation.

The paper's Algorithm 1 consumes f_t — "request frequency at time t" — and
the availability sets S_F / S_D. We estimate f_t two ways (selectable): a
sliding count window (matches the paper's 'requests per 180 s' load metric)
and an EWMA of instantaneous rate (smoother under bursts). ``CapacityGauge``
closes the availability side of the loop: serving engines register live
probes (``free_pages()`` / ``capacity_now()`` from the paged engine) and the
router/tier models pull through the gauge, so S_F/S_D reflect the machine
rather than static capacity constants. Percentile aggregation serves the
evaluation figures.
"""
from __future__ import annotations

import math
import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional, Sequence


def batch_occupancy(stats: Optional[dict]) -> Optional[float]:
    """Decode-batch occupancy in [0, 1] from a ``capacity_now()``-style
    snapshot: active sequences / ``num_slots``. With a continuous-batching
    step loop (serving/scheduler.py) this is the fraction of the shared
    decode batch actually interleaving work — the utilization the placer's
    capacity feedback ultimately buys. Returns None when the snapshot is
    missing or exports no slot total."""
    if not stats:
        return None
    total = stats.get("num_slots") or 0
    if total <= 0:
        return None
    active = stats.get("active_slots")
    if active is None:
        free = stats.get("free_slots")
        if free is None:
            return None
        active = total - free
    return min(1.0, max(0.0, active / total))


def queue_depth(stats: Optional[dict]) -> Optional[int]:
    """Admitted-but-waiting sequences from a ``capacity_now()``-style
    snapshot (``queue_depth`` from an EngineLoop, else the engine's raw
    ``waiting``), or None when unknown."""
    if not stats:
        return None
    d = stats.get("queue_depth", stats.get("waiting"))
    return None if d is None else int(d)


def prefill_backlog(stats: Optional[dict]) -> Optional[int]:
    """Prompt tokens not yet absorbed by the engine's (chunked) prefill
    phase from a ``capacity_now()``-style snapshot, or None when the
    snapshot is missing or predates the chunked-prefill export."""
    if not stats:
        return None
    b = stats.get("prefill_backlog_tokens")
    return None if b is None else int(b)


def warm_fraction(stats: Optional[dict]) -> Optional[float]:
    """Bucket-compilation progress in [0, 1] from a ``capacity_now()``-style
    snapshot: ``compile_events / total_buckets``. Returns None when the
    snapshot is missing or exports no bucket total (unbucketed engines,
    static tiers) — callers treat unknown warm-up as "always warm"."""
    if not stats:
        return None
    total = stats.get("total_buckets") or 0
    if total <= 0:
        return None
    return min(1.0, max(0.0, stats.get("compile_events", 0) / total))


class FrequencyEstimator:
    def __init__(self, window_s: float = 180.0, mode: str = "window", halflife_s: float = 5.0):
        self.window_s = window_s
        self.mode = mode
        self.halflife_s = halflife_s
        self._times: Deque[float] = deque()
        self._rate = 0.0
        self._last_t: Optional[float] = None

    def observe(self, t: float) -> None:
        self._times.append(t)
        cutoff = t - self.window_s
        while self._times and self._times[0] < cutoff:
            self._times.popleft()
        if self._last_t is not None:
            dt = max(t - self._last_t, 1e-9)
            inst = 1.0 / dt
            alpha = 1.0 - 0.5 ** (dt / self.halflife_s)
            self._rate += alpha * (inst - self._rate)
        self._last_t = t

    def frequency(self, t: float) -> float:
        """f_t: requests per window (paper's unit: sessions / 180 s)."""
        if self.mode == "ewma":
            return self._rate * self.window_s
        cutoff = t - self.window_s
        while self._times and self._times[0] < cutoff:
            self._times.popleft()
        return float(len(self._times))


class CapacityGauge:
    """Registry of live per-tier capacity probes.

    A probe is a zero-arg callable returning "requests admittable right now"
    (e.g. ``lambda: engine.admission_capacity(est_tokens)`` — slots bounded
    by free KV pages for the paged engine). The router's ``Backend`` and the
    simulator's ``TierSim`` consult the gauge when a probe is registered and
    fall back to their static models otherwise, so Algorithm 1's S_F / S_D
    availability checks track the actual cache state of the serving tier.
    """

    def __init__(self):
        self._probes: Dict[str, Callable[[], int]] = {}
        self._stats: Dict[str, Callable[[], dict]] = {}

    def register(self, name: str, probe: Callable[[], int]) -> None:
        self._probes[name] = probe

    def register_stats(self, name: str, probe: Callable[[], dict]) -> None:
        """Bind a rich snapshot probe (``engine.capacity_now``) so consumers
        can read warm-up state, not just a free-capacity integer."""
        self._stats[name] = probe

    def unregister(self, name: str) -> None:
        self._probes.pop(name, None)
        self._stats.pop(name, None)

    def free(self, name: str) -> Optional[int]:
        """Live free capacity for ``name``, or None when no probe is bound."""
        probe = self._probes.get(name)
        if probe is None:
            return None
        return max(0, int(probe()))

    def stats(self, name: str) -> Optional[dict]:
        probe = self._stats.get(name)
        return probe() if probe is not None else None

    def warmth(self, name: str) -> Optional[float]:
        """Warm-up fraction for ``name`` (compile progress), or None."""
        return warm_fraction(self.stats(name))

    def occupancy(self, name: str) -> Optional[float]:
        """Decode-batch occupancy for ``name`` (continuous-batching
        interleaving), or None when the stats probe exports no slots."""
        return batch_occupancy(self.stats(name))

    def queue_depth(self, name: str) -> Optional[int]:
        """Admitted-but-waiting depth behind ``name``'s step loop, or None."""
        return queue_depth(self.stats(name))

    def prefill_backlog(self, name: str) -> Optional[int]:
        """Unabsorbed prompt tokens behind ``name``'s chunked prefill, or
        None when the stats probe does not export a backlog."""
        return prefill_backlog(self.stats(name))

    def snapshot(self) -> Dict[str, int]:
        return {name: max(0, int(p())) for name, p in self._probes.items()}


def percentile(xs: Sequence[float], p: float) -> float:
    if not xs:
        return float("nan")
    s = sorted(xs)
    k = min(len(s) - 1, max(0, int(math.ceil(p / 100.0 * len(s))) - 1))
    return s[k]


@dataclass
class Metrics:
    """Aggregates matching the paper's figures: failed rate, session length,
    response time (median/p95), per-tier breakdowns. ``record`` is atomic
    (lock-guarded) so the concurrent router's workers can report from any
    thread; the read-side properties take instantaneous snapshots."""

    completed: List = field(default_factory=list)
    failed: List = field(default_factory=list)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False, compare=False)

    def record(self, req) -> None:
        with self._lock:
            (self.failed if req.failed else self.completed).append(req)

    @property
    def total(self) -> int:
        return len(self.completed) + len(self.failed)

    @property
    def failure_rate(self) -> float:
        return len(self.failed) / self.total if self.total else 0.0

    def response_times(self, tier=None) -> List[float]:
        return [
            r.response_s
            for r in self.completed
            if r.response_s is not None and (tier is None or r.tier == tier)
        ]

    def summary(self) -> Dict[str, float]:
        rts = self.response_times()
        return {
            "total": self.total,
            "failed": len(self.failed),
            "failure_rate": round(self.failure_rate, 4),
            "median_response_s": round(percentile(rts, 50), 4) if rts else float("nan"),
            "p95_response_s": round(percentile(rts, 95), 4) if rts else float("nan"),
            "p99_response_s": round(percentile(rts, 99), 4) if rts else float("nan"),
            "mean_response_s": round(sum(rts) / len(rts), 4) if rts else float("nan"),
        }
