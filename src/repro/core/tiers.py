"""Execution-tier models for the simulator and router.

Tier semantics mirror the paper's testbed (§III.A):

  * InteractiveTier (Flask/IIS): single-threaded service, bounded accept
    queue, 50 s timeout. Fastest per-request at low load; collapses past the
    saturation knee (paper Fig 4: ~1200-1300 sessions/180 s).
  * BatchTier (Docker/RESTful): k container workers, per-request activation
    overhead, larger queue. Best for large payloads (latency-tolerant).
  * ElasticTier (AWS Lambda): per-request instances with cold start, a warm
    pool with expiry, a concurrency ceiling and a memory class; failures
    rise when demand crosses the ceiling and fall with bigger memory
    (paper Fig 5a: 2 GB vs 3 GB).
"""
from __future__ import annotations

import heapq
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, List, Optional

from repro.core.estimator import AppProfile, LatencyEstimator, SliceProfile, transfer_time
from repro.core.request import Request, Tier
from repro.core.telemetry import warm_fraction


@dataclass
class TierConfig:
    tier: Tier
    slice_: SliceProfile
    n_workers: int = 1
    queue_cap: int = 64
    activation_s: float = 0.0        # per-request container/batch overhead
    warm_expiry_s: float = 60.0      # elastic: warm-instance lifetime
    concurrency_limit: int = 10**9   # elastic: hard throttle ceiling
    net_bw: float = 50e6             # payload upload bandwidth to this tier
    freq_capacity: float = 1e12      # elastic: sessions/window before resource
                                      # contention sets in (memory class, Fig 5a)
    overload_fail_slope: float = 0.0 # elastic: P(fail) growth past 80% of capacity


class TierSim:
    """Server-pool state used by the discrete-event simulator.

    ``capacity_probe`` optionally binds a live capacity source (e.g. a
    ``CapacityGauge`` probe fed by a real serving engine's ``free_pages()``)
    so hybrid sim/real testbeds place against measured state instead of the
    queue-model constants. ``stats_probe`` optionally binds the richer
    ``capacity_now()`` snapshot from which ``warm_fraction()`` derives the
    tier's bucket-compilation progress for warm-up-aware placement.
    """

    def __init__(
        self,
        cfg: TierConfig,
        app: AppProfile,
        rng,
        capacity_probe: Optional[Callable[[], int]] = None,
        stats_probe: Optional[Callable[[], dict]] = None,
    ):
        self.cfg = cfg
        self.app = app
        self.rng = rng
        self.busy = 0
        self.queue: Deque[Request] = deque()
        self.warm_instances: List[float] = []   # elastic: free-at times
        self.inflight = 0
        self.served = 0
        self.busy_time = 0.0
        self.capacity_probe = capacity_probe
        self.stats_probe = stats_probe

    # -- availability (Algorithm 1's S_F / S_D) -----------------------------
    def free_slots(self) -> int:
        if self.capacity_probe is not None:
            live = self.capacity_probe()
            if live is not None:      # probe gone dark -> static queue model
                return max(0, int(live))
        if self.cfg.tier == Tier.SERVERLESS:
            return max(0, self.cfg.concurrency_limit - self.inflight)
        return max(0, self.cfg.n_workers - self.busy) + max(
            0, self.cfg.queue_cap - len(self.queue)
        )

    def worker_free(self) -> bool:
        return self.busy < self.cfg.n_workers

    def warm_fraction(self) -> Optional[float]:
        """Bucket-compilation progress of the live engine backing this tier
        (None when no stats probe is bound — the queue-model tiers have no
        warm-up phase)."""
        if self.stats_probe is None:
            return None
        return warm_fraction(self.stats_probe())

    # -- service model -------------------------------------------------------
    def service_time(self, req: Request, now: float) -> float:
        base = LatencyEstimator.service_time(self.app, req.work_units, self.cfg.slice_)
        t = base + transfer_time(req.data_size, self.cfg.net_bw) + self.cfg.activation_s
        if self.cfg.tier == Tier.SERVERLESS:
            # reuse a warm instance if one is free, else pay cold start
            self.warm_instances = [w for w in self.warm_instances if w > now - self.cfg.warm_expiry_s]
            free_warm = sum(1 for w in self.warm_instances if w <= now)
            if free_warm == 0:
                t += LatencyEstimator.cold_start(self.app, self.cfg.slice_)
        return t

    def admission_failure(self, now: float, f_t: float = 0.0) -> Optional[str]:
        """Elastic-tier throttling/contention failures (paper Fig 5a): the
        failure rate rises once the request frequency crosses ~80% of the
        memory class's capacity — the 2 GB class saturates earlier."""
        if self.cfg.tier != Tier.SERVERLESS:
            return None
        if self.inflight >= self.cfg.concurrency_limit:
            return "throttled"
        util = f_t / self.cfg.freq_capacity
        if util > 0.8 and self.cfg.overload_fail_slope > 0:
            p = min(0.95, self.cfg.overload_fail_slope * (util - 0.8))
            if self.rng.random() < p:
                return "resource-contention"
        return None
