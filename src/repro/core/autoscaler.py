"""Elastic-tier autoscaling (paper future-work §3, implemented here).

Keeps a warm-instance pool sized to the observed arrival rate so bursts do
not pay cold starts: warm_target = ceil(rate * (avg_service + cold_start)),
Little's-law style, with hysteresis to avoid thrash.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from repro.core.estimator import LatencyEstimator
from repro.core.request import Tier


@dataclass
class Autoscaler:
    headroom: float = 1.2
    max_warm: int = 4096
    _last_target: int = 0

    def step(self, sim, now: float, f_t: float) -> int:
        tier = sim.tiers[Tier.SERVERLESS]
        rate = f_t / sim.cfg.window_s
        cold = LatencyEstimator.cold_start(tier.app, tier.cfg.slice_)
        avg_svc = LatencyEstimator.service_time(tier.app, 1.0, tier.cfg.slice_)
        target = min(self.max_warm, math.ceil(rate * (avg_svc + cold) * self.headroom))
        # hysteresis: shrink slowly
        if target < self._last_target:
            target = max(target, int(self._last_target * 0.9))
        self._last_target = target
        warm_now = len(tier.warm_instances)
        if target > warm_now:
            # pre-warm: instances become usable after one cold start
            tier.warm_instances.extend([now + cold] * (target - warm_now))
        return target
