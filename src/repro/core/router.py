"""Online StraightLine router — fronts *real* execution backends.

The simulator (simulator.py) validates policies at scale; this router runs
the same Algorithm-1 logic against live backends (e.g. the JAX serving
engine or the Xception classifier in examples/). Single-threaded event-loop
style: callers submit requests, ``poll()`` drains whatever is due.

Fault tolerance: per-request deadline, retry-once on a different tier,
hedging for stragglers (duplicate to the elastic tier past the hedge
deadline — first result wins).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, Optional

from repro.core.placing import StraightLinePolicy
from repro.core.request import Request, Tier
from repro.core.telemetry import FrequencyEstimator, Metrics


@dataclass
class Backend:
    """A live tier: run(req) executes synchronously and returns the result.

    ``capacity_fn`` is an optional live probe (e.g. the paged engine's
    ``admission_capacity``): when set, the placer sees the tier's measured
    free capacity instead of the static ``capacity`` constant.
    """

    tier: Tier
    run: Callable[[Request], object]
    capacity: int = 1            # concurrent requests the tier accepts
    queue_cap: int = 64
    inflight: int = 0
    queue: Deque[Request] = field(default_factory=deque)
    capacity_fn: Optional[Callable[[], int]] = None

    def free(self) -> int:
        """Free capacity for Algorithm 1's availability check. A live probe
        reports requests admittable NOW (already net of running work — e.g.
        the paged engine's admission_capacity), so it is used as-is; the
        static constant must have in-flight work subtracted. Queue headroom
        is NOT availability (a tier with every worker busy is busy, however
        long its backlog may be). A probe returning None (e.g. a
        CapacityGauge whose source unregistered) falls back to the static
        constant."""
        if self.capacity_fn is not None:
            live = self.capacity_fn()
            if live is not None:
                return max(0, int(live))
        return max(0, self.capacity - self.inflight)


class StraightLineRouter:
    def __init__(
        self,
        backends: Dict[Tier, Backend],
        policy: Optional[StraightLinePolicy] = None,
        window_s: float = 180.0,
        clock: Callable[[], float] = time.monotonic,
        hedge_after_s: Optional[float] = None,
        retry_on_failure: bool = True,
    ):
        self.backends = backends
        self.policy = policy or StraightLinePolicy()
        self.freq = FrequencyEstimator(window_s=window_s)
        self.clock = clock
        self.metrics = Metrics()
        self.hedge_after_s = hedge_after_s
        self.retry_on_failure = retry_on_failure
        self.results: Dict[int, object] = {}

    def _free(self, t: Tier) -> int:
        return self.backends[t].free()

    def submit(self, req: Request) -> Tier:
        now = self.clock()
        req.arrival_t = now
        self.freq.observe(now)
        f_t = self.freq.frequency(now)
        d = self.policy.place(req, f_t, self._free(Tier.FLASK), self._free(Tier.DOCKER))
        tier = d.tier
        # Admission control (queue_cap): a full backlog deflects to the
        # elastic serverless tier instead of growing without bound; if even
        # serverless is saturated the request is rejected outright — a fast
        # failure the client can retry, not an unbounded queueing delay.
        b = self.backends[tier]
        if (
            tier != Tier.SERVERLESS
            and len(b.queue) >= b.queue_cap
            and Tier.SERVERLESS in self.backends
        ):
            tier = Tier.SERVERLESS
            b = self.backends[tier]
        req.tier = tier
        if len(b.queue) >= b.queue_cap:
            self._fail(req, "queue-full")
            return tier
        b.queue.append(req)
        return tier

    def _spill_to_serverless(self, req: Request) -> bool:
        """Move a retried/hedged request to the serverless queue — but only
        within its queue_cap; admission control must hold on every enqueue
        path, not just submit(), or a flapping tier grows it without bound."""
        b = self.backends.get(Tier.SERVERLESS)
        if b is None or len(b.queue) >= b.queue_cap:
            return False
        req.hedged = True
        b.queue.append(req)
        return True

    def _run_one(self, b: Backend, req: Request) -> None:
        now = self.clock()
        if now - req.arrival_t > req.timeout_s:
            self._fail(req, "timeout-in-queue")
            return
        b.inflight += 1
        req.start_t = now
        try:
            out = b.run(req)
            req.finish_t = self.clock()
            if req.finish_t - req.arrival_t > req.timeout_s:
                self._fail(req, "timeout")
            else:
                self.results[req.rid] = out
                self.metrics.record(req)
        except Exception as e:  # tier failure
            retryable = (
                self.retry_on_failure and not req.hedged and req.tier != Tier.SERVERLESS
            )
            if not (retryable and self._spill_to_serverless(req)):
                self._fail(req, f"error:{type(e).__name__}")
        finally:
            b.inflight -= 1

    def _fail(self, req: Request, reason: str) -> None:
        req.failed = True
        req.fail_reason = reason
        req.finish_t = self.clock()
        self.metrics.record(req)

    def poll(self) -> int:
        """Drain one waiting request per tier (round-robin-ish); returns the
        number executed."""
        ran = 0
        for b in self.backends.values():
            # dispatch paces on the static concurrency limit, NOT the live
            # probe: placement (free()) may refuse NEW work when a probe
            # reports 0, but work already queued here must still drain —
            # a probe stuck at 0 must never strand queued requests
            while b.queue and b.inflight < b.capacity:
                req = b.queue.popleft()
                if (
                    self.hedge_after_s is not None
                    and not req.hedged
                    and self.clock() - req.arrival_t > self.hedge_after_s
                    and b.tier != Tier.SERVERLESS
                    # serverless backlog full -> keep the straggler here
                    # rather than stack it onto an already-saturated tier
                    and self._spill_to_serverless(req)
                ):
                    continue
                self._run_one(b, req)
                ran += 1
        return ran

    def drain(self) -> None:
        while any(b.queue for b in self.backends.values()):
            if self.poll() == 0:
                break
