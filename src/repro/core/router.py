"""Online StraightLine router — concurrent runtime fronting *real* backends.

The simulator (simulator.py) validates policies at scale; this router runs
the same Algorithm-1 logic against live backends (e.g. the JAX serving
engine or the Xception classifier in examples/). Two execution modes share
one placement/accounting core:

* **Concurrent runtime** (``start(workers_per_tier)``): per-tier worker
  pools pull from the deque queues, ``Backend`` accounting is lock-guarded,
  and completion is futures-based — callers block on ``result(rid,
  timeout)``. Hedging is *real*: past the hedge deadline a duplicate of the
  request races the original on the elastic tier; the first finisher wins,
  the loser's result is discarded, and the request's metrics are recorded
  exactly once. ``stop()`` joins the pools.

* **Serial fallback** (``poll()`` / ``drain()`` without ``start()``): the
  original single-threaded event loop, kept as the benchmark baseline
  (benchmarks/router_concurrency.py) and for deterministic fake-clock
  tests. Serial hedging *moves* a straggler to the elastic tier instead of
  racing a duplicate (there is no parallelism to race with).

Thread-safety contract: ``submit``/``result``/``drain`` may be called from
any number of threads. Placement reads (``Backend.free()``, warm-up stats)
are instantaneous snapshots — two concurrent submits may both see the same
free slot; the bounded queues absorb the race. Lock order: a backend
condition may be taken while holding nothing; the router registry lock
(``_lock``) is innermost and never held across a backend run or an engine
call.

Trace context contract: with a ``tracer`` attached, ``submit`` begins a
``core.tracing.Trace`` and carries it on ``req.trace`` for the request's
whole lifetime. The router records the *placement* span with Algorithm 1's
actual inputs (f_t, S_F/S_D free counts, the warm-up snapshot consumed,
chosen tier + reason), an ``enqueued`` event per enqueue, a ``queue_wait``
span and an ``execute`` span per execution copy, and events for deflection,
retry-spill, hedging (``hedge_fired`` / ``hedge_discarded``) and failure.
Each execution copy records on its own *lane* (tier name; ``*-hedge`` /
``*-retry`` for duplicates) — a hedged request's racing copies therefore
render as parallel tracks. Downstream components extend the SAME trace:
``Backend.submit_fn`` should forward ``req.trace`` into
``EngineLoop.submit(prompt, trace=...)`` so engine-side spans (chunked
prefill, preemption, per-token decode) land in it. The trace is finished
(moved into the tracer's ring) exactly once, when the rid settles. All of
this is skipped at a single ``is None`` check per site when no tracer is
attached. Router-side counters/histograms (requests, failures, hedges,
queue-wait, response time) land in a ``telemetry.MetricsRegistry``
(``default_registry()`` unless one is injected).

Fault tolerance: per-request deadline, retry-once on a different tier on
error, hedging for stragglers. Completed results are popped on retrieval
and evicted past ``results_cap`` so a long-running router cannot grow its
result map without bound.
"""
from __future__ import annotations

import copy
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Dict, List, Optional

from repro.core.placing import StraightLinePolicy, place_compat, takes_warmup
from repro.core.request import Request, Tier
from repro.core.telemetry import (
    FrequencyEstimator,
    Metrics,
    MetricsRegistry,
    default_registry,
    warm_fraction,
)
from repro.core.tracing import Tracer


class RequestFailed(RuntimeError):
    """Raised by ``result()`` when the request finished in failure."""

    def __init__(self, rid: int, reason: str):
        super().__init__(f"request {rid} failed: {reason}")
        self.rid = rid
        self.reason = reason


@dataclass
class Backend:
    """A live tier: run(req) executes synchronously and returns the result.

    ``capacity_fn`` is an optional live probe (e.g. the paged engine's
    ``admission_capacity``): when set, the placer sees the tier's measured
    free capacity instead of the static ``capacity`` constant.
    ``stats_fn`` is an optional richer snapshot (``engine.capacity_now`` or
    ``EngineLoop.capacity_now``) from which the router derives warm-up state
    (compile_events vs total_buckets, weighted by the measured
    ``compile_ema_s``) and batch occupancy for placement.

    ``submit_fn``/``wait_fn`` select the continuous-batching execution path:
    ``submit_fn(req)`` enqueues the request into a shared engine step loop
    (``serving.scheduler.EngineLoop``) and returns a ticket; ``wait_fn(
    ticket, timeout)`` blocks until it finishes. The worker thread sleeps on
    a future while the loop batches the sequence with every other in-flight
    request on that engine — set ``capacity`` to the engine's ``max_slots``
    so the pool keeps the batch fed. When unset, ``run(req)`` executes
    synchronously (lock-holding ``generate``; the serialized baseline).
    """

    tier: Tier
    run: Callable[[Request], object]
    capacity: int = 1            # concurrent requests the tier accepts
    queue_cap: int = 64
    inflight: int = 0                                     # guarded by: cond
    queue: Deque[Request] = field(default_factory=deque)  # guarded by: cond
    capacity_fn: Optional[Callable[[], int]] = None
    stats_fn: Optional[Callable[[], dict]] = None
    submit_fn: Optional[Callable[[Request], object]] = None
    wait_fn: Optional[Callable[[object, Optional[float]], object]] = None

    def __post_init__(self):
        # cond shares the lock: enqueue/dequeue and inflight accounting are
        # guarded together, and workers sleep on the same primitive
        self.lock = threading.Lock()
        self.cond = threading.Condition(self.lock)

    def free(self) -> int:
        """Free capacity for Algorithm 1's availability check. A live probe
        reports requests admittable NOW (already net of running work — e.g.
        the paged engine's admission_capacity), so it is used as-is; the
        static constant must have in-flight work subtracted. Queue headroom
        is NOT availability (a tier with every worker busy is busy, however
        long its backlog may be). A probe returning None (e.g. a
        CapacityGauge whose source unregistered) falls back to the static
        constant."""
        if self.capacity_fn is not None:
            live = self.capacity_fn()
            if live is not None:
                return max(0, int(live))
        return max(0, self.capacity - self.inflight)  # locklint: ok lock-free placement snapshot; a stale int read only skews a heuristic

    def try_push(self, req: Request) -> bool:
        """Enqueue within queue_cap (atomically) and wake a worker."""
        with self.cond:
            if len(self.queue) >= self.queue_cap:
                return False
            self.queue.append(req)
            self.cond.notify()
        return True


class _Completion:
    """Per-rid completion record: the future the caller waits on, plus the
    bookkeeping that makes hedged execution exactly-once. ``live`` is the
    number of in-flight copies of the request (1, or 2 once a hedge fires)
    and is decremented on EVERY per-copy terminal path — win, recorded
    failure, absorbed failure, discarded loser. A success wins immediately;
    a failure only records once the last live copy has failed. A record may
    be evicted/reaped only at ``live == 0`` — earlier, a still-running copy
    could resurrect the rid and record its metrics twice. ``pending``
    stashes a failure absorbed while a sibling copy was believed live, so
    it can still become the rid's outcome if that sibling evaporates (a
    hedge whose enqueue ultimately fails)."""

    __slots__ = ("request", "event", "value", "failure", "done", "live", "retrieved", "pending")

    def __init__(self, request: Optional[Request] = None):
        self.request = request
        self.event = threading.Event()
        self.value: object = None
        self.failure: Optional[str] = None
        self.done = False
        self.live = 1
        self.retrieved = False
        self.pending: Optional[tuple] = None   # (req, failure) absorbed, unrecorded


class StraightLineRouter:
    def __init__(
        self,
        backends: Dict[Tier, Backend],
        policy: Optional[StraightLinePolicy] = None,
        window_s: float = 180.0,
        clock: Callable[[], float] = time.monotonic,
        hedge_after_s: Optional[float] = None,
        retry_on_failure: bool = True,
        results_cap: int = 1024,
        tracer: Optional[Tracer] = None,
        registry: Optional[MetricsRegistry] = None,
    ):
        self.backends = backends
        self.policy = policy or StraightLinePolicy()
        self.freq = FrequencyEstimator(window_s=window_s)
        self.clock = clock
        self.metrics = Metrics()
        self.tracer = tracer
        self.registry = registry if registry is not None else default_registry()
        self.hedge_after_s = hedge_after_s
        self.retry_on_failure = retry_on_failure
        self.results_cap = results_cap
        self.results: "OrderedDict[int, object]" = OrderedDict()  # guarded by: _lock
        self._lock = threading.Lock()          # guards freq, results, _completions
        self._completions: Dict[int, _Completion] = {}  # guarded by: _lock
        self._done_order: Deque[int] = deque()  # guarded by: _lock -- completed rids, oldest first
        self._threads: List[threading.Thread] = []
        self._stop_flag = False
        self._monitor_stop = threading.Event()   # hedge-monitor pacing/stop
        self._policy_takes_warmup = takes_warmup(self.policy)

    # -- lifecycle (concurrent runtime) --------------------------------------
    @property
    def running(self) -> bool:
        return bool(self._threads)

    def start(self, workers_per_tier: int = 4) -> "StraightLineRouter":
        """Launch the worker pools: per tier, min(workers_per_tier, capacity)
        threads (capacity is the tier's concurrent-acceptance limit — more
        workers than capacity would not add admissible parallelism). When
        hedging is enabled a monitor thread fires duplicates for stragglers."""
        if self._threads:
            raise RuntimeError("router already started")
        self._stop_flag = False
        self._monitor_stop.clear()
        for b in self.backends.values():
            n = max(1, min(workers_per_tier, b.capacity))
            for i in range(n):
                t = threading.Thread(
                    target=self._worker, args=(b,), daemon=True,
                    name=f"router-{b.tier.name.lower()}-{i}",
                )
                t.start()
                self._threads.append(t)
        if self.hedge_after_s is not None:
            t = threading.Thread(target=self._hedge_monitor, daemon=True, name="router-hedge")
            t.start()
            self._threads.append(t)
        return self

    def stop(self) -> None:
        """Stop the pools; queued-but-unstarted work stays queued.

        Idempotent and re-entrancy-safe: the thread list is swapped out under
        ``_lock`` so concurrent stops join each worker at most once, the
        joins run with no lock held (workers take ``_lock`` to settle), and a
        worker calling ``stop`` itself skips the self-join."""
        self._stop_flag = True
        self._monitor_stop.set()     # wakes the hedge monitor immediately
        for b in self.backends.values():
            with b.cond:
                b.cond.notify_all()
        with self._lock:
            threads, self._threads = self._threads, []
        me = threading.current_thread()
        for t in threads:
            if t is not me:
                t.join()

    def __enter__(self) -> "StraightLineRouter":
        if not self._threads:
            self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- placement ------------------------------------------------------------
    def _free(self, t: Tier) -> int:
        return self.backends[t].free()

    def _warmup_snapshot(self) -> Optional[Dict[Tier, object]]:
        """Per-tier warm-up state for warm-up-aware placement; None when no
        backend exports any (keeps Algorithm 1 byte-faithful). A tier whose
        snapshot carries a measured ``compile_ema_s`` gets a rich entry
        ({"warmth", "compile_cost_s"}) so the policy can weigh the warmth
        gap against the actual cost of a cold bucket; otherwise the bare
        warm fraction (cost unknown -> policy keeps the plain preference)."""
        snap: Dict[Tier, object] = {}
        for t, b in self.backends.items():
            if b.stats_fn is None:
                continue
            stats = b.stats_fn()
            w = warm_fraction(stats)
            if w is None:
                continue
            cost = (stats or {}).get("compile_ema_s") or 0.0
            snap[t] = {"warmth": w, "compile_cost_s": cost} if cost > 0.0 else w
        return snap or None

    def submit(self, req: Request) -> Tier:
        now = self.clock()
        req.arrival_t = now
        tr = (
            self.tracer.begin(req.rid, t0=now, data_size=req.data_size, model=req.model)
            if self.tracer is not None
            else None
        )
        req.trace = tr
        with self._lock:
            self.freq.observe(now)
            f_t = self.freq.frequency(now)
        # availability snapshots + the warm-up state actually consumed are
        # Algorithm 1's inputs — captured into the placement span so a trace
        # answers "why this tier"
        flask_free, docker_free = self._free(Tier.FLASK), self._free(Tier.DOCKER)
        warm_seen: Dict[str, object] = {}

        def warm_fn():
            w = self._warmup_snapshot()
            warm_seen["w"] = w
            return w

        d = place_compat(
            self.policy, req, f_t, flask_free, docker_free, warm_fn,
            self._policy_takes_warmup,
        )
        tier = d.tier
        if tr is not None:
            warm = warm_seen.get("w")
            tr.add_span(
                "placement", now, self.clock(),
                f_t=f_t, flask_free=flask_free, docker_free=docker_free,
                tier=tier.name, reason=d.reason,
                warmth={
                    t.name: (v["warmth"] if isinstance(v, dict) else v)
                    for t, v in warm.items()
                } if warm else None,
            )
        self.registry.counter("router_requests_total", {"tier": tier.name.lower()}).inc()
        # Registration happens after the fallible placement/probe calls (a
        # raising probe must not leak a forever-pending completion) but
        # before the enqueue, so a worker can never finish a request the
        # registry has not seen.
        with self._lock:
            self._completions[req.rid] = _Completion(req)
        # Admission control (queue_cap): the enqueue is atomic (try_push),
        # so a full backlog — whether seen up front or raced in by another
        # submitter — deflects to the elastic serverless tier instead of
        # growing without bound; if even serverless refuses, the request is
        # rejected outright — a fast failure the client can retry, not an
        # unbounded queueing delay.
        req.tier = tier
        if self._push_traced(self.backends[tier], req):
            return tier
        sls = self.backends.get(Tier.SERVERLESS)
        if tier != Tier.SERVERLESS and sls is not None:
            req.tier = Tier.SERVERLESS
            if tr is not None:
                tr.event("deflected", t=self.clock(),
                         from_tier=tier.name, to_tier=Tier.SERVERLESS.name)
            self.registry.counter("router_deflections_total").inc()
            if self._push_traced(sls, req):
                return Tier.SERVERLESS
        self._fail(req, "queue-full")
        return req.tier

    def _push_traced(self, b: Backend, req: Request) -> bool:
        """try_push + the trace bookkeeping every enqueue path shares: stamp
        the enqueue time (the queue_wait span's start) and record the
        ``enqueued`` event on the copy's lane."""
        t = self.clock()
        req._enq_t = t
        if not b.try_push(req):
            return False
        tr = req.trace
        if tr is not None:
            tr.event("enqueued", lane=self._lane(req), t=t, tier=b.tier.name)
        return True

    @staticmethod
    def _lane(req: Request) -> str:
        """Trace lane for one execution copy: its tier, suffixed for
        hedge/retry duplicates (set where the duplicate is created)."""
        lane = getattr(req, "_lane_tag", None)
        if lane is not None:
            return lane
        return req.tier.name.lower() if req.tier is not None else "router"

    # -- completion registry (exactly-once) -----------------------------------
    def _completion_for(self, req: Request) -> _Completion:
        """Look up (or lazily create, for requests injected straight into a
        backend queue without submit()) the rid's completion record."""
        with self._lock:
            c = self._completions.get(req.rid)
            if c is None:
                c = _Completion(req)
                self._completions[req.rid] = c
            return c

    def _settle(self, c: _Completion, req: Request, value: object, failure: Optional[str]) -> bool:
        """One copy of the request reached a terminal state. Record the
        rid's outcome exactly once; returns False when this copy lost the
        race (result discarded, no metrics)."""
        with self._lock:
            c.live -= 1
            if c.done:
                return False           # a sibling copy already won
            if failure is not None and c.live > 0:
                # stash it: if the believed-live sibling never materializes
                # (hedge enqueue fails), this failure must still settle the rid
                c.pending = (req, failure)
                return False           # a hedged copy is still in flight
            c.done = True
            c.value = value
            c.failure = failure
            if failure is None:
                self.results[req.rid] = value
            self._done_order.append(req.rid)
            self._evict_locked()
        self.metrics.record(req)
        self._record_outcome(req, failure)
        c.event.set()
        return True

    def _record_outcome(self, req: Request, failure: Optional[str]) -> None:
        """Final per-rid observability: outcome counters, the response-time
        histogram, and the trace hand-off into the tracer ring (exactly
        once — losing hedge copies never reach here)."""
        tier = req.tier.name.lower() if req.tier is not None else "none"
        if failure is None:
            self.registry.counter("router_completions_total", {"tier": tier}).inc()
            if req.response_s is not None:
                self.registry.histogram("router_response_seconds", {"tier": tier}).observe(
                    req.response_s
                )
        else:
            self.registry.counter("router_failures_total", {"reason": failure}).inc()
        if req.trace is not None and self.tracer is not None:
            self.tracer.finish(
                req.trace, tier=req.tier.name if req.tier is not None else None,
                failed=failure is not None, fail_reason=failure or "",
                response_s=req.response_s, hedged=req.hedged,
            )

    def _evict_locked(self) -> None:
        """Bound results + completion-registry growth (caller holds _lock).
        A record whose rid still has a live copy is rotated to the back
        instead of reaped — reaping it would let the copy resurrect the rid
        via _completion_for and record its metrics a second time."""
        excess = len(self._done_order) - self.results_cap
        spins = len(self._done_order)
        while excess > 0 and spins > 0:
            spins -= 1
            old = self._done_order.popleft()
            c = self._completions.get(old)
            if c is not None and c.live > 0:
                self._done_order.append(old)
                continue
            self.results.pop(old, None)
            self._completions.pop(old, None)
            excess -= 1

    def _complete(self, req: Request, out: object) -> bool:
        return self._settle(self._completion_for(req), req, out, None)

    def _fail(self, req: Request, reason: str) -> None:
        req.failed = True
        req.fail_reason = reason
        req.finish_t = self.clock()
        if req.trace is not None:
            req.trace.event("failed", lane=self._lane(req), t=req.finish_t, reason=reason)
        self._settle(self._completion_for(req), req, None, reason)

    def result(self, rid: int, timeout: Optional[float] = None) -> object:
        """Block until ``rid`` finishes and return its result, popping it
        from the result map (a second call raises KeyError). Raises
        ``RequestFailed`` if the request failed, ``TimeoutError`` if it does
        not finish within ``timeout`` seconds."""
        with self._lock:
            c = self._completions.get(rid)
            if c is None or c.retrieved:
                raise KeyError(f"unknown or already-retrieved rid {rid}")
        if not c.event.wait(timeout):
            raise TimeoutError(f"request {rid} not finished within {timeout}s")
        with self._lock:
            if c.retrieved:                # raced another retriever of this rid
                raise KeyError(f"unknown or already-retrieved rid {rid}")
            c.retrieved = True
            self.results.pop(rid, None)
            if c.live == 0:            # all copies terminal: reap eagerly
                self._completions.pop(rid, None)
                try:
                    self._done_order.remove(rid)
                except ValueError:
                    pass
            # else: a losing copy is still running — leave the record for
            # the eviction pass to reap once it goes quiet
        if c.failure is not None:
            raise RequestFailed(rid, c.failure)
        return c.value

    # -- execution ------------------------------------------------------------
    def _spill_to_serverless(self, req: Request) -> bool:
        """Move a retried/hedged request to the serverless queue — but only
        within its queue_cap; admission control must hold on every enqueue
        path, not just submit(), or a flapping tier grows it without bound."""
        b = self.backends.get(Tier.SERVERLESS)
        if b is None:
            return False
        prev_tier = req.tier
        prev_lane = getattr(req, "_lane_tag", None)
        req.hedged = True
        req.tier = Tier.SERVERLESS     # metrics must attribute the execution here
        req._lane_tag = "serverless-retry"
        if self._push_traced(b, req):
            if req.trace is not None:
                req.trace.event("retry_spill", t=self.clock(), from_tier=prev_tier.name)
            self.registry.counter("router_retry_spills_total").inc()
            return True
        req.hedged = False             # spill refused: keep the request retryable
        req.tier = prev_tier
        req._lane_tag = prev_lane
        return False

    def _execute(self, b: Backend, req: Request) -> None:
        """Run one dequeued request to a terminal state (or hand it to the
        retry path). Called with no locks held.

        Continuous-batching backends (``submit_fn``/``wait_fn``) execute in
        two phases: submit into the engine's shared step loop, then block on
        the per-request future — the engine interleaves this request with
        every other in-flight one instead of serializing on its lock.
        Hedging and exactly-once settlement are unchanged: either way this
        worker owns one copy of the request until it reaches a terminal
        state."""
        c = self._completion_for(req)
        tr = req.trace
        lane = self._lane(req)
        if c.done:
            with self._lock:
                c.live -= 1            # hedge race already won — discard copy
            if tr is not None:
                tr.event("hedge_discarded", lane=lane, t=self.clock())
            return
        now = self.clock()
        enq_t = getattr(req, "_enq_t", req.arrival_t)
        if tr is not None:
            tr.add_span("queue_wait", enq_t, now, lane=lane, tier=b.tier.name)
        self.registry.histogram(
            "router_queue_wait_seconds", {"tier": b.tier.name.lower()}
        ).observe(max(0.0, now - enq_t))
        if now - req.arrival_t > req.timeout_s:
            self._fail(req, "timeout-in-queue")
            return
        req.start_t = now
        try:
            if b.submit_fn is not None and b.wait_fn is not None:
                ticket = b.submit_fn(req)
                left = max(0.0, req.timeout_s - (self.clock() - req.arrival_t))
                out = b.wait_fn(ticket, left)
            else:
                out = b.run(req)
        except TimeoutError:
            # the engine loop outlived the request's deadline: the deadline
            # verdict is final — retrying elsewhere cannot beat a clock that
            # already ran out
            if tr is not None:
                tr.add_span("execute", now, self.clock(), lane=lane,
                            tier=b.tier.name, outcome="timeout")
            self._fail(req, "timeout")
            return
        except Exception as e:  # tier failure
            if tr is not None:
                tr.add_span("execute", now, self.clock(), lane=lane,
                            tier=b.tier.name, outcome=f"error:{type(e).__name__}")
            retryable = (
                self.retry_on_failure and not req.hedged and req.tier != Tier.SERVERLESS
            )
            if not (retryable and self._spill_to_serverless(req)):
                self._fail(req, f"error:{type(e).__name__}")
            return
        req.finish_t = self.clock()
        if tr is not None:
            tr.add_span("execute", now, req.finish_t, lane=lane,
                        tier=b.tier.name, outcome="ok")
        if req.finish_t - req.arrival_t > req.timeout_s:
            self._fail(req, "timeout")
        else:
            self._complete(req, out)

    def _worker(self, b: Backend) -> None:
        """Worker-pool loop: block for queued work, execute outside the lock."""
        while True:
            with b.cond:
                while not b.queue and not self._stop_flag:
                    b.cond.wait(0.1)
                if self._stop_flag:
                    return                 # prompt shutdown: queued work stays queued
                req = b.queue.popleft()
                b.inflight += 1
            try:
                self._execute(b, req)
            finally:
                with b.cond:
                    b.inflight -= 1

    # -- hedging (concurrent runtime) -----------------------------------------
    def _fire_hedge(self, req: Request) -> None:
        """Race a duplicate of a straggler on the elastic tier. The copy
        shares the rid (and therefore the completion record): first finisher
        wins, the loser is discarded by the done-check in _settle/_execute."""
        b = self.backends.get(Tier.SERVERLESS)
        if b is None:
            return
        with self._lock:
            c = self._completions.get(req.rid)
            if c is None or c.done or req.hedged:
                return
            req.hedged = True          # never hedge the same request twice
            c.live += 1
        if req.trace is not None:
            req.trace.event("hedge_fired", t=self.clock(), original_tier=req.tier.name)
        self.registry.counter("router_hedges_total").inc()
        clone = copy.copy(req)         # shares req.trace: both copies record
        clone.hedged = True
        clone.tier = Tier.SERVERLESS
        clone._lane_tag = "serverless-hedge"
        if not self._push_traced(b, clone):
            # hedge target saturated — no duplicate. req.hedged stays True:
            # a request gets one hedge opportunity, not a retry loop that
            # hammers a saturated elastic tier every monitor tick.
            with self._lock:
                c.live -= 1
                orphan = self._adopt_pending_locked(c)
            if orphan is not None:
                # the original failed inside the live+=1/try_push window and
                # was absorbed against this never-enqueued duplicate — its
                # failure is the rid's outcome, settled here exactly once
                self.metrics.record(orphan)
                self._record_outcome(orphan, c.failure)
                c.event.set()

    def _adopt_pending_locked(self, c: _Completion) -> Optional[Request]:
        """Caller holds _lock. If every copy is gone, nothing won, and a
        failure was absorbed on the promise of a live sibling, promote that
        failure to the rid's outcome; returns the request to record."""
        if c.done or c.live > 0 or c.pending is None:
            return None
        req, failure = c.pending
        c.done = True
        c.failure = failure
        self._done_order.append(req.rid)
        self._evict_locked()
        return req

    def _hedge_scan(self) -> int:
        """One staleness pass over the in-flight completions against the
        INJECTED clock; fires a hedge per straggler found and returns how
        many fired. Extracted from the monitor loop so fake-clock tests can
        advance ``self.clock`` and drive hedging deterministically — no
        monitor thread, no wall-clock sleep in the loop's way."""
        now = self.clock()
        with self._lock:
            stale = [
                c.request
                for c in self._completions.values()
                if not c.done
                and c.request is not None
                and not c.request.hedged
                and c.request.tier not in (None, Tier.SERVERLESS)
                and now - c.request.arrival_t > self.hedge_after_s
            ]
        for req in stale:
            self._fire_hedge(req)
        return len(stale)

    def _hedge_monitor(self) -> None:
        assert self.hedge_after_s is not None
        tick = min(max(self.hedge_after_s / 4.0, 0.001), 0.05)
        # pace on a stop Event, not time.sleep: stop() returns immediately
        # instead of blocking up to a full tick behind a sleeping monitor
        while not self._monitor_stop.wait(tick):
            self._hedge_scan()

    # -- serial fallback (benchmark baseline) ----------------------------------
    def poll(self) -> int:
        """Serial mode only: drain one waiting request per tier (round-robin
        -ish); returns the number executed. The concurrent runtime's worker
        pools replace this loop — do not mix the two."""
        ran = 0
        for b in self.backends.values():
            # dispatch paces on the static concurrency limit, NOT the live
            # probe: placement (free()) may refuse NEW work when a probe
            # reports 0, but work already queued here must still drain —
            # a probe stuck at 0 must never strand queued requests
            while b.queue and b.inflight < b.capacity:  # locklint: ok serial mode: no workers started, single-threaded by contract
                req = b.queue.popleft()  # locklint: ok serial mode: no workers started, single-threaded by contract
                if (
                    self.hedge_after_s is not None
                    and not req.hedged
                    and self.clock() - req.arrival_t > self.hedge_after_s
                    and b.tier != Tier.SERVERLESS
                    # serverless backlog full -> keep the straggler here
                    # rather than stack it onto an already-saturated tier
                    and self._spill_to_serverless(req)
                ):
                    continue
                b.inflight += 1  # locklint: ok serial mode: no workers started, single-threaded by contract
                try:
                    self._execute(b, req)
                finally:
                    b.inflight -= 1  # locklint: ok serial mode: no workers started, single-threaded by contract
                ran += 1
        return ran

    def drain(self, timeout: Optional[float] = None) -> None:
        """Block until every submitted request reaches a terminal state.
        Serial mode runs the poll loop; the concurrent runtime waits on the
        outstanding completion futures."""
        if not self._threads:
            while any(b.queue for b in self.backends.values()):  # locklint: ok serial mode: guarded by the `not self._threads` branch above
                if self.poll() == 0:
                    break
            return
        deadline = None if timeout is None else self.clock() + timeout
        while True:
            with self._lock:
                pending = [c for c in self._completions.values() if not c.done]
            if not pending:
                return
            for c in pending:
                left = None if deadline is None else max(0.0, deadline - self.clock())
                if not c.event.wait(left):
                    raise TimeoutError(f"drain: request still pending after {timeout}s")
