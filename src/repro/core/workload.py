"""Artillery-analog workload generation (paper §III.C).

The paper drives each platform with ramps of "total sessions per 180 s" from
10 up to 7000. ``ramp()`` reproduces that: N arrivals over the window with a
linearly increasing instantaneous rate. Payload sizes model the image
requests (299x299 JPEGs around ~180 KB) or LM prompts; a bimodal option
exercises Algorithm 1's D threshold.
"""
from __future__ import annotations

from typing import Callable, List, Optional

import numpy as np

from repro.core.request import Request


def _sizes(rng, n: int, dist: str) -> np.ndarray:
    if dist == "image":          # ~299x299 JPEG payloads
        return np.clip(rng.lognormal(np.log(180e3), 0.35, n), 20e3, 2e6)
    if dist == "image-hires":    # the paper's medical-image example
        return np.clip(rng.lognormal(np.log(6e6), 0.4, n), 2e6, 40e6)
    if dist == "bimodal":        # small + large mix across threshold D
        small = rng.lognormal(np.log(150e3), 0.3, n)
        large = rng.lognormal(np.log(8e6), 0.4, n)
        pick = rng.random(n) < 0.8
        return np.where(pick, small, large)
    if dist == "tokens":         # LM prompts: bytes ~ 4x token count
        toks = np.clip(rng.lognormal(np.log(600), 0.8, n), 16, 32768)
        return toks * 4.0
    raise ValueError(dist)


def ramp(
    total_sessions: int,
    duration_s: float = 180.0,
    dist: str = "image",
    model: str = "xception",
    timeout_s: float = 50.0,
    seed: int = 0,
    start_rate_frac: float = 0.1,
) -> List[Request]:
    """N sessions over the window with linearly increasing rate (Artillery
    ramp phase). start_rate_frac sets rate(0) relative to rate(duration)."""
    rng = np.random.default_rng(seed)
    n = int(total_sessions)
    # inverse-CDF sampling of a linear rate profile
    u = np.sort(rng.random(n))
    a = start_rate_frac
    t = duration_s * (np.sqrt(a * a + (1 - a * a) * u) - a) / (1 - a) if a != 1 else u * duration_s
    sizes = _sizes(rng, n, dist)
    out = []
    for i in range(n):
        out.append(
            Request(
                rid=i,
                arrival_t=float(t[i]),
                data_size=float(sizes[i]),
                model=model,
                work_units=float(max(1.0, sizes[i] / 180e3)),
                timeout_s=timeout_s,
            )
        )
    return out


def poisson(
    rate_per_s: float,
    duration_s: float = 180.0,
    dist: str = "image",
    model: str = "xception",
    timeout_s: float = 50.0,
    seed: int = 0,
) -> List[Request]:
    rng = np.random.default_rng(seed)
    t = 0.0
    out: List[Request] = []
    i = 0
    while True:
        t += rng.exponential(1.0 / rate_per_s)
        if t > duration_s:
            break
        size = float(_sizes(rng, 1, dist)[0])
        out.append(
            Request(rid=i, arrival_t=t, data_size=size, model=model,
                    work_units=max(1.0, size / 180e3), timeout_s=timeout_s)
        )
        i += 1
    return out


def burst(
    background_rate: float,
    burst_rate: float,
    burst_at_s: float,
    burst_len_s: float,
    duration_s: float = 180.0,
    dist: str = "image",
    seed: int = 0,
) -> List[Request]:
    """Steady background + a hard burst — the elastic tier's reason to exist."""
    base = poisson(background_rate, duration_s, dist=dist, seed=seed)
    extra = poisson(burst_rate, burst_len_s, dist=dist, seed=seed + 1)
    for r in extra:
        r.arrival_t += burst_at_s
    reqs = sorted(base + extra, key=lambda r: r.arrival_t)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs
