"""The paper's hybrid testbed, as simulator configuration.

Calibration targets (paper §III):
  * Xception: 110.9 MB weights, 109.4 ms inference.
  * Flask/IIS: single-threaded, 50 s timeout; failure knee ~1200-1300
    sessions/180 s; lowest response time at low load (Fig 4, Fig 8).
  * Docker: RESTful with container-activation overhead (Fig 8).
  * Lambda: median response 300-500 ms up to 6000 sessions/180 s; failure
    rate up to ~60% at 6000 for the 2 GB class, lower for 3 GB (Fig 5).

The TPU analogue maps tiers onto slices (DESIGN.md §2); service times come
from the estimator. `paper_tiers()` gives the calibrated testbed used by the
fig4/5/6/7/8 benchmarks.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.estimator import AppProfile, SliceProfile, xception_profile
from repro.core.request import Tier
from repro.core.tiers import TierConfig, TierSim


def paper_tiers(
    app: AppProfile = None,
    seed: int = 0,
    elastic_mem: str = "3GB",
    interactive_workers: int = 1,
    docker_workers: int = 4,
) -> Dict[Tier, TierSim]:
    """Tier set calibrated to the paper's testbed behaviour."""
    app = app or xception_profile()
    rng = np.random.default_rng(seed)

    # Interactive (Flask/IIS on the local web server, Xeon E-2176M): CPU-class
    # speed calibrated so Xception ~= the paper's 109.4 ms inference + server
    # overhead -> knee at ~180/0.14 ~= 1286 sessions/180 s (paper: 1200-1300).
    flask = TierConfig(
        tier=Tier.FLASK,
        slice_=SliceProfile(chips=1, name="interactive-cpu", speed_factor=3.6e-4),
        n_workers=interactive_workers,
        queue_cap=96,                 # IIS connection backlog analogue
        activation_s=0.02,            # WFastCgi dispatch
        net_bw=200e6,                 # local: negligible upload cost
    )
    # Batch (Docker containers on the in-house GPU node): faster per request
    # but pays container-activation overhead per request (paper Fig 8).
    docker = TierConfig(
        tier=Tier.DOCKER,
        slice_=SliceProfile(chips=1, name="batch-gpu-node", speed_factor=2.4e-3),
        n_workers=docker_workers,
        queue_cap=512,
        activation_s=0.35,
        net_bw=50e6,
    )
    # Elastic (Lambda): per-request instances; the memory class trades failure
    # rate and speed for cost. freq_capacity sets where resource contention
    # bites: 2 GB fails ~60% at 6000 sessions/180 s, 3 GB much less (Fig 5a).
    mem = {"2GB": (2800, 1.6, 1.1e-4), "3GB": (5200, 1.6, 1.6e-4)}[elastic_mem]
    cap, slope, speed = mem
    serverless = TierConfig(
        tier=Tier.SERVERLESS,
        slice_=SliceProfile(chips=1, name=f"elastic-{elastic_mem}", alloc_s=0.25, speed_factor=speed),
        concurrency_limit=3000,
        freq_capacity=cap,
        overload_fail_slope=slope,
        warm_expiry_s=60.0,
        activation_s=0.05,
        net_bw=50e6,
    )
    return {
        Tier.FLASK: TierSim(flask, app, rng),
        Tier.DOCKER: TierSim(docker, app, rng),
        Tier.SERVERLESS: TierSim(serverless, app, rng),
    }
