"""Discrete-event simulation of the hybrid serving estate.

Reproduces the paper's testbed methodology: a load generator (workload.py,
the Artillery analogue) emits requests; the placing policy routes each one at
arrival; tiers model service, queuing, cold starts, timeouts, throttling.
Outputs the paper's metrics (failed rate, session length, response time).

Also implements beyond-paper fault tolerance: hedged requests (straggler
mitigation — a copy is fired at the elastic tier if the primary hasn't
finished by the hedge deadline; first finish wins) and retry-on-failure.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.placing import StraightLinePolicy, place_compat, takes_warmup
from repro.core.request import Request, Tier
from repro.core.telemetry import FrequencyEstimator, Metrics
from repro.core.tiers import TierSim
from repro.core.tracing import Tracer


@dataclass
class SimConfig:
    hedge_after_s: Optional[float] = None     # straggler mitigation
    retry_failed_on_elastic: bool = False     # retry-once fault tolerance
    autoscaler: Optional[object] = None       # core.autoscaler.Autoscaler
    window_s: float = 180.0


class Simulation:
    def __init__(self, policy, tiers: Dict[Tier, TierSim], cfg: SimConfig = SimConfig(),
                 tracer: Optional[Tracer] = None):
        self.policy = policy
        self.tiers = tiers
        self.cfg = cfg
        self.freq = FrequencyEstimator(window_s=cfg.window_s)
        self.metrics = Metrics()
        # optional lifecycle tracing; trace timestamps here are SIM time
        # (seconds on the event-queue clock), never wall time — a trace is
        # internally consistent, do not mix the two bases in one tracer
        self.tracer = tracer
        self._events: List = []
        self._seq = itertools.count()
        self._done: Dict[int, bool] = {}
        self._f_t = 0.0
        self._takes_warmup = takes_warmup(policy)

    def _warmup(self) -> Optional[Dict[Tier, float]]:
        """Per-tier warm-up fractions when any tier binds a live stats probe
        (hybrid sim/real testbeds); None keeps placement purely paper-faithful."""
        snap = {
            t: w for t, sim in self.tiers.items() if (w := sim.warm_fraction()) is not None
        }
        return snap or None

    # -- event plumbing -----------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self._events, (t, next(self._seq), kind, payload))

    # -- tier execution -----------------------------------------------------
    def _start_service(self, req: Request, tier: TierSim, now: float) -> None:
        svc = tier.service_time(req, now)
        req.start_t = now
        if req.trace is not None:
            req.trace.add_span("service", now, now + svc,
                               lane=tier.cfg.tier.name.lower(), service_s=svc)
        if tier.cfg.tier == Tier.SERVERLESS:
            tier.inflight += 1
            tier.warm_instances.append(now + svc)
        else:
            tier.busy += 1
        tier.busy_time += svc
        self._push(now + svc, "finish", (req, tier))

    def _submit(self, req: Request, tier_id: Tier, now: float) -> None:
        tier = self.tiers[tier_id]
        req.tier = tier_id
        fail = tier.admission_failure(now, self._f_t)
        if fail is not None:
            self._fail(req, now, fail)
            return
        if tier.cfg.tier == Tier.SERVERLESS or tier.worker_free():
            self._start_service(req, tier, now)
        elif len(tier.queue) < tier.cfg.queue_cap:
            tier.queue.append(req)
            if req.trace is not None:
                req.trace.event("enqueued", lane=tier_id.name.lower(), t=now,
                                depth=len(tier.queue))
        else:
            self._fail(req, now, "queue-overflow")

    def _fail(self, req: Request, now: float, reason: str) -> None:
        if self._done.get(req.rid):
            return
        if self.cfg.retry_failed_on_elastic and not req.hedged and req.tier != Tier.SERVERLESS:
            req.hedged = True
            if req.trace is not None:
                req.trace.event("retry_spill", t=now, reason=reason)
            self._submit(req, Tier.SERVERLESS, now)
            return
        self._done[req.rid] = True
        req.failed = True
        req.fail_reason = reason
        req.finish_t = now
        self.metrics.record(req)
        if req.trace is not None:
            req.trace.event("failed", t=now, reason=reason)
            self._finish_trace(req)

    def _finish(self, req: Request, tier: TierSim, now: float) -> None:
        if tier.cfg.tier == Tier.SERVERLESS:
            tier.inflight -= 1
        else:
            tier.busy -= 1
            if tier.queue:
                nxt = tier.queue.popleft()
                if now - nxt.arrival_t > nxt.timeout_s:
                    self._fail(nxt, now, "timeout-in-queue")
                else:
                    self._start_service(nxt, tier, now)
        if self._done.get(req.rid):
            return
        if now - req.arrival_t > req.timeout_s:
            self._fail(req, now, "timeout")
            return
        self._done[req.rid] = True
        req.finish_t = now
        tier.served += 1
        self.metrics.record(req)
        self._finish_trace(req)

    def _finish_trace(self, req: Request) -> None:
        if self.tracer is not None and req.trace is not None:
            self.tracer.finish(
                req.trace,
                tier=req.tier.name if req.tier is not None else None,
                failed=req.failed, fail_reason=req.fail_reason,
                response_s=req.response_s, hedged=req.hedged,
            )

    # -- main loop ------------------------------------------------------------
    def run(self, requests: List[Request]) -> Metrics:
        for r in requests:
            self._push(r.arrival_t, "arrival", r)
        last_scale = 0.0
        while self._events:
            now, _, kind, payload = heapq.heappop(self._events)
            if kind == "arrival":
                req: Request = payload
                self.freq.observe(now)
                f_t = self.freq.frequency(now)
                self._f_t = f_t
                flask_free = self.tiers[Tier.FLASK].free_slots()
                docker_free = self.tiers[Tier.DOCKER].free_slots()
                d = place_compat(
                    self.policy,
                    req,
                    f_t,
                    flask_free,
                    docker_free,
                    self._warmup,
                    self._takes_warmup,
                )
                if self.tracer is not None:
                    req.trace = self.tracer.begin(
                        req.rid, t0=now, data_size=req.data_size, model=req.model
                    )
                    if req.trace is not None:
                        req.trace.add_span(
                            "placement", now, now, f_t=f_t, flask_free=flask_free,
                            docker_free=docker_free, tier=d.tier.name, reason=d.reason,
                        )
                self._submit(req, d.tier, now)
                if self.cfg.hedge_after_s is not None and d.tier != Tier.SERVERLESS:
                    self._push(now + self.cfg.hedge_after_s, "hedge", req)
                if self.cfg.autoscaler is not None and now - last_scale > 1.0:
                    self.cfg.autoscaler.step(self, now, f_t)
                    last_scale = now
            elif kind == "finish":
                req, tier = payload
                self._finish(req, tier, now)
            elif kind == "hedge":
                req = payload
                if not self._done.get(req.rid) and req.start_t is None:
                    # still queued somewhere: fire a copy at the elastic tier
                    req.hedged = True
                    if req.trace is not None:
                        req.trace.event("hedge_fired", t=now)
                    self._submit(req, Tier.SERVERLESS, now)
        return self.metrics

    # -- introspection ---------------------------------------------------------
    def tier_stats(self) -> Dict[str, dict]:
        out = {}
        for t, sim in self.tiers.items():
            out[t.name.lower()] = {
                "served": sim.served,
                "busy_time_s": round(sim.busy_time, 2),
            }
        return out
