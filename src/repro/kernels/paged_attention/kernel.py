"""Paged GQA decode attention — Pallas TPU kernel (vLLM-style block tables).

One new token per sequence attends over a KV cache stored as fixed-size
pages in a shared pool; a per-sequence block table maps logical block i to a
physical page. Grid (B, KV, n_pages): each step DMAs ONE physical page of
K/V into VMEM — the page id comes from the scalar-prefetched block table, so
the index map itself performs the gather and the kernel body is identical in
shape to the dense flash-decoding kernel (online softmax over page blocks).

Unused block-table entries point at the reserved null page 0, so every index
the DMA engine sees is in-bounds; the length mask kills their scores.

VMEM working set per step: G x hd (q) + 2 x ps x hd (one K and one V page)
+ G x hd f32 accumulator — independent of sequence length and pool size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, ps, n_p, scale, softcap):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[b]                                  # scalar int32
    t_start = ip * ps

    @pl.when(t_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (G, ps)
        if softcap:
            # gemma-style logit softcap, applied pre-mask so capped scores
            # match the dense decode path bit-for-bit
            s = jnp.tanh(s / softcap) * softcap
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < valid, s, NEG_INF)
        m_prev = m_ref[...]                              # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "softcap"))
def paged_attention_grouped(
    q: jax.Array,          # (B, KV, G, hd) — one token per sequence
    pool_k: jax.Array,     # (num_pages, KV, ps, hd) shared page pool
    pool_v: jax.Array,
    block_tab: jax.Array,  # (B, P) int32 physical page per logical block
    lengths: jax.Array,    # (B,) int32 valid tokens per sequence
    interpret: bool = True,
    softcap: float = 0.0,
) -> jax.Array:
    B, KV, G, hd = q.shape
    ps = pool_k.shape[2]
    n_p = block_tab.shape[1]
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, ps=ps, n_p=n_p, scale=scale, softcap=softcap)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, lens, tab: (b, h, 0, 0)),
            # the gather: block ip of sequence b lives in physical page tab[b, ip]
            pl.BlockSpec((1, 1, ps, hd), lambda b, h, ip, lens, tab: (tab[b, ip], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), lambda b, h, ip, lens, tab: (tab[b, ip], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, lens, tab: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, block_tab, q, pool_k, pool_v)


# ---------------------------------------------------------------------------
# Prefill write — the decode gather's twin: scatter one prompt's K/V through
# its block-table row into the pool. Grid (n_blocks,): step ib transposes one
# ps-token chunk of the incoming K/V into page layout and lands it in
# physical page tab_row[ib] — the scalar-prefetched row drives the OUTPUT
# index map, so the scatter happens in the write-back DMA and the kernel body
# is a pure VMEM transpose. The pools are input/output-aliased: only visited
# pages change, everything else is untouched in place. Bucket padding past
# the sequence's allocated pages carries tab_row entries of the reserved null
# page 0 — those trailing steps all land on (and fully overwrite) the null
# page, which is garbage by contract and never read back.
# ---------------------------------------------------------------------------


def _write_kernel(tab_ref, k_ref, v_ref, pool_k_ref, pool_v_ref, ok_ref, ov_ref):
    # k/v block: (1, ps, KV, hd) token-major -> page layout (KV, ps, hd)
    ok_ref[0] = jnp.transpose(k_ref[0], (1, 0, 2)).astype(ok_ref.dtype)
    ov_ref[0] = jnp.transpose(v_ref[0], (1, 0, 2)).astype(ov_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_write_grouped(
    pool_k: jax.Array,     # (num_pages, KV, ps, hd) shared page pool (donated)
    pool_v: jax.Array,
    k: jax.Array,          # (1, Lp, KV, hd) — Lp a multiple of ps
    v: jax.Array,
    tab_row: jax.Array,    # (P,) int32, P >= Lp // ps
    interpret: bool = True,
):
    """Returns (new_pool_k, new_pool_v); Lp % ps must be 0 (bucketed prefill
    guarantees it — ops.py falls back to the jnp ref for ragged lengths)."""
    num_pages, KV, ps, hd = pool_k.shape
    Lp = k.shape[1]
    assert Lp % ps == 0, f"Lp={Lp} not a page multiple (ps={ps})"
    nb = Lp // ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, ps, KV, hd), lambda ib, tab: (0, ib, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd), lambda ib, tab: (0, ib, 0, 0)),
            # the pools stay in place (aliased outputs); no copy-in
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            # the scatter: chunk ib of the prompt lands in page tab[ib]
            pl.BlockSpec((1, KV, ps, hd), lambda ib, tab: (tab[ib], 0, 0, 0)),
            pl.BlockSpec((1, KV, ps, hd), lambda ib, tab: (tab[ib], 0, 0, 0)),
        ],
    )
    return pl.pallas_call(
        _write_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(pool_k.shape, pool_k.dtype),
            jax.ShapeDtypeStruct(pool_v.shape, pool_v.dtype),
        ],
        # operand indices count the scalar-prefetch arg: tab=0, k=1, v=2
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(tab_row, k, v, pool_k, pool_v)
