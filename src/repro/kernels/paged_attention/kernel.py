"""Paged GQA decode attention — Pallas TPU kernel (vLLM-style block tables).

One new token per sequence attends over a KV cache stored as fixed-size
pages in a shared pool; a per-sequence block table maps logical block i to a
physical page. Grid (B, KV, n_pages): each step DMAs ONE physical page of
K/V into VMEM — the page id comes from the scalar-prefetched block table, so
the index map itself performs the gather and the kernel body is identical in
shape to the dense flash-decoding kernel (online softmax over page blocks).

Unused block-table entries point at the reserved null page 0, so every index
the DMA engine sees is in-bounds; the length mask kills their scores.

Two orthogonal extensions ride the same grid:

* **int8 pools** (``pool_ks``/``pool_vs``): K/V pages are stored int8 with a
  bf16 scale per (page slot, head group); the kernel DMAs the int8 page plus
  its (ps, 1) scale column and dequantizes IN VMEM right after the gather —
  the decode hot loop reads ~hd/(hd+2) of the fp page bytes from HBM and the
  MXU sees f32 operands as before.
* **chained block tables** (``l2_tab``): ``block_tab`` becomes a first-level
  row of *table-page* ids into a shared (n_rows, tpp) second-level pool, so
  the per-sequence table width no longer caps context at
  ``max_seq_len`` — the scalar-prefetched index map simply chases one more
  pointer: page(ip) = l2[l1[b, ip // tpp], ip % tpp]. Row 0 of l2 is the
  reserved all-null table page (the null-page contract, one level up).

VMEM working set per step: G x hd (q) + 2 x ps x hd (one K and one V page)
+ G x hd f32 accumulator — independent of sequence length and pool size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(*refs, ps, n_p, scale, softcap, quant, chained):
    ns = 3 if chained else 2
    len_ref = refs[0]
    if quant:
        q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, acc_ref, m_ref, l_ref = refs[ns:]
    else:
        q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref = refs[ns:]
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[b]                                  # scalar int32
    t_start = ip * ps

    @pl.when(t_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        if quant:
            # dequant-on-gather: the int8 page and its (ps, 1) scale column
            # were DMA'd together; one broadcast multiply in VMEM restores
            # f32 operands before the MXU pass
            k = k * ks_ref[0, 0].astype(jnp.float32)
            v = v * vs_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (G, ps)
        if softcap:
            # gemma-style logit softcap, applied pre-mask so capped scores
            # match the dense decode path bit-for-bit
            s = jnp.tanh(s / softcap) * softcap
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < valid, s, NEG_INF)
        m_prev = m_ref[...]                              # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "softcap"))
def paged_attention_grouped(
    q: jax.Array,          # (B, KV, G, hd) — one token per sequence
    pool_k: jax.Array,     # (num_pages, KV, ps, hd) shared page pool
    pool_v: jax.Array,
    block_tab: jax.Array,  # (B, P) physical pages — or (B, W1) l1 rows (chained)
    lengths: jax.Array,    # (B,) int32 valid tokens per sequence
    interpret: bool = True,
    softcap: float = 0.0,
    pool_ks: jax.Array | None = None,   # (num_pages, KV, ps, 1) bf16 scales
    pool_vs: jax.Array | None = None,
    l2_tab: jax.Array | None = None,    # (n_rows, tpp) second-level table pool
) -> jax.Array:
    B, KV, G, hd = q.shape
    ps = pool_k.shape[2]
    quant = pool_ks is not None
    chained = l2_tab is not None
    scale = 1.0 / (hd ** 0.5)

    if chained:
        tpp = l2_tab.shape[1]
        n_p = block_tab.shape[1] * tpp

        def page(ip, l1, l2, b):
            # two-level gather: logical block ip -> table page -> data page
            return l2[l1[b, ip // tpp], ip % tpp]

        def qmap(b, h, ip, lens, l1, l2):
            return (b, h, 0, 0)

        def kvmap(b, h, ip, lens, l1, l2):
            return (page(ip, l1, l2, b), h, 0, 0)
    else:
        n_p = block_tab.shape[1]

        def qmap(b, h, ip, lens, tab):
            return (b, h, 0, 0)

        def kvmap(b, h, ip, lens, tab):
            # the gather: block ip of sequence b lives in page tab[b, ip]
            return (tab[b, ip], h, 0, 0)

    kernel = functools.partial(
        _kernel, ps=ps, n_p=n_p, scale=scale, softcap=softcap,
        quant=quant, chained=chained,
    )
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), qmap),
        pl.BlockSpec((1, 1, ps, hd), kvmap),
        pl.BlockSpec((1, 1, ps, hd), kvmap),
    ]
    operands = [q, pool_k, pool_v]
    if quant:
        in_specs += [
            pl.BlockSpec((1, 1, ps, 1), kvmap),
            pl.BlockSpec((1, 1, ps, 1), kvmap),
        ]
        operands += [pool_ks, pool_vs]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3 if chained else 2,
        grid=(B, KV, n_p),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd), qmap),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    scalars = [lengths, block_tab] + ([l2_tab] if chained else [])
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(*scalars, *operands)


# ---------------------------------------------------------------------------
# Prefill write — the decode gather's twin: scatter one prompt's K/V through
# its block-table row into the pool. Grid (n_blocks,): step ib transposes one
# ps-token chunk of the incoming K/V into page layout and lands it in
# physical page tab_row[ib] — the scalar-prefetched row drives the OUTPUT
# index map, so the scatter happens in the write-back DMA and the kernel body
# is a pure VMEM transpose. The pools are input/output-aliased: only visited
# pages change, everything else is untouched in place. Bucket padding past
# the sequence's allocated pages carries tab_row entries of the reserved null
# page 0 — those trailing steps all land on (and fully overwrite) the null
# page, which is garbage by contract and never read back.
#
# The quantized variant fuses the int8 conversion into the same VMEM pass:
# per (token, head) absmax scales (models/quant.py's KV idiom, bit-identical
# to the jnp ref) are computed on the transposed page and written to the
# aliased scale pools alongside the int8 values — quantization happens at
# write time, so readers never see an fp page.
# ---------------------------------------------------------------------------


def _write_kernel(tab_ref, k_ref, v_ref, pool_k_ref, pool_v_ref, ok_ref, ov_ref):
    # k/v block: (1, ps, KV, hd) token-major -> page layout (KV, ps, hd)
    ok_ref[0] = jnp.transpose(k_ref[0], (1, 0, 2)).astype(ok_ref.dtype)
    ov_ref[0] = jnp.transpose(v_ref[0], (1, 0, 2)).astype(ov_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_write_grouped(
    pool_k: jax.Array,     # (num_pages, KV, ps, hd) shared page pool (donated)
    pool_v: jax.Array,
    k: jax.Array,          # (1, Lp, KV, hd) — Lp a multiple of ps
    v: jax.Array,
    tab_row: jax.Array,    # (P,) int32, P >= Lp // ps
    interpret: bool = True,
):
    """Returns (new_pool_k, new_pool_v); Lp % ps must be 0 (bucketed prefill
    guarantees it — ops.py falls back to the jnp ref for ragged lengths)."""
    num_pages, KV, ps, hd = pool_k.shape
    Lp = k.shape[1]
    assert Lp % ps == 0, f"Lp={Lp} not a page multiple (ps={ps})"
    nb = Lp // ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, ps, KV, hd), lambda ib, tab: (0, ib, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd), lambda ib, tab: (0, ib, 0, 0)),
            # the pools stay in place (aliased outputs); no copy-in
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            # the scatter: chunk ib of the prompt lands in page tab[ib]
            pl.BlockSpec((1, KV, ps, hd), lambda ib, tab: (tab[ib], 0, 0, 0)),
            pl.BlockSpec((1, KV, ps, hd), lambda ib, tab: (tab[ib], 0, 0, 0)),
        ],
    )
    return pl.pallas_call(
        _write_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(pool_k.shape, pool_k.dtype),
            jax.ShapeDtypeStruct(pool_v.shape, pool_v.dtype),
        ],
        # operand indices count the scalar-prefetch arg: tab=0, k=1, v=2
        input_output_aliases={3: 0, 4: 1},
        interpret=interpret,
    )(tab_row, k, v, pool_k, pool_v)


def _write_kernel_quant(
    tab_ref, k_ref, v_ref,
    pool_k_ref, pool_v_ref, pool_ks_ref, pool_vs_ref,
    ok_ref, ov_ref, oks_ref, ovs_ref,
):
    # quantize-at-write: transpose to page layout, absmax per (token, head),
    # land int8 values + bf16 scales in one pass (same op order as the jnp
    # ref / models.quant.quantize_kv, so parity is exact on the int8 bits)
    k = jnp.transpose(k_ref[0], (1, 0, 2)).astype(jnp.float32)   # (KV, ps, hd)
    v = jnp.transpose(v_ref[0], (1, 0, 2)).astype(jnp.float32)
    ks = jnp.maximum(jnp.max(jnp.abs(k), axis=-1, keepdims=True) / 127.0, 1e-8)
    vs = jnp.maximum(jnp.max(jnp.abs(v), axis=-1, keepdims=True) / 127.0, 1e-8)
    ok_ref[0] = jnp.clip(jnp.round(k / ks), -127, 127).astype(jnp.int8)
    ov_ref[0] = jnp.clip(jnp.round(v / vs), -127, 127).astype(jnp.int8)
    oks_ref[0] = ks.astype(oks_ref.dtype)
    ovs_ref[0] = vs.astype(ovs_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_prefill_write_grouped_quant(
    pool_k: jax.Array,     # (num_pages, KV, ps, hd) int8 page pool (donated)
    pool_v: jax.Array,
    pool_ks: jax.Array,    # (num_pages, KV, ps, 1) bf16 scale pool (donated)
    pool_vs: jax.Array,
    k: jax.Array,          # (1, Lp, KV, hd) fp activations — Lp % ps == 0
    v: jax.Array,
    tab_row: jax.Array,    # (P,) int32, P >= Lp // ps
    interpret: bool = True,
):
    """Returns (new_pool_k, new_pool_v, new_pool_ks, new_pool_vs)."""
    num_pages, KV, ps, hd = pool_k.shape
    Lp = k.shape[1]
    assert Lp % ps == 0, f"Lp={Lp} not a page multiple (ps={ps})"
    nb = Lp // ps

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, ps, KV, hd), lambda ib, tab: (0, ib, 0, 0)),
            pl.BlockSpec((1, ps, KV, hd), lambda ib, tab: (0, ib, 0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, KV, ps, hd), lambda ib, tab: (tab[ib], 0, 0, 0)),
            pl.BlockSpec((1, KV, ps, hd), lambda ib, tab: (tab[ib], 0, 0, 0)),
            pl.BlockSpec((1, KV, ps, 1), lambda ib, tab: (tab[ib], 0, 0, 0)),
            pl.BlockSpec((1, KV, ps, 1), lambda ib, tab: (tab[ib], 0, 0, 0)),
        ],
    )
    return pl.pallas_call(
        _write_kernel_quant,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct(pool_k.shape, pool_k.dtype),
            jax.ShapeDtypeStruct(pool_v.shape, pool_v.dtype),
            jax.ShapeDtypeStruct(pool_ks.shape, pool_ks.dtype),
            jax.ShapeDtypeStruct(pool_vs.shape, pool_vs.dtype),
        ],
        # operand indices count the scalar-prefetch arg: tab=0, k=1, v=2
        input_output_aliases={3: 0, 4: 1, 5: 2, 6: 3},
        interpret=interpret,
    )(tab_row, k, v, pool_k, pool_v, pool_ks, pool_vs)
