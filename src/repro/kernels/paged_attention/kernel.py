"""Paged GQA decode attention — Pallas TPU kernel (vLLM-style block tables).

One new token per sequence attends over a KV cache stored as fixed-size
pages in a shared pool; a per-sequence block table maps logical block i to a
physical page. Grid (B, KV, n_pages): each step DMAs ONE physical page of
K/V into VMEM — the page id comes from the scalar-prefetched block table, so
the index map itself performs the gather and the kernel body is identical in
shape to the dense flash-decoding kernel (online softmax over page blocks).

Unused block-table entries point at the reserved null page 0, so every index
the DMA engine sees is in-bounds; the length mask kills their scores.

VMEM working set per step: G x hd (q) + 2 x ps x hd (one K and one V page)
+ G x hd f32 accumulator — independent of sequence length and pool size.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, tab_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, ps, n_p, scale):
    b = pl.program_id(0)
    ip = pl.program_id(2)

    @pl.when(ip == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[b]                                  # scalar int32
    t_start = ip * ps

    @pl.when(t_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (ps, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (G, ps)
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < valid, s, NEG_INF)
        m_prev = m_ref[...]                              # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ip == n_p - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_grouped(
    q: jax.Array,          # (B, KV, G, hd) — one token per sequence
    pool_k: jax.Array,     # (num_pages, KV, ps, hd) shared page pool
    pool_v: jax.Array,
    block_tab: jax.Array,  # (B, P) int32 physical page per logical block
    lengths: jax.Array,    # (B,) int32 valid tokens per sequence
    interpret: bool = True,
) -> jax.Array:
    B, KV, G, hd = q.shape
    ps = pool_k.shape[2]
    n_p = block_tab.shape[1]
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, ps=ps, n_p=n_p, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_p),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, lens, tab: (b, h, 0, 0)),
            # the gather: block ip of sequence b lives in physical page tab[b, ip]
            pl.BlockSpec((1, 1, ps, hd), lambda b, h, ip, lens, tab: (tab[b, ip], h, 0, 0)),
            pl.BlockSpec((1, 1, ps, hd), lambda b, h, ip, lens, tab: (tab[b, ip], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, ip, lens, tab: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, block_tab, q, pool_k, pool_v)
