"""Model-facing wrappers for the paged KV pool: decode-time gather-attention
over block tables, and its write-side twin — the prefill scatter that lands a
whole prompt's K/V in the pool without ever materializing a dense per-length
staging cache. Every entry point carries an optional int8 leg (scale pools
alongside the value pools — quantize at write, dequantize on gather) and the
decode path additionally accepts chained two-level block tables."""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_grouped,
    paged_prefill_write_grouped,
    paged_prefill_write_grouped_quant,
)
from repro.kernels.paged_attention.ref import (
    gather_kv,
    paged_attention_ref,
    paged_prefill_write_quant_ref,
    paged_prefill_write_ref,
    paged_verify_write_quant_ref,
    paged_verify_write_ref,
)
from repro.models.quant import dequantize_kv

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def _shift_row(tab, offset, ps):
    """Shift a block-table row left by ``offset // ps`` pages (chunked
    prefill: chunk token t lands at absolute position offset + t). Entries
    shifted past the row's end map to the reserved null page 0."""
    P = tab.shape[0]
    idx = jnp.asarray(offset, jnp.int32) // ps + jnp.arange(P, dtype=jnp.int32)
    return jnp.where(idx < P, tab[jnp.clip(idx, 0, P - 1)], 0)  # 0 == null page


def paged_prefill_write(pool_k, pool_v, k, v, tab_row, use_pallas: bool = True,
                        offset=None):
    """Scatter one prefilled prompt's (or prompt chunk's) K/V through its
    block-table row.

    pool_k/pool_v: (num_pages, KV, ps, hd); k/v: (1, Lp, KV, hd) — Lp may be
    bucket-padded past the sequence's allocated pages, in which case
    ``tab_row[t // ps]`` is the reserved null page 0 and the pad writes are
    absorbed there (never read: the length mask kills those positions).
    Returns (new_pool_k, new_pool_v).

    ``offset`` (scalar int32, page-multiple) makes this the CHUNKED prefill
    write: chunk token t lands at absolute position offset + t, realized by
    shifting the block-table row left by offset // ps pages before the
    scatter — the kernels keep their token-t -> row[t // ps] contract
    untouched. Row entries shifted past the table's end map to the reserved
    null page 0, so a tail chunk whose bucket padding overruns the allocated
    pages is absorbed exactly like whole-prompt bucket padding.

    The Pallas kernel requires Lp to be a page multiple (bucketed prefill
    always is); ragged lengths (bucketing off) fall back to the jnp ref."""
    ps = pool_k.shape[2]
    Lp = k.shape[1]
    tab = jnp.asarray(tab_row, jnp.int32)
    if offset is not None:
        tab = _shift_row(tab, offset, ps)
    if use_pallas and Lp % ps == 0:
        return paged_prefill_write_grouped(pool_k, pool_v, k, v, tab, interpret=_INTERPRET)
    return paged_prefill_write_ref(pool_k, pool_v, k, v, tab)


def paged_prefill_write_quant(pool_k, pool_v, pool_ks, pool_vs, k, v, tab_row,
                              use_pallas: bool = True, offset=None):
    """Int8 leg of ``paged_prefill_write``: quantization happens AT WRITE
    TIME — fused into the Pallas write kernel's VMEM pass on the kernel
    path, via ``models/quant.py``'s KV idiom on the jnp path (bit-identical
    by construction). Returns the four updated pools (values + scales)."""
    ps = pool_k.shape[2]
    Lp = k.shape[1]
    tab = jnp.asarray(tab_row, jnp.int32)
    if offset is not None:
        tab = _shift_row(tab, offset, ps)
    if use_pallas and Lp % ps == 0:
        return paged_prefill_write_grouped_quant(
            pool_k, pool_v, pool_ks, pool_vs, k, v, tab, interpret=_INTERPRET
        )
    return paged_prefill_write_quant_ref(pool_k, pool_v, pool_ks, pool_vs, k, v, tab)


def paged_verify_write(pool_k, pool_v, k, v, tab_row, offset):
    """Scatter a speculative verify stripe's K/V (1, S, KV, hd) through a
    block-table row at an arbitrary (non-page-multiple) token offset — the
    write-side of the speculative-decode verify pass. S is k+1 proposal
    tokens (single digits), far below any Pallas grid's useful occupancy, so
    the jnp per-token scatter IS the kernel on every path; the read side
    reuses ``paged_gather_context`` + absolute-position masking exactly like
    a chunked-prefill chunk."""
    tab = jnp.asarray(tab_row, jnp.int32)
    return paged_verify_write_ref(pool_k, pool_v, k, v, tab, offset)


def paged_verify_write_quant(pool_k, pool_v, pool_ks, pool_vs, k, v, tab_row, offset):
    """Int8 leg of ``paged_verify_write``: quantize the stripe per (token,
    head) and land values + scales through the same per-token page indexing,
    so speculative decoding rides the one quantized storage format."""
    tab = jnp.asarray(tab_row, jnp.int32)
    return paged_verify_write_quant_ref(pool_k, pool_v, pool_ks, pool_vs, k, v, tab, offset)


def paged_gather_context(pool_k, pool_v, tab_row, pool_ks=None, pool_vs=None):
    """Materialize one sequence's dense K/V context view from the page pool:
    (num_pages, KV, ps, hd) x (P,) -> two (1, P*ps, KV, hd) arrays where
    index t holds the token at logical position t (null-row entries carry
    page-0 garbage — callers mask them out positionally).

    This is the read-side of the chunked prefill: each chunk's queries
    attend over every previously written position plus the chunk itself, so
    the bounded-compilation contract holds (the gathered shape is fixed at
    the row width * page_size regardless of how much context is live).

    With ``pool_ks``/``pool_vs`` the pools are int8 and the gathered view is
    dequantized (f32) — chunked prefill and speculative verify read the same
    quantized storage the decode kernel does."""
    tab = jnp.asarray(tab_row, jnp.int32)[None, :]            # (1, P)
    k = gather_kv(pool_k, tab)                                # (1, KV, P*ps, hd)
    v = gather_kv(pool_v, tab)
    if pool_ks is not None:
        k = dequantize_kv(k, gather_kv(pool_ks, tab), jnp.float32)
        v = dequantize_kv(v, gather_kv(pool_vs, tab), jnp.float32)
    return k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3)


def paged_attention(q, pool_k, pool_v, block_tab, lengths, use_pallas: bool = True,
                    softcap: float = 0.0, pool_ks=None, pool_vs=None, l2_tab=None):
    """q: (B, S=1, H, hd); pools: (num_pages, KV, ps, hd); block_tab: (B, P)
    physical pages — or, with ``l2_tab`` (n_rows, tpp), the (B, W1)
    first-level rows of a chained table; lengths: (B,) valid tokens per
    sequence. ``pool_ks``/``pool_vs`` select the int8 dequant-on-gather
    path. Returns (B, 1, H, hd)."""
    B, S, H, hd = q.shape
    KV = pool_k.shape[1]
    G = H // KV
    qg = q[:, 0].reshape(B, KV, G, hd)
    lens = jnp.asarray(lengths, jnp.int32)
    tab = jnp.asarray(block_tab, jnp.int32)
    l2 = None if l2_tab is None else jnp.asarray(l2_tab, jnp.int32)
    if use_pallas:
        o = paged_attention_grouped(
            qg, pool_k, pool_v, tab, lens, interpret=_INTERPRET, softcap=softcap,
            pool_ks=pool_ks, pool_vs=pool_vs, l2_tab=l2,
        )
    else:
        o = paged_attention_ref(
            qg, pool_k, pool_v, tab, lens, softcap=softcap,
            pool_ks=pool_ks, pool_vs=pool_vs, l2_tab=l2,
        )
    return o.reshape(B, 1, H, hd)
