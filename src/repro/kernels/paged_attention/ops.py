"""Model-facing wrapper: (B, 1, H, hd) q against a shared KV page pool."""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import paged_attention_grouped
from repro.kernels.paged_attention.ref import paged_attention_ref

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def paged_attention(q, pool_k, pool_v, block_tab, lengths, use_pallas: bool = True):
    """q: (B, S=1, H, hd); pools: (num_pages, KV, ps, hd); block_tab: (B, P);
    lengths: (B,) valid tokens per sequence. Returns (B, 1, H, hd)."""
    B, S, H, hd = q.shape
    KV = pool_k.shape[1]
    G = H // KV
    qg = q[:, 0].reshape(B, KV, G, hd)
    lens = jnp.asarray(lengths, jnp.int32)
    tab = jnp.asarray(block_tab, jnp.int32)
    if use_pallas:
        o = paged_attention_grouped(qg, pool_k, pool_v, tab, lens, interpret=_INTERPRET)
    else:
        o = paged_attention_ref(qg, pool_k, pool_v, tab, lens)
    return o.reshape(B, 1, H, hd)
