"""Model-facing wrappers for the paged KV pool: decode-time gather-attention
over block tables, and its write-side twin — the prefill scatter that lands a
whole prompt's K/V in the pool without ever materializing a dense per-length
staging cache."""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.paged_attention.kernel import (
    paged_attention_grouped,
    paged_prefill_write_grouped,
)
from repro.kernels.paged_attention.ref import (
    paged_attention_ref,
    paged_prefill_write_ref,
)

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def paged_prefill_write(pool_k, pool_v, k, v, tab_row, use_pallas: bool = True):
    """Scatter one prefilled prompt's K/V through its block-table row.

    pool_k/pool_v: (num_pages, KV, ps, hd); k/v: (1, Lp, KV, hd) — Lp may be
    bucket-padded past the sequence's allocated pages, in which case
    ``tab_row[t // ps]`` is the reserved null page 0 and the pad writes are
    absorbed there (never read: the length mask kills those positions).
    Returns (new_pool_k, new_pool_v).

    The Pallas kernel requires Lp to be a page multiple (bucketed prefill
    always is); ragged lengths (bucketing off) fall back to the jnp ref."""
    ps = pool_k.shape[2]
    Lp = k.shape[1]
    tab = jnp.asarray(tab_row, jnp.int32)
    if use_pallas and Lp % ps == 0:
        return paged_prefill_write_grouped(pool_k, pool_v, k, v, tab, interpret=_INTERPRET)
    return paged_prefill_write_ref(pool_k, pool_v, k, v, tab)


def paged_attention(q, pool_k, pool_v, block_tab, lengths, use_pallas: bool = True,
                    softcap: float = 0.0):
    """q: (B, S=1, H, hd); pools: (num_pages, KV, ps, hd); block_tab: (B, P);
    lengths: (B,) valid tokens per sequence. Returns (B, 1, H, hd)."""
    B, S, H, hd = q.shape
    KV = pool_k.shape[1]
    G = H // KV
    qg = q[:, 0].reshape(B, KV, G, hd)
    lens = jnp.asarray(lengths, jnp.int32)
    tab = jnp.asarray(block_tab, jnp.int32)
    if use_pallas:
        o = paged_attention_grouped(
            qg, pool_k, pool_v, tab, lens, interpret=_INTERPRET, softcap=softcap
        )
    else:
        o = paged_attention_ref(qg, pool_k, pool_v, tab, lens, softcap=softcap)
    return o.reshape(B, 1, H, hd)
