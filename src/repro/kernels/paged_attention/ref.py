"""Pure-jnp oracles for the paged KV-pool kernels: decode gather-attention
(gather then dense) and the prefill write scatter (`.at[].set` through the
block-table row)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def gather_kv(pool: jnp.ndarray, block_tab: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense (B, KV, P*ps, hd) view of a paged pool.

    pool: (num_pages, KV, ps, hd); block_tab: (B, P) int32.
    """
    B, P = block_tab.shape
    _, KV, ps, hd = pool.shape
    g = pool[block_tab]                       # (B, P, KV, ps, hd)
    return g.transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)


def paged_attention_ref(q, pool_k, pool_v, block_tab, lengths, softcap: float = 0.0):
    """q: (B, KV, G, hd); pools: (num_pages, KV, ps, hd); lengths: (B,)."""
    k = gather_kv(pool_k, block_tab)
    v = gather_kv(pool_v, block_tab)
    return decode_attention_ref(q, k, v, lengths, softcap=softcap)


def paged_verify_write_ref(pool_k, pool_v, k, v, tab_row, offset):
    """Scatter a short verify stripe (1, S, KV, hd) through a block-table row
    at an ARBITRARY token offset: token t lands at absolute position
    offset + t, i.e. (tab_row[(offset + t) // ps], (offset + t) % ps).

    Unlike the prefill write's page-shift trick (page-multiple offsets
    only), the page index is computed per token — S is tiny (k+1 spec
    tokens), so a plain ``.at[].set`` scatter is the whole kernel. Positions
    whose page index runs past the table width land on the reserved null
    page 0 (same absorption contract as bucket padding)."""
    ps = pool_k.shape[2]
    KV = pool_k.shape[1]
    S = k.shape[1]
    P = tab_row.shape[0]
    t = jnp.asarray(offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    page_idx = t // ps
    pages = jnp.where(page_idx < P, tab_row[jnp.clip(page_idx, 0, P - 1)], 0)
    offs = t % ps
    kvh = jnp.arange(KV)
    new_k = pool_k.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        k[0].astype(pool_k.dtype)
    )
    new_v = pool_v.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        v[0].astype(pool_v.dtype)
    )
    return new_k, new_v


def paged_prefill_write_ref(pool_k, pool_v, k, v, tab_row):
    """Scatter one prefilled prompt's K/V through its block-table row.

    pool_k/pool_v: (num_pages, KV, ps, hd); k/v: (1, Lp, KV, hd) — Lp may be
    bucket-padded past the sequence's allocated pages, in which case
    ``tab_row[t // ps]`` is the reserved null page 0 and the pad writes are
    absorbed there (never read: the length mask kills those positions).
    Returns (new_pool_k, new_pool_v)."""
    ps = pool_k.shape[2]
    KV = pool_k.shape[1]
    Lp = k.shape[1]
    t = jnp.arange(Lp)
    pages = tab_row[t // ps]
    offs = t % ps
    kvh = jnp.arange(KV)
    new_k = pool_k.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        k[0].astype(pool_k.dtype)
    )
    new_v = pool_v.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        v[0].astype(pool_v.dtype)
    )
    return new_k, new_v
