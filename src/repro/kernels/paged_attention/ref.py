"""Pure-jnp oracles for the paged KV-pool kernels: decode gather-attention
(gather then dense), the prefill write scatter (`.at[].set` through the
block-table row), the int8-pool legs (quantize-at-write / dequantize-on-
gather, sharing ``models/quant.py``'s KV quant idiom so kernel-vs-ref parity
is exact on the int8 tensors), and the chained-table flattener (two-level
block tables reduce to a flat physical row for every oracle)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.models.quant import dequantize_kv, quantize_kv


def chain_rows(l1_tab: jnp.ndarray, l2_tab: jnp.ndarray) -> jnp.ndarray:
    """Flatten two-level block tables to the flat physical row they encode.

    l1_tab: (B, W1) int32 — per-sequence row of *table-page* ids; l2_tab:
    (n_rows, tpp) int32 — pool of second-level rows holding physical page
    ids. Logical block i of sequence b lives in physical page
    ``l2_tab[l1_tab[b, i // tpp], i % tpp]``; row 0 of l2_tab is the
    reserved all-null table page, so unused l1 entries resolve to the null
    data page. Returns (B, W1 * tpp) int32.
    """
    B, W1 = l1_tab.shape
    tpp = l2_tab.shape[1]
    return l2_tab[l1_tab].reshape(B, W1 * tpp)


def gather_kv(pool: jnp.ndarray, block_tab: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense (B, KV, P*ps, hd) view of a paged pool.

    pool: (num_pages, KV, ps, hd); block_tab: (B, P) int32.
    """
    B, P = block_tab.shape
    _, KV, ps, hd = pool.shape
    g = pool[block_tab]                       # (B, P, KV, ps, hd)
    return g.transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)


def paged_attention_ref(q, pool_k, pool_v, block_tab, lengths, softcap: float = 0.0,
                        pool_ks=None, pool_vs=None, l2_tab=None):
    """q: (B, KV, G, hd); pools: (num_pages, KV, ps, hd); lengths: (B,).

    With ``pool_ks``/``pool_vs`` (int8 pool + per-(page-slot, head) scale
    pools) the gathered K/V is dequantized before the dense oracle — the
    dequant-on-gather contract the Pallas kernel implements in VMEM. With
    ``l2_tab``, ``block_tab`` is the first-level table of page-of-pages and
    is flattened through ``chain_rows`` first."""
    tab = chain_rows(block_tab, l2_tab) if l2_tab is not None else block_tab
    k = gather_kv(pool_k, tab)
    v = gather_kv(pool_v, tab)
    if pool_ks is not None:
        k = dequantize_kv(k, gather_kv(pool_ks, tab), jnp.float32)
        v = dequantize_kv(v, gather_kv(pool_vs, tab), jnp.float32)
    return decode_attention_ref(q, k, v, lengths, softcap=softcap)


def paged_verify_write_ref(pool_k, pool_v, k, v, tab_row, offset):
    """Scatter a short verify stripe (1, S, KV, hd) through a block-table row
    at an ARBITRARY token offset: token t lands at absolute position
    offset + t, i.e. (tab_row[(offset + t) // ps], (offset + t) % ps).

    Unlike the prefill write's page-shift trick (page-multiple offsets
    only), the page index is computed per token — S is tiny (k+1 spec
    tokens), so a plain ``.at[].set`` scatter is the whole kernel. Positions
    whose page index runs past the table width land on the reserved null
    page 0 (same absorption contract as bucket padding)."""
    ps = pool_k.shape[2]
    KV = pool_k.shape[1]
    S = k.shape[1]
    P = tab_row.shape[0]
    t = jnp.asarray(offset, jnp.int32) + jnp.arange(S, dtype=jnp.int32)
    page_idx = t // ps
    pages = jnp.where(page_idx < P, tab_row[jnp.clip(page_idx, 0, P - 1)], 0)
    offs = t % ps
    kvh = jnp.arange(KV)
    new_k = pool_k.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        k[0].astype(pool_k.dtype)
    )
    new_v = pool_v.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        v[0].astype(pool_v.dtype)
    )
    return new_k, new_v


def paged_verify_write_quant_ref(pool_k, pool_v, pool_ks, pool_vs, k, v, tab_row, offset):
    """Int8 leg of the verify-stripe scatter: quantize the incoming stripe
    per (token, head), then land values and scales through the same
    per-token page indexing. Returns the four updated pools."""
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    new_k, new_v = paged_verify_write_ref(pool_k, pool_v, kq, vq, tab_row, offset)
    new_ks, new_vs = paged_verify_write_ref(pool_ks, pool_vs, ks, vs, tab_row, offset)
    return new_k, new_v, new_ks, new_vs


def paged_prefill_write_ref(pool_k, pool_v, k, v, tab_row):
    """Scatter one prefilled prompt's K/V through its block-table row.

    pool_k/pool_v: (num_pages, KV, ps, hd); k/v: (1, Lp, KV, hd) — Lp may be
    bucket-padded past the sequence's allocated pages, in which case
    ``tab_row[t // ps]`` is the reserved null page 0 and the pad writes are
    absorbed there (never read: the length mask kills those positions).
    Returns (new_pool_k, new_pool_v)."""
    ps = pool_k.shape[2]
    KV = pool_k.shape[1]
    Lp = k.shape[1]
    t = jnp.arange(Lp)
    pages = tab_row[t // ps]
    offs = t % ps
    kvh = jnp.arange(KV)
    new_k = pool_k.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        k[0].astype(pool_k.dtype)
    )
    new_v = pool_v.at[pages[:, None], kvh[None, :], offs[:, None]].set(
        v[0].astype(pool_v.dtype)
    )
    return new_k, new_v


def paged_prefill_write_quant_ref(pool_k, pool_v, pool_ks, pool_vs, k, v, tab_row):
    """Int8 leg of the prefill scatter: quantize-at-write (per token, head —
    ``models/quant.py``'s KV idiom), then scatter values and scales through
    the same block-table row. The Pallas twin fuses the quantization into
    the write kernel's VMEM pass; this oracle keeps it bit-identical."""
    kq, ks = quantize_kv(k)
    vq, vs = quantize_kv(v)
    new_k, new_v = paged_prefill_write_ref(pool_k, pool_v, kq, vq, tab_row)
    new_ks, new_vs = paged_prefill_write_ref(pool_ks, pool_vs, ks, vs, tab_row)
    return new_k, new_v, new_ks, new_vs
