"""Pure-jnp oracle for paged GQA decode attention: gather then dense."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def gather_kv(pool: jnp.ndarray, block_tab: jnp.ndarray) -> jnp.ndarray:
    """Materialize the dense (B, KV, P*ps, hd) view of a paged pool.

    pool: (num_pages, KV, ps, hd); block_tab: (B, P) int32.
    """
    B, P = block_tab.shape
    _, KV, ps, hd = pool.shape
    g = pool[block_tab]                       # (B, P, KV, ps, hd)
    return g.transpose(0, 2, 1, 3, 4).reshape(B, KV, P * ps, hd)


def paged_attention_ref(q, pool_k, pool_v, block_tab, lengths):
    """q: (B, KV, G, hd); pools: (num_pages, KV, ps, hd); lengths: (B,)."""
    k = gather_kv(pool_k, block_tab)
    v = gather_kv(pool_v, block_tab)
    return decode_attention_ref(q, k, v, lengths)
