"""Oracle: the sequential mLSTM recurrence (models/xlstm.py)."""
from repro.models.xlstm import mlstm_sequential  # noqa: F401
