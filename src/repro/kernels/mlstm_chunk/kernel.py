"""Chunkwise mLSTM — Pallas TPU kernel.

The xLSTM matrix-memory cell in its chunkwise-parallel form: grid
(B*NH, n_chunks), chunks sequential; the (C, n, m) recurrent state lives in
VMEM scratch and carries across the chunk dimension, so the (DH x DH) matrix
memory never round-trips HBM between chunks (the CUDA kernels of the xLSTM
paper keep it in SMEM; VMEM is the TPU analogue — DESIGN.md §2).

Per chunk: two (L x L) MXU matmuls (intra-chunk attention-like term) + two
(L x DH) x (DH x DH) matmuls (inter-chunk via C), all stabilized in f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG = -1e30


def _kernel(q_ref, k_ref, v_ref, i_ref, lf_ref, h_ref, Cf_ref, nf_ref, mf_ref,
            C_ref, n_ref, m_ref, *, L, DH, n_chunks):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        C_ref[...] = jnp.zeros_like(C_ref)
        n_ref[...] = jnp.zeros_like(n_ref)
        m_ref[...] = jnp.zeros_like(m_ref)

    q = q_ref[0].astype(jnp.float32)                    # (L, DH) — caller pre-scales
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    it = i_ref[0].astype(jnp.float32)                    # (L, 1)
    lf = lf_ref[0].astype(jnp.float32)                   # (L, 1)

    cum = jnp.cumsum(lf, axis=0)                         # (L, 1) inclusive
    total = cum[L - 1:L, :]                              # (1, 1)
    m0 = m_ref[0, 0]

    # intra-chunk decay D_ij = cum_i - cum_j + i_j (j <= i)
    Dm = cum - cum.reshape(1, L) + it.reshape(1, L)      # (L, L)
    tri = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0) >= jax.lax.broadcasted_iota(
        jnp.int32, (L, L), 1
    )
    Dm = jnp.where(tri, Dm, NEG)

    g = cum + m0                                         # (L, 1)
    m_row = jnp.maximum(jnp.max(Dm, axis=-1, keepdims=True), g)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    s = s * jnp.exp(Dm - m_row)                          # (L, L)
    inter = jnp.exp(g - m_row)                           # (L, 1)
    num = jax.lax.dot_general(s, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    num = num + inter * jax.lax.dot_general(
        q, C_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    den = jnp.sum(s, axis=-1, keepdims=True) + inter * jax.lax.dot_general(
        q, n_ref[...], (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    h = num / jnp.maximum(jnp.abs(den), 1.0)
    h_ref[0] = h.astype(h_ref.dtype)

    # carry update
    a = total - cum + it                                 # (L, 1)
    m_new = jnp.maximum(total[0, 0] + m0, jnp.max(a))
    w = jnp.exp(a - m_new)                               # (L, 1)
    scale_old = jnp.exp(total[0, 0] + m0 - m_new)
    C_ref[...] = scale_old * C_ref[...] + jax.lax.dot_general(
        k * w, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    n_ref[...] = scale_old * n_ref[...] + jnp.sum(k * w, axis=0, keepdims=True).T
    m_ref[...] = jnp.full_like(m_ref, m_new)

    @pl.when(ic == n_chunks - 1)
    def _emit_state():
        Cf_ref[0] = C_ref[...]
        nf_ref[0] = n_ref[...]
        mf_ref[0] = m_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mlstm_chunkwise_bh(
    q: jax.Array,   # (BH, S, DH)
    k: jax.Array,
    v: jax.Array,
    i: jax.Array,   # (BH, S, 1) input-gate preactivation
    lf: jax.Array,  # (BH, S, 1) log-sigmoid forget gate
    chunk: int = 64,
    interpret: bool = True,
):
    BH, S, DH = q.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk
    kernel = functools.partial(_kernel, L=chunk, DH=DH, n_chunks=n_chunks)
    spec_sd = pl.BlockSpec((1, chunk, DH), lambda bh, ic: (bh, ic, 0))
    spec_s1 = pl.BlockSpec((1, chunk, 1), lambda bh, ic: (bh, ic, 0))
    h, Cf, nf, mf = pl.pallas_call(
        kernel,
        grid=(BH, n_chunks),
        in_specs=[spec_sd, spec_sd, spec_sd, spec_s1, spec_s1],
        out_specs=[
            spec_sd,
            pl.BlockSpec((1, DH, DH), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((1, DH, 1), lambda bh, ic: (bh, 0, 0)),
            pl.BlockSpec((1, 1, 1), lambda bh, ic: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, DH), q.dtype),
            jax.ShapeDtypeStruct((BH, DH, DH), jnp.float32),
            jax.ShapeDtypeStruct((BH, DH, 1), jnp.float32),
            jax.ShapeDtypeStruct((BH, 1, 1), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((DH, DH), jnp.float32),   # C
            pltpu.VMEM((DH, 1), jnp.float32),    # n
            pltpu.VMEM((1, 1), jnp.float32),     # m
        ],
        interpret=interpret,
    )(q, k, v, i, lf)
    return h, Cf, nf, mf
