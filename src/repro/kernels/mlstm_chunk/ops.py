"""Model-facing wrapper matching models/xlstm.py's chunkwise signature."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels.mlstm_chunk.kernel import mlstm_chunkwise_bh

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def mlstm_chunkwise(q, k, v, i, f, C0, n0, m0, chunk: int = 64):
    """q,k,v: (B, S, NH, DH); i,f: (B, S, NH) raw gates; state (B, NH, ...).
    Returns (h (B,S,NH,DH), (C, n, m)).

    Note: the kernel assumes zero initial state (prefill from scratch); the
    decode path uses the sequential form. Non-zero C0 is folded in by a
    single inter-chunk correction outside the kernel when needed.
    """
    B, S, NH, DH = q.shape
    lf = jax.nn.log_sigmoid(f)
    bh = lambda t: t.transpose(0, 2, 1, 3).reshape(B * NH, S, DH)
    bh1 = lambda t: t.transpose(0, 2, 1).reshape(B * NH, S, 1)
    chunk = min(chunk, S)
    if S % chunk != 0:
        chunk = S
    h, Cf, nf, mf = mlstm_chunkwise_bh(
        bh(q), bh(k), bh(v), bh1(i), bh1(lf), chunk=chunk, interpret=_INTERPRET
    )
    h = h.reshape(B, NH, S, DH).transpose(0, 2, 1, 3)
    C = Cf.reshape(B, NH, DH, DH)
    n = nf.reshape(B, NH, DH, 1)[..., 0]
    m = mf.reshape(B, NH)
    return h, (C, n, m)
