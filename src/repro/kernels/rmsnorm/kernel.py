"""Fused RMSNorm — Pallas TPU kernel (rows tiled through VMEM, f32 math)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)).astype(
        o_ref.dtype
    )


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_2d(x: jax.Array, w: jax.Array, eps: float = 1e-6,
               block_rows: int = 256, interpret: bool = True) -> jax.Array:
    R, D = x.shape
    block_rows = min(block_rows, R)
    assert R % block_rows == 0, (R, block_rows)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(R // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, w)
