"""Oracle: models/common.rmsnorm."""
from repro.models.common import rmsnorm as rmsnorm_ref  # noqa: F401
