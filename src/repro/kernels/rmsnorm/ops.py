"""Model-facing wrapper: arbitrary leading dims + row padding."""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_2d

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def rmsnorm(x, w, eps: float = 1e-6):
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block = 256
    while rows % block != 0 and block > 1:
        block //= 2
    out = rmsnorm_2d(x2, w, eps=eps, block_rows=block, interpret=_INTERPRET)
    return out.reshape(shape)
