"""Pure-jnp oracle for flash attention (causal, GQA)."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v):
    """q: (B, H, S, hd); k/v: (B, KV, S, hd) -> (B, H, S, hd), fp32 softmax."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    qg = q.reshape(B, KV, G, S, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bkgqh,bkth->bkgqt", qg, kf) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((S, S), bool))
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgqt,bkth->bkgqh", p, vf)
    return o.reshape(B, H, S, hd).astype(q.dtype)
