"""Jit'd model-facing wrapper: (B, S, H, hd) layout + padding + layout swap.

``interpret`` defaults to True because this container is CPU-only; on TPU
set REPRO_PALLAS_INTERPRET=0 (or pass interpret=False).
"""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def flash_attention(q, k, v, pos_q=None, pos_k=None, bq: int = 128, bkv: int = 128):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) -> (B, S, H, hd). Standard
    causal positions (the model's train/prefill path)."""
    B, S, H, hd = q.shape
    bq = min(bq, S)
    bkv = min(bkv, S)
    pad = (-S) % bq
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    o = flash_attention_bhsd(
        q.transpose(0, 2, 1, 3),
        k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3),
        bq=bq,
        bkv=bkv,
        interpret=_INTERPRET,
    )
    o = o.transpose(0, 2, 1, 3)
    return o[:, :S] if pad else o
