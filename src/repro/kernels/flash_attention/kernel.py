"""Causal flash attention (GQA) — Pallas TPU kernel.

Blockwise online-softmax with *causal block skipping*: fully-masked
(q-block, kv-block) pairs are predicated off, so the quadratic masked waste
of the jnp reference path disappears (~2x FLOPs), and probabilities never
leave VMEM — removing the dominant HBM term of the baseline roofline.

Grid: (B, H, n_q_blocks, n_kv_blocks), kv innermost; the (acc, m, l)
scratch carries across the sequential kv dimension. Block shapes are
MXU-aligned (bq x hd, bkv x hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, bq, bkv, scale, n_kv):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * bq
    kv_start = ikv * bkv

    @pl.when(kv_start <= q_start + bq - 1)  # causal block skipping
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (bkv, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                       # (bq, bkv)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        kpos = kv_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
        s = jnp.where(qpos >= kpos, s, NEG_INF)

        m_prev = m_ref[...]                             # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)                  # (bq, 1)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(ikv == n_kv - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bkv", "interpret"))
def flash_attention_bhsd(
    q: jax.Array,  # (B, H, S, hd)
    k: jax.Array,  # (B, KV, S, hd)
    v: jax.Array,
    bq: int = 128,
    bkv: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    assert S % bq == 0 and S % bkv == 0, (S, bq, bkv)
    n_q = S // bq
    n_kv = S // bkv
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, bq=bq, bkv=bkv, scale=scale, n_kv=n_kv)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ikv: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, iq, ikv: (b, h // G, ikv, 0)),
            pl.BlockSpec((1, 1, bkv, hd), lambda b, h, iq, ikv: (b, h // G, ikv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ikv: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((bq, 1), jnp.float32),    # running max
            pltpu.VMEM((bq, 1), jnp.float32),    # normalizer
        ],
        interpret=interpret,
    )(q, k, v)
