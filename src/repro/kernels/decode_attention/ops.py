"""Model-facing wrapper: (B, 1, H, hd) q + (B, T, KV, hd) cache layout."""
from __future__ import annotations

import os

import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_grouped

_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") != "0"


def decode_attention(q, k, v, cache_len, bt: int = 128):
    """q: (B, S=1, H, hd); k/v: (B, T, KV, hd); cache_len: scalar or (B,)."""
    B, S, H, hd = q.shape
    T = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    lens = jnp.broadcast_to(jnp.asarray(cache_len, jnp.int32), (B,))
    bt = min(bt, T)
    pad = (-T) % bt
    kk = k.transpose(0, 2, 1, 3)
    vv = v.transpose(0, 2, 1, 3)
    if pad:
        kk = jnp.pad(kk, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vv = jnp.pad(vv, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q[:, 0].reshape(B, KV, G, hd)
    o = decode_attention_grouped(qg, kk, vv, lens, bt=bt, interpret=_INTERPRET)
    return o.reshape(B, 1, H, hd)
