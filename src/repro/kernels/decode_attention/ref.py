"""Pure-jnp oracle for fused GQA decode attention."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, lengths, softcap: float = 0.0):
    """q: (B, KV, G, hd); k/v: (B, KV, T, hd); lengths: (B,)."""
    B, KV, G, hd = q.shape
    T = k.shape[2]
    s = jnp.einsum("bkgh,bkth->bkgt", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / (hd ** 0.5)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    mask = jnp.arange(T)[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jnp.einsum("bkgt,bkth->bkgh", p, v.astype(jnp.float32))
    return o.astype(q.dtype)
