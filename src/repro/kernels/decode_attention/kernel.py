"""Fused GQA decode attention — Pallas TPU kernel (flash-decoding style).

One new token per sequence attends over a long KV cache. Grid
(B, KV, n_t_blocks): each step streams one (bt, hd) KV block through VMEM,
updating an online-softmax accumulator for the G query heads that share the
KV head. Per-sequence valid length arrives via scalar prefetch so padded /
short slots mask correctly (continuous batching).

VMEM working set per step: G x hd (q) + 2 x bt x hd (k, v) + G x hd f32
accumulator — independent of cache length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *, bt, n_t, scale):
    b = pl.program_id(0)
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    valid = len_ref[b]                                  # scalar int32
    t_start = it * bt

    @pl.when(t_start < valid)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)             # (G, hd)
        k = k_ref[0, 0].astype(jnp.float32)             # (bt, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                        # (G, bt)
        tpos = t_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(tpos < valid, s, NEG_INF)
        m_prev = m_ref[...]                              # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(it == n_t - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bt", "interpret"))
def decode_attention_grouped(
    q: jax.Array,        # (B, KV, G, hd) — one token per sequence
    k: jax.Array,        # (B, KV, T, hd)
    v: jax.Array,
    lengths: jax.Array,  # (B,) int32 valid prefix per sequence
    bt: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, KV, G, hd = q.shape
    T = k.shape[2]
    assert T % bt == 0, (T, bt)
    n_t = T // bt
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_kernel, bt=bt, n_t=n_t, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KV, n_t),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, it, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bt, hd), lambda b, h, it, lens: (b, h, it, 0)),
            pl.BlockSpec((1, 1, bt, hd), lambda b, h, it, lens: (b, h, it, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, it, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, hd), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(lengths, q, k, v)
