"""Int8 paged KV cache + chained block tables: kernel-vs-ref parity across
page sizes and activation dtypes, engine-level greedy token-match guards
(int8-vs-f32 across chunked / prefix-cache / spec-decode / preemption), the
dense bf16 cache counterpart, long-context admission through chained tables,
and the kv-memory telemetry export."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.paging import NULL_PAGE

PROMPTS = [[1, 2, 3, 4], [5, 6, 7], [9, 10, 11, 12, 13]]


def _smoke(arch="smollm-360m"):
    cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _quant_pools(rng, NP, KV, ps, hd, n_filled):
    """An int8 pool quartet with pages [1, n_filled] holding quantized
    normal K/V (written via the ref quantizer) — plus the f32 originals
    reassembled from the same writes for bounded-error comparison."""
    from repro.kernels.paged_attention.ref import paged_prefill_write_quant_ref

    pool_k = jnp.zeros((NP, KV, ps, hd), jnp.int8)
    pool_v = jnp.zeros((NP, KV, ps, hd), jnp.int8)
    pool_ks = jnp.zeros((NP, KV, ps, 1), jnp.bfloat16)
    pool_vs = jnp.zeros((NP, KV, ps, 1), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, n_filled * ps, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, n_filled * ps, KV, hd)), jnp.float32)
    tab = jnp.asarray(np.arange(1, n_filled + 1), jnp.int32)
    pool_k, pool_v, pool_ks, pool_vs = paged_prefill_write_quant_ref(
        pool_k, pool_v, pool_ks, pool_vs, k, v, tab
    )
    return pool_k, pool_v, pool_ks, pool_vs, k, v


# ---------------------------------------------------------------------------
# Kernel vs jnp reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("src_dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ps,Lp", [(4, 8), (8, 16), (16, 32)])
def test_paged_prefill_write_quant_kernel_matches_ref(ps, Lp, src_dtype):
    """The fused quantize-at-write Pallas scatter must land bit-identical
    int8 values AND scales to the jnp reference on every touched page —
    across page sizes and f32/bf16 source activations (the quantizer
    upcasts to f32 first, so both dtypes share one code path)."""
    from repro.kernels.paged_attention.kernel import paged_prefill_write_grouped_quant
    from repro.kernels.paged_attention.ref import paged_prefill_write_quant_ref

    rng = np.random.default_rng(7)
    KV, hd, NP = 2, 16, 12
    n_real = Lp // ps
    pool_k = jnp.zeros((NP, KV, ps, hd), jnp.int8)
    pool_v = jnp.zeros((NP, KV, ps, hd), jnp.int8)
    pool_ks = jnp.zeros((NP, KV, ps, 1), jnp.bfloat16)
    pool_vs = jnp.zeros((NP, KV, ps, 1), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, Lp, KV, hd)), jnp.float32).astype(src_dtype)
    v = jnp.asarray(rng.normal(size=(1, Lp, KV, hd)), jnp.float32).astype(src_dtype)
    real = rng.permutation(np.arange(1, NP))[:n_real]
    tab = np.full(n_real + 2, NULL_PAGE, np.int32)
    tab[:n_real] = real
    tab = jnp.asarray(tab)
    refs = paged_prefill_write_quant_ref(pool_k, pool_v, pool_ks, pool_vs, k, v, tab)
    outs = paged_prefill_write_grouped_quant(
        pool_k, pool_v, pool_ks, pool_vs, k, v, tab, interpret=True
    )
    touched = np.zeros(NP, bool)
    touched[np.asarray(real)] = True
    untouched = ~touched
    untouched[NULL_PAGE] = False
    # scales are bit-exact; int8 values may differ by 1 LSB where a bf16
    # source puts the quotient within 1 ulp of a rounding tie (x/s vs the
    # compiler's reciprocal form) — f32 sources never hit a tie, so they
    # must be bit-exact
    for name, got, want in zip(("k", "v", "ks", "vs"), outs, refs):
        g, w = np.asarray(jnp.asarray(got)), np.asarray(jnp.asarray(want))
        assert np.array_equal(g[untouched], w[untouched]), name
        if name in ("ks", "vs") or src_dtype == jnp.float32:
            assert np.array_equal(g[touched], w[touched]), name
        else:
            d = np.abs(g[touched].astype(np.int32) - w[touched].astype(np.int32))
            assert d.max() <= 1 and (d > 0).mean() < 1e-3, (name, d.max(), (d > 0).mean())


@pytest.mark.parametrize("ps", [4, 8, 16])
def test_paged_attention_quant_kernel_matches_ref(ps):
    """Dequant-on-gather inside the decode kernel must match the jnp oracle
    (gather -> dequantize -> dense attention) on an int8 pool."""
    from repro.kernels.paged_attention.kernel import paged_attention_grouped
    from repro.kernels.paged_attention.ref import paged_attention_ref

    rng = np.random.default_rng(11)
    B, KV, G, hd, NP, n_filled = 3, 2, 2, 16, 12, 9
    pool_k, pool_v, pool_ks, pool_vs, _, _ = _quant_pools(rng, NP, KV, ps, hd, n_filled)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    P = 3
    tab = jnp.asarray([[1, 2, 3], [4, 5, NULL_PAGE], [6, 7, 8]], jnp.int32)
    lens = jnp.asarray([3 * ps - 1, ps + 2, 2 * ps], jnp.int32)
    o_kernel = paged_attention_grouped(
        q, pool_k, pool_v, tab, lens, interpret=True, pool_ks=pool_ks, pool_vs=pool_vs
    )
    o_ref = paged_attention_ref(q, pool_k, pool_v, tab, lens, pool_ks=pool_ks, pool_vs=pool_vs)
    err = float(jnp.max(jnp.abs(o_kernel - o_ref)))
    assert err < 2e-5, err
    assert o_kernel.shape == (B, KV, G, hd) and P == tab.shape[1]


def test_paged_attention_quant_bounded_error_vs_f32():
    """The int8 decode output must stay within quantization-error distance
    of attention over the original f32 K/V — the bounded-logit-error guard
    behind the engine token-match tests."""
    from repro.kernels.paged_attention.kernel import paged_attention_grouped
    from repro.kernels.paged_attention.ref import paged_prefill_write_ref

    rng = np.random.default_rng(13)
    KV, G, hd, ps, NP, n_filled = 2, 3, 32, 8, 12, 8
    pool_k, pool_v, pool_ks, pool_vs, k, v = _quant_pools(rng, NP, KV, ps, hd, n_filled)
    f32_k = jnp.zeros((NP, KV, ps, hd), jnp.float32)
    f32_v = jnp.zeros((NP, KV, ps, hd), jnp.float32)
    tab = jnp.asarray(np.arange(1, n_filled + 1), jnp.int32)
    f32_k, f32_v = paged_prefill_write_ref(f32_k, f32_v, k, v, tab)
    q = jnp.asarray(rng.normal(size=(2, KV, G, hd)), jnp.float32)
    tab2 = jnp.stack([tab, tab])
    lens = jnp.asarray([n_filled * ps, n_filled * ps - 3], jnp.int32)
    o_q = paged_attention_grouped(
        q, pool_k, pool_v, tab2, lens, interpret=True, pool_ks=pool_ks, pool_vs=pool_vs
    )
    o_f = paged_attention_grouped(q, f32_k, f32_v, tab2, lens, interpret=True)
    err = float(jnp.max(jnp.abs(o_q - o_f)))
    # per-element quant error is <= absmax/254 ~ 2% relative; softmax mixing
    # keeps the output perturbation the same order
    assert err < 0.15, err
    assert err > 0.0, "quantization was a no-op — int8 leg not exercised"


@pytest.mark.parametrize("quant", [False, True])
def test_paged_attention_chained_matches_flat(quant):
    """A chained (l1 -> l2 -> page) table must produce EXACTLY the flat
    table's decode output — the indirection is pure addressing, quantized
    or not."""
    from repro.kernels.paged_attention.kernel import paged_attention_grouped
    from repro.kernels.paged_attention.ref import chain_rows

    rng = np.random.default_rng(17)
    B, KV, G, hd, ps, NP = 2, 2, 2, 16, 8, 12
    if quant:
        pool_k, pool_v, pool_ks, pool_vs, _, _ = _quant_pools(rng, NP, KV, ps, hd, 9)
    else:
        pool_k = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
        pool_v = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
        pool_ks = pool_vs = None
    flat = jnp.asarray([[3, 5, 1, NULL_PAGE], [3, 5, NULL_PAGE, NULL_PAGE]], jnp.int32)
    l2 = jnp.asarray([[NULL_PAGE, NULL_PAGE], [3, 5], [1, NULL_PAGE], [3, 5]], jnp.int32)
    l1 = jnp.asarray([[1, 2], [3, 0]], jnp.int32)
    assert jnp.array_equal(chain_rows(l1, l2), flat)
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    lens = jnp.asarray([2 * ps + 3, ps + 2], jnp.int32)
    o_flat = paged_attention_grouped(
        q, pool_k, pool_v, flat, lens, interpret=True, pool_ks=pool_ks, pool_vs=pool_vs
    )
    o_chain = paged_attention_grouped(
        q, pool_k, pool_v, l1, lens, interpret=True,
        pool_ks=pool_ks, pool_vs=pool_vs, l2_tab=l2,
    )
    assert jnp.array_equal(o_flat, o_chain)


# ---------------------------------------------------------------------------
# Engine-level greedy token match: int8 vs f32 storage
# ---------------------------------------------------------------------------

def _rand_prompts(seed, n, length):
    rng = np.random.default_rng(seed)
    return [[int(t) for t in rng.integers(1, 512, length)] for _ in range(n)]


def _motif_prompts(seed, n, length):
    """Period-4 repetition so the n-gram speculative proposer actually
    fires (random prompts give it nothing to match)."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n):
        motif = [int(t) for t in rng.integers(1, 512, 4)]
        out.append((motif * ((length + 3) // 4))[:length])
    return out


# Greedy token-match int8-vs-f32 is a property of the logit margins, not of
# the storage format: quantization shifts logits by a bounded amount (the
# kernel-level test above), so on prompts whose greedy gaps exceed it the
# token streams must be identical. The prompt seeds are fixed and the whole
# pipeline is deterministic — each variant exercises its path for real
# (two chunks, accepted proposals, actual preemptions).
VARIANTS = {
    "plain":   dict(kw={}, num_pages=33, prompts=_rand_prompts(102, 4, 4)),
    "chunked": dict(kw={"chunk_tokens": 16}, num_pages=65,
                    prompts=_rand_prompts(200, 3, 20)),       # 16+4: two chunks
    "spec":    dict(kw={"spec_tokens": 3}, num_pages=65,
                    prompts=_motif_prompts(301, 3, 14)),
    "preempt": dict(kw={}, num_pages=10, prompts=_rand_prompts(102, 4, 4)),
}


@pytest.mark.parametrize("variant", ["plain", "chunked", "spec", "preempt"])
def test_paged_engine_int8_matches_f32_greedy(variant):
    """Int8 KV storage must be invisible to greedy decoding on every paged
    execution path — full prefill, chunked prefill, speculative decode
    (verify writes + gathers ride the quantized pool), and
    preemption/recompute-resume."""
    cfg = _smoke()
    spec = VARIANTS[variant]
    mk = lambda dt, p: PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=spec["num_pages"], max_slots=4,
                          max_seq_len=32, max_new_tokens=8, cache_dtype=dt,
                          **spec["kw"]),
        params=p,
    )
    f32 = mk("f32", None)
    i8 = mk("int8", f32.params)
    assert f32.capacity_now()["kv_cache_dtype"] == "float32"
    assert i8.capacity_now()["kv_cache_dtype"] == "int8"
    a = f32.generate(spec["prompts"])
    b = i8.generate(spec["prompts"])
    assert [s.out for s in a] == [s.out for s in b]
    if variant == "preempt":
        assert i8.preemptions > 0
    if variant == "spec":
        assert i8.spec_accepted > 0
    i8.allocator.check_invariants()
    assert i8.allocator.used_pages == 0


def test_paged_engine_int8_matches_f32_with_prefix_cache():
    """Radix-tree prefix reuse over a quantized pool: cached int8 pages are
    re-attached verbatim, so the second wave (full prefix hits) must match
    the f32 engine token-for-token."""
    cfg = _smoke()
    mk = lambda dt, p: PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=33, max_slots=4, max_seq_len=32,
                          max_new_tokens=6, prefix_cache=True, cache_dtype=dt),
        params=p,
    )
    f32 = mk("f32", None)
    i8 = mk("int8", f32.params)
    shared = [3, 1, 4, 1, 5, 9, 2, 6]
    waves = [[shared + [7], shared + [8]], [shared + [7], shared + [2, 7]]]
    for wave in waves:
        a = f32.generate(wave)
        b = i8.generate(wave)
        assert [s.out for s in a] == [s.out for s in b]
    assert i8.capacity_now()["prefix_hit_rate"] > 0
    i8.allocator.check_invariants()


def test_dense_engine_bf16_cache_matches_f32():
    """The dense engine's cheap counterpart: a bf16 KV cache must not
    change greedy tokens, and capacity telemetry must show the halved
    per-token footprint."""
    cfg = _smoke()
    f32 = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=4,
                                            cache_dtype="f32"))
    bf16 = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=4,
                                             cache_dtype="bf16"), params=f32.params)
    a = f32.generate(PROMPTS)
    b = bf16.generate(PROMPTS)
    assert [s.out for s in a] == [s.out for s in b]
    ca, cb = f32.capacity_now(), bf16.capacity_now()
    assert ca["kv_cache_dtype"] == "float32" and cb["kv_cache_dtype"] == "bfloat16"
    assert cb["kv_bytes_per_token"] == pytest.approx(ca["kv_bytes_per_token"] / 2)


def test_capacity_telemetry_reports_kv_bytes_per_token():
    """capacity_now() exports the storage dtype and measured bytes/token;
    int8 (values + bf16 scales) must land well under half of f32 — the
    number the placer uses to see a quantized tier's extra headroom."""
    cfg = _smoke()
    mk = lambda dt: PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=8, num_pages=17, max_slots=2, max_seq_len=64,
                          max_new_tokens=2, cache_dtype=dt),
    )
    snaps = {dt: mk(dt).capacity_now() for dt in ("f32", "bf16", "int8")}
    assert snaps["f32"]["kv_cache_dtype"] == "float32"
    assert snaps["bf16"]["kv_bytes_per_token"] == pytest.approx(
        snaps["f32"]["kv_bytes_per_token"] / 2
    )
    ratio = snaps["f32"]["kv_bytes_per_token"] / snaps["int8"]["kv_bytes_per_token"]
    assert ratio >= 1.8, ratio

    from repro.core.telemetry import CapacityGauge, kv_bytes_per_token, kv_cache_dtype

    assert kv_bytes_per_token(snaps["int8"]) == snaps["int8"]["kv_bytes_per_token"]
    assert kv_cache_dtype(snaps["int8"]) == "int8"
    assert kv_bytes_per_token({}) is None and kv_cache_dtype(None) is None
    g = CapacityGauge()
    g.register_stats("flask", lambda: snaps["int8"])
    assert g.kv_cache_dtype("flask") == "int8"
    assert g.kv_bytes_per_token("flask") == snaps["int8"]["kv_bytes_per_token"]


def test_cache_dtype_rejects_unknown_choice():
    cfg = _smoke()
    with pytest.raises(ValueError, match="cache_dtype"):
        PagedInferenceEngine(
            cfg,
            PagedEngineConfig(page_size=8, num_pages=17, max_slots=2,
                              max_seq_len=64, cache_dtype="fp8"),
        )


# ---------------------------------------------------------------------------
# Chained tables: long-context admission regression
# ---------------------------------------------------------------------------


def test_long_prompt_admitted_via_chained_tables():
    """Regression: a prompt longer than the flat block-table width used to
    be structurally unservable — the flat engine cannot even construct when
    table_width > num_pages - 1. Chained tables re-derive the admission cap
    from pool capacity: the same pool admits and COMPLETES the long prompt,
    and over-pool prompts get the new capacity-derived rejection."""
    cfg = _smoke()
    long_prompt = list(np.random.default_rng(0).integers(1, cfg.vocab_size, 200))
    flat = PagedEngineConfig(page_size=8, num_pages=33, max_slots=2,
                             max_seq_len=1024, max_new_tokens=4)
    with pytest.raises(ValueError, match="num_pages"):
        PagedInferenceEngine(cfg, flat)
    eng = PagedInferenceEngine(cfg, dataclasses.replace(flat, chained_tables=True))
    assert eng._len_cap == min(1024, flat.cache_tokens)
    seqs = eng.generate([long_prompt])
    assert len(seqs[0].out) == 4 and seqs[0].done
    eng.allocator.check_invariants()
    eng.chain.check_invariants(eng.pcfg.max_slots)
    assert eng.allocator.used_pages == 0
    # beyond the POOL (not the table): rejected at submit with the new cap
    over = list(np.random.default_rng(1).integers(1, cfg.vocab_size, 300))
    with pytest.raises(ValueError, match="length cap"):
        eng.submit(over)


@pytest.mark.parametrize("variant", ["plain", "chunked", "spec", "preempt"])
def test_chained_engine_matches_flat_engine(variant):
    """With geometry where both construct, chained indirection must be a
    pure addressing change: identical greedy tokens to the flat engine on
    every execution path, with table rows fully recycled at the end."""
    cfg = _smoke()
    spec = VARIANTS[variant]
    mk = lambda chained, p: PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=spec["num_pages"], max_slots=4,
                          max_seq_len=32, max_new_tokens=8,
                          chained_tables=chained, **spec["kw"]),
        params=p,
    )
    flat = mk(False, None)
    chained = mk(True, flat.params)
    a = flat.generate(spec["prompts"])
    b = chained.generate(spec["prompts"])
    assert [s.out for s in a] == [s.out for s in b]
    if variant == "preempt":
        assert chained.preemptions > 0
    chained.allocator.check_invariants()
    chained.chain.check_invariants(chained.pcfg.max_slots)
    assert chained.allocator.used_pages == 0
    assert chained.chain.free_rows == chained.chain.l2.shape[0] - 1


def test_chained_plus_int8_long_context_end_to_end():
    """The two tentpole halves composed: an int8 pool addressed through
    chained tables serves a long prompt with tokens identical to the f32
    chained engine."""
    cfg = _smoke()
    long_prompt = list(np.random.default_rng(2).integers(1, cfg.vocab_size, 120))
    mk = lambda dt, p: PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=8, num_pages=33, max_slots=2, max_seq_len=1024,
                          max_new_tokens=4, chained_tables=True, cache_dtype=dt),
        params=p,
    )
    f32 = mk("f32", None)
    i8 = mk("int8", f32.params)
    a = f32.generate([long_prompt])
    b = i8.generate([long_prompt])
    assert [s.out for s in a] == [s.out for s in b]
    i8.allocator.check_invariants()
    assert i8.allocator.used_pages == 0
