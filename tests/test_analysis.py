"""Tests for the static-analysis subsystem (``repro.analysis``).

Each analyzer is fed small seeded-bad fixtures (in-memory sources for the
lock tools, tmp_path trees for the kernel checker) and must flag exactly the
planted defect; the mirror-image good fixture must pass.  A final test runs
all three analyzers on the real tree and requires zero unexplained findings
-- the same gate ``scripts/ci.sh analyze`` enforces.
"""
import textwrap

import pytest

from repro.analysis.common import SourceFile, unsuppressed
from repro.analysis.kernelcheck import check as kernel_check
from repro.analysis.locklint import LockLint
from repro.analysis.lockorder import LockOrder


def lint(text):
    return LockLint([SourceFile.from_text("mem.py", textwrap.dedent(text))]).run()


def order(text):
    graph = LockOrder([SourceFile.from_text("mem.py", textwrap.dedent(text))])
    graph.build()
    return graph, graph.check()


def codes(findings):
    return [f.code for f in unsuppressed(findings)]


# ---------------------------------------------------------------------------
# locklint: guarded fields
# ---------------------------------------------------------------------------


GUARDED = """
    import threading

    class C:
        def __init__(self):
            self._lock = threading.Lock()
            self.items = []  # guarded by: _lock

        def good(self):
            with self._lock:
                self.items.append(1)

        def bad(self):
            self.items.append(2)
"""


def test_guarded_field_outside_lock_flagged():
    findings = lint(GUARDED)
    assert codes(findings) == ["guarded-field"]
    (f,) = unsuppressed(findings)
    assert "self.items" in f.message and "C.bad" in f.message


def test_guarded_field_inside_lock_and_init_clean():
    clean = GUARDED.replace("""
        def bad(self):
            self.items.append(2)
""", "")
    assert lint(clean) == []


def test_locked_suffix_method_exempt():
    text = GUARDED.replace("def bad(self):", "def bad_locked(self):")
    assert lint(text) == []


def test_suppression_with_reason_hides_finding():
    text = GUARDED.replace(
        "self.items.append(2)",
        "self.items.append(2)  # locklint: ok snapshot read, staleness is fine",
    )
    findings = lint(text)
    assert unsuppressed(findings) == []
    (f,) = findings
    assert f.suppressed and f.reason == "snapshot read, staleness is fine"


def test_reasonless_suppression_is_loud():
    text = GUARDED.replace(
        "self.items.append(2)", "self.items.append(2)  # locklint: ok"
    )
    assert codes(lint(text)) == ["bad-suppression"]


def test_guarded_decl_via_registry():
    text = """
        import threading

        class C:
            _GUARDED = {"items": "_lock"}

            def __init__(self):
                self._lock = threading.Lock()
                self.items = []

            def bad(self):
                return len(self.items)
    """
    assert codes(lint(text)) == ["guarded-field"]


# ---------------------------------------------------------------------------
# locklint: blocking under a strict lock
# ---------------------------------------------------------------------------


def test_blocking_join_and_sleep_under_lock_flagged():
    text = """
        import threading, time

        class D:
            def __init__(self):
                self._lock = threading.Lock()

            def bad_join(self, t):
                with self._lock:
                    t.join()

            def bad_sleep(self):
                with self._lock:
                    time.sleep(0.1)
    """
    assert codes(lint(text)) == ["blocking-under-lock", "blocking-under-lock"]


def test_wait_on_held_condition_allowed():
    text = """
        import threading

        class D:
            def __init__(self):
                self._lock = threading.Lock()
                self.cond = threading.Condition(self._lock)

            def consume(self):
                with self.cond:
                    self.cond.wait()
    """
    assert lint(text) == []


def test_device_dispatch_under_strict_lock_flagged():
    text = """
        import threading

        class D:
            def __init__(self):
                self._lock = threading.Lock()

            def bad(self, engine):
                with self._lock:
                    engine.step_once()
    """
    findings = lint(text)
    assert codes(findings) == ["blocking-under-lock"]
    assert "device dispatch" in findings[0].message


def test_blocking_ok_policy_silences_rule():
    text = """
        import threading

        class E:
            def __init__(self):
                self.lock = threading.RLock()  # locklint: blocking-ok stepper owns the buffers

            def step(self, fut):
                with self.lock:
                    return fut.result()
    """
    assert lint(text) == []


def test_nested_def_resets_held_set():
    text = """
        import threading, time

        class F:
            def __init__(self):
                self._lock = threading.Lock()

            def spawn(self):
                with self._lock:
                    def later():
                        time.sleep(1.0)
                    return later
    """
    assert lint(text) == []


# ---------------------------------------------------------------------------
# lockorder: inversions and self-deadlocks
# ---------------------------------------------------------------------------


INVERSION = """
    import threading

    class A:
        def __init__(self, b):
            self._lock = threading.Lock()
            self.b = b

        def one(self):
            with self._lock:
                self.b.grab()

    class B:
        def __init__(self, a):
            self._lock = threading.Lock()
            self.a = a

        def grab(self):
            with self._lock:
                pass

        def two(self):
            with self._lock:
                self.a.one()
"""


def test_ab_ba_cycle_flagged():
    graph, findings = order(INVERSION)
    assert "lock-cycle" in codes(findings)
    edges = {(e.src, e.dst) for e in graph.edges}
    assert ("A._lock", "B._lock") in edges and ("B._lock", "A._lock") in edges


def test_one_direction_only_is_clean():
    text = INVERSION.replace("""
        def two(self):
            with self._lock:
                self.a.one()
""", "")
    graph, findings = order(text)
    assert findings == []
    assert {(e.src, e.dst) for e in graph.edges} == {("A._lock", "B._lock")}


def test_self_reacquire_plain_lock_flagged_rlock_ok():
    text = """
        import threading

        class S:
            def __init__(self):
                self._lock = threading.{factory}()

            def outer(self):
                with self._lock:
                    self.inner()

            def inner(self):
                with self._lock:
                    pass
    """
    _, findings = order(text.format(factory="Lock"))
    assert codes(findings) == ["self-deadlock"]
    _, findings = order(text.format(factory="RLock"))
    assert findings == []


def test_transitive_edge_through_helper():
    text = """
        import threading

        class A:
            def __init__(self, b):
                self._lock = threading.Lock()
                self.b = b

            def top(self):
                with self._lock:
                    self.helper()

            def helper(self):
                self.b.grab()

        class B:
            def __init__(self):
                self._lock = threading.Lock()

            def grab(self):
                with self._lock:
                    pass
    """
    graph, findings = order(text)
    assert findings == []
    assert ("A._lock", "B._lock") in {(e.src, e.dst) for e in graph.edges}


# ---------------------------------------------------------------------------
# kernelcheck fixtures
# ---------------------------------------------------------------------------


GOOD_KERNEL = """
import jax
from jax.experimental import pallas as pl


def body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype)
    )(x)
"""

GOOD_REF = """
def run_ref(x):
    return x
"""

GOOD_TEST = """
from repro.kernels.goodfam.kernel import run
from repro.kernels.goodfam.ref import run_ref
"""


def make_family(tmp_path, name, kernel=GOOD_KERNEL, ref=GOOD_REF, test=None):
    fam = tmp_path / "kernels" / name
    fam.mkdir(parents=True)
    (fam / "kernel.py").write_text(kernel)
    if ref is not None:
        (fam / "ref.py").write_text(ref)
    tests = tmp_path / "tests"
    tests.mkdir(exist_ok=True)
    if test is not None:
        (tests / f"test_{name}.py").write_text(test)
    return str(tmp_path / "kernels"), str(tests)


def test_good_family_passes(tmp_path):
    roots = make_family(tmp_path, "goodfam", test=GOOD_TEST)
    assert kernel_check(*roots) == []


def test_missing_ref_flagged(tmp_path):
    roots = make_family(tmp_path, "goodfam", ref=None, test=GOOD_TEST)
    assert "missing-ref" in codes(kernel_check(*roots))


def test_missing_parity_test_flagged(tmp_path):
    kernel_only = "from repro.kernels.goodfam.kernel import run\n"
    roots = make_family(tmp_path, "goodfam", test=kernel_only)
    found = codes(kernel_check(*roots))
    assert found == ["missing-parity-test"]


def test_inplace_pool_without_alias_flagged(tmp_path):
    kernel = """
import jax
from jax.experimental import pallas as pl


def body(pool_ref, x_ref, o_ref):
    o_ref[...] = pool_ref[...]


def update(kv_pool, x):
    return pl.pallas_call(
        body, out_shape=jax.ShapeDtypeStruct(kv_pool.shape, kv_pool.dtype)
    )(kv_pool, x)
"""
    roots = make_family(tmp_path / "bad", "goodfam", kernel=kernel, test=GOOD_TEST)
    assert codes(kernel_check(*roots)) == ["in-place-no-alias"]

    aliased = kernel.replace(
        "out_shape=jax.ShapeDtypeStruct(kv_pool.shape, kv_pool.dtype)",
        "out_shape=jax.ShapeDtypeStruct(kv_pool.shape, kv_pool.dtype),\n"
        "        input_output_aliases={0: 0}",
    )
    roots = make_family(tmp_path / "good", "goodfam", kernel=aliased, test=GOOD_TEST)
    assert kernel_check(*roots) == []


def test_traced_index_map_flagged(tmp_path):
    kernel = """
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def run(x):
    spec = pl.BlockSpec((1, 128), lambda i: (jnp.minimum(i, 4), 0))
    return pl.pallas_call(
        body, in_specs=[spec], out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype)
    )(x)
"""
    roots = make_family(tmp_path, "goodfam", kernel=kernel, test=GOOD_TEST)
    assert codes(kernel_check(*roots)) == ["traced-index-map"]


def test_shape_branch_in_kernel_body_flagged(tmp_path):
    kernel = """
import jax
from jax.experimental import pallas as pl


def body(x_ref, o_ref):
    if x_ref.shape[0] > 8:
        o_ref[...] = x_ref[...]
    else:
        o_ref[...] = x_ref[...]


def run(x):
    return pl.pallas_call(
        body, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype)
    )(x)
"""
    roots = make_family(tmp_path, "goodfam", kernel=kernel, test=GOOD_TEST)
    assert codes(kernel_check(*roots)) == ["shape-branch-in-kernel"]


# ---------------------------------------------------------------------------
# the real tree must be clean (zero unexplained findings)
# ---------------------------------------------------------------------------


def test_real_tree_is_clean():
    from repro.analysis.__main__ import repo_root, run_all

    findings, graph = run_all(repo_root(), ["locklint", "lockorder", "kernelcheck"])
    loud = unsuppressed(findings)
    assert loud == [], "unexplained findings:\n" + "\n".join(f.format() for f in loud)
    # every suppression must carry a reason (enforced structurally, but make
    # the contract explicit here)
    assert all(f.reason for f in findings if f.suppressed)


def test_real_tree_graph_shape():
    from repro.analysis.__main__ import repo_root, run_all

    _, graph = run_all(repo_root(), ["lockorder"])
    edges = {(e.src, e.dst) for e in graph.edges}
    # the router's placement path samples telemetry under its registry lock
    assert ("StraightLineRouter._lock", "FrequencyEstimator._lock") in edges
    # the engines' coarse step lock wraps trace recording
    assert ("_EngineBase.lock", "Trace._lock") in edges
