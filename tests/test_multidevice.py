"""Multi-device SPMD tests — run in a subprocess with 8 forced host devices
(the main pytest process must keep seeing 1 device)."""
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parents[1]


def run_in_subprocess(body: str):
    script = textwrap.dedent(
        """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        if not hasattr(jax.sharding, "AxisType"):
            # older JAX: meshes are implicitly Auto-typed; accept and drop
            # the axis_types kwarg so the test bodies run unchanged
            import enum
            class _AxisType(enum.Enum):
                Auto = "auto"
                Explicit = "explicit"
                Manual = "manual"
            jax.sharding.AxisType = _AxisType
            _real_make_mesh = jax.make_mesh
            def _make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
                return _real_make_mesh(axis_shapes, axis_names, **kw)
            jax.make_mesh = _make_mesh
        """
    ) + textwrap.dedent(body)
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env={**__import__("os").environ, "PYTHONPATH": str(REPO / "src")},
        timeout=600,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-3000:]}"
    return r.stdout


def test_moe_shard_map_matches_single_device():
    run_in_subprocess(
        """
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        from repro.models import MoECfg, ModelConfig
        from repro.models.common import init_tree
        from repro.models.moe import moe_defs, moe_ffn
        from repro.sharding.axes import make_ctx
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
            n_kv_heads=2, d_ff=32, vocab_size=64,
            moe=MoECfg(n_experts=4, top_k=2, capacity_factor=100.0),
            param_dtype=jnp.float32, compute_dtype=jnp.float32)
        p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 16), jnp.float32)
        ref, aux_ref = moe_ffn(cfg, None, p, x)   # single-device oracle
        ctx = make_ctx(mesh)
        out, aux = jax.jit(lambda p, x: moe_ffn(cfg, ctx, p, x))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        # aux is the mean of per-shard balance losses (standard DP form);
        # it approximates but does not equal the whole-batch estimator.
        assert abs(float(aux - aux_ref)) / float(aux_ref) < 0.5
        print("MOE_SHARD_OK", err)
        """
    )


def test_moe_fsdp_expert_gather_matches():
    run_in_subprocess(
        """
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        from repro.models import MoECfg, ModelConfig
        from repro.models.common import init_tree
        from repro.models.moe import moe_defs, moe_ffn
        from repro.sharding.axes import make_ctx
        cfg = ModelConfig(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
            n_kv_heads=2, d_ff=32, vocab_size=64,
            moe=MoECfg(n_experts=4, top_k=1, capacity_factor=100.0, fsdp_experts=True),
            param_dtype=jnp.float32, compute_dtype=jnp.float32)
        p = init_tree(jax.random.PRNGKey(0), moe_defs(cfg), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 6, 16), jnp.float32)
        ref, _ = moe_ffn(cfg.replace(moe=MoECfg(n_experts=4, top_k=1,
            capacity_factor=100.0, fsdp_experts=False)), None, p, x)
        ctx = make_ctx(mesh)
        out, _ = jax.jit(lambda p, x: moe_ffn(cfg, ctx, p, x))(p, x)
        err = float(jnp.max(jnp.abs(out - ref)))
        assert err < 1e-4, err
        print("MOE_FSDP_OK", err)
        """
    )


def test_compressed_allreduce_close_to_psum():
    run_in_subprocess(
        """
        mesh = jax.make_mesh((8,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))
        from repro.train.compression import make_compressed_psum
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 256), jnp.float32)
        sh = NamedSharding(mesh, P("pod", None))
        xs = jax.device_put(x, sh)
        fn = make_compressed_psum(mesh, "pod", P("pod", None))
        out = jax.jit(fn)(xs)                # per-shard rows each all-reduced
        want = jnp.broadcast_to(x.sum(0, keepdims=True), x.shape)
        rel = float(jnp.max(jnp.abs(out - want)) / jnp.max(jnp.abs(want)))
        assert rel < 0.05, rel               # int8 wire error bound
        print("COMPRESS_OK", rel)
        """
    )


def test_elastic_remesh_restore():
    run_in_subprocess(
        """
        import tempfile
        from repro.train import checkpoint as ckpt
        from jax.sharding import Mesh
        # save under a (4,2) mesh sharding, restore under (2,4)
        t = {"w": jnp.arange(64.0).reshape(8, 8)}
        d = tempfile.mkdtemp()
        mesh_a = jax.make_mesh((4, 2), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
        sh_a = {"w": NamedSharding(mesh_a, P("data", "model"))}
        placed = jax.tree.map(lambda x, s: jax.device_put(x, s), t, sh_a)
        ckpt.save(d, 1, placed)
        mesh_b = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
        sh_b = {"w": NamedSharding(mesh_b, P("data", "model"))}
        back = ckpt.restore(d, 1, t, shardings=sh_b)
        assert jnp.array_equal(back["w"], t["w"])
        assert back["w"].sharding.mesh.shape == mesh_b.shape
        print("REMESH_OK")
        """
    )


def test_small_mesh_train_step_executes():
    """Actually RUN (not just compile) a sharded train step on 8 devices."""
    run_in_subprocess(
        """
        mesh = jax.make_mesh((2, 4), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
        from repro.configs.registry import get_config
        from repro.models import get_model
        from repro.sharding.axes import make_ctx
        from repro.launch.steps import make_train_step, param_shardings, opt_shardings, batch_shardings
        from repro.train.optimizer import OptConfig, init_opt_state
        cfg = get_config("glm4-9b", smoke=True).replace(
            d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, attn_chunk=8, ce_chunks=2)
        model = get_model(cfg)
        ctx = make_ctx(mesh)
        params = model.init(jax.random.PRNGKey(0))
        ocfg = OptConfig(lr=1e-3)
        opt = init_opt_state(params, ocfg)
        psh = param_shardings(model, ctx, fsdp=True)
        osh = opt_shardings(model, ctx, ocfg)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s), params, psh)
        opt = jax.tree.map(lambda x, s: jax.device_put(x, s) if s is not None else x, opt, osh)
        B, S = 4, 16
        batch = {"tokens": jnp.zeros((B, S), jnp.int32), "labels": jnp.ones((B, S), jnp.int32)}
        step = jax.jit(make_train_step(model, ctx, ocfg), donate_argnums=(0, 1))
        params, opt, metrics = step(params, opt, batch)
        l0 = float(metrics["loss"])
        for _ in range(3):
            params, opt, metrics = step(params, opt, batch)
        assert np.isfinite(l0) and float(metrics["loss"]) < l0
        print("TRAIN_SPMD_OK", l0, float(metrics["loss"]))
        """
    )


def test_moe_token_gather_matches_weight_gather():
    """The 104x llama4-decode optimization must be semantics-preserving."""
    run_in_subprocess(
        """
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        from repro.models import MoECfg, ModelConfig
        from repro.models.common import init_tree
        from repro.models.moe import moe_defs, moe_ffn
        from repro.sharding.axes import make_ctx
        base = ModelConfig(name="m", family="moe", n_layers=1, d_model=16, n_heads=2,
            n_kv_heads=2, d_ff=32, vocab_size=64,
            moe=MoECfg(n_experts=4, top_k=1, capacity_factor=100.0, fsdp_experts=True),
            param_dtype=jnp.float32, compute_dtype=jnp.float32)
        p = init_tree(jax.random.PRNGKey(0), moe_defs(base), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 2, 16), jnp.float32)
        ctx = make_ctx(mesh)
        out_w, _ = jax.jit(lambda p, x: moe_ffn(base, ctx, p, x))(p, x)
        tok = base.replace(moe_token_gather=True)
        out_t, _ = jax.jit(lambda p, x: moe_ffn(tok, ctx, p, x))(p, x)
        err = float(jnp.max(jnp.abs(out_w - out_t)))
        assert err < 1e-4, err
        print("MOETOK_OK", err)
        """
    )


def test_seq_shard_activations_matches_baseline():
    """Megatron-SP variant must not change the math."""
    run_in_subprocess(
        """
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        from repro.configs.registry import get_config
        from repro.models import get_model
        from repro.sharding.axes import make_ctx
        cfg = get_config("granite-8b", smoke=True).replace(
            d_model=64, n_heads=4, n_kv_heads=2, attn_chunk=8, ce_chunks=2)
        ctx = make_ctx(mesh)
        model = get_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = {"tokens": jnp.zeros((4, 16), jnp.int32),
                 "labels": jnp.ones((4, 16), jnp.int32)}
        l0, _ = jax.jit(lambda p, b: model.loss(ctx, p, b))(params, batch)
        sp = get_model(cfg.replace(seq_shard_activations=True))
        l1, _ = jax.jit(lambda p, b: sp.loss(ctx, p, b))(params, batch)
        assert abs(float(l0) - float(l1)) < 1e-4, (float(l0), float(l1))
        print("SP_OK", float(l0))
        """
    )
