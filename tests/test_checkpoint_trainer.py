"""Checkpointing (atomicity, roundtrip) and trainer fault tolerance."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import get_model
from repro.train import checkpoint as ckpt
from repro.train.data import DataConfig
from repro.train.optimizer import OptConfig
from repro.train.trainer import TrainConfig, Trainer


def small_tree():
    return {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.bfloat16)}}


def test_checkpoint_roundtrip(tmp_path):
    t = small_tree()
    ckpt.save(str(tmp_path), 7, t, meta={"note": "x"})
    assert ckpt.latest_step(str(tmp_path)) == 7
    back = ckpt.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        assert jnp.array_equal(a, b)
    assert ckpt.load_meta(str(tmp_path), 7)["note"] == "x"


def test_checkpoint_atomicity_ignores_partial(tmp_path):
    t = small_tree()
    ckpt.save(str(tmp_path), 5, t)
    # a torn write: directory without manifest must be ignored
    (tmp_path / "step_00000009" ).mkdir()
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_restore_rejects_shape_mismatch(tmp_path):
    ckpt.save(str(tmp_path), 1, {"a": jnp.zeros((2, 2))})
    with pytest.raises(AssertionError):
        ckpt.restore(str(tmp_path), 1, {"a": jnp.zeros((3, 3))})


def _trainer(tmp_path, steps, arch="smollm-360m"):
    cfg = get_config(arch, smoke=True).replace(attn_chunk=16, ce_chunks=2)
    model = get_model(cfg)
    tcfg = TrainConfig(steps=steps, ckpt_every=5, ckpt_dir=str(tmp_path),
                       log_every=1, opt=OptConfig(lr=1e-3))
    dcfg = DataConfig(batch_size=2, seq_len=16, vocab_size=cfg.vocab_size, seed=3)
    return Trainer(model, None, tcfg, dcfg)


def test_restart_resumes_identical_trajectory(tmp_path):
    # run 10 steps straight
    r_full = _trainer(tmp_path / "full", 10).run(seed=0)
    # run 5 steps, then a fresh Trainer resumes from the checkpoint
    _trainer(tmp_path / "resume", 5).run(seed=0)
    r_resumed = _trainer(tmp_path / "resume", 10).run(seed=0)
    assert r_resumed["steps_done"] == 10
    tail_full = [h["loss"] for h in r_full["history"] if h["step"] >= 5]
    tail_res = [h["loss"] for h in r_resumed["history"] if h["step"] >= 5]
    np.testing.assert_allclose(tail_full, tail_res, rtol=1e-6)


def test_loss_decreases_on_synthetic_data(tmp_path):
    r = _trainer(tmp_path, 40).run(seed=0)
    losses = [h["loss"] for h in r["history"]]
    assert losses[-1] < losses[0] - 0.3, losses[:3] + losses[-3:]


def test_preemption_saves_and_exits(tmp_path):
    tr = _trainer(tmp_path, 50)
    tr._preempted = False

    orig = tr._jit_step

    def step_then_preempt(*a, **k):
        out = orig(*a, **k)
        tr._preempted = True     # simulate SIGTERM arriving mid-run
        return out

    tr._jit_step = step_then_preempt
    r = tr.run(seed=0)
    assert r["preempted"] and r["steps_done"] < 50
    assert ckpt.latest_step(str(tmp_path)) == r["steps_done"]


def test_data_determinism_and_sharding():
    from repro.train.data import SyntheticLM

    a = SyntheticLM(DataConfig(batch_size=2, seq_len=8, vocab_size=64, seed=1))
    b = SyntheticLM(DataConfig(batch_size=2, seq_len=8, vocab_size=64, seed=1))
    assert np.array_equal(a.batch_at(3)["tokens"], b.batch_at(3)["tokens"])
    s0 = SyntheticLM(DataConfig(batch_size=2, seq_len=8, vocab_size=64, seed=1, shard_id=0, num_shards=2))
    s1 = SyntheticLM(DataConfig(batch_size=2, seq_len=8, vocab_size=64, seed=1, shard_id=1, num_shards=2))
    assert not np.array_equal(s0.batch_at(3)["tokens"], s1.batch_at(3)["tokens"])
    assert a.batch_at(0)["labels"][0, 0] == a.batch_at(0)["tokens"][0, 1]
