"""Prefill+decode must continue exactly from the full-sequence forward —
the invariant continuous batching rests on (mamba / mlstm / slstm / attn)."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import MambaCfg, ModelConfig, XLSTMCfg
from repro.models import mamba as mam
from repro.models import xlstm as xl
from repro.models.common import init_tree

CFG = ModelConfig(
    name="t", family="hybrid", n_layers=1, d_model=16, n_heads=4, n_kv_heads=2,
    d_ff=32, vocab_size=64,
    mamba=MambaCfg(d_state=4, d_conv=4, expand=2, chunk=4),
    xlstm=XLSTMCfg(chunk=4),
    param_dtype=jnp.float32, compute_dtype=jnp.float32,
)


def _zeros_cache(defs):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), defs)


@pytest.mark.parametrize(
    "name,defs_fn,cache_fn,mixer",
    [
        ("mamba", mam.mamba_defs, mam.mamba_cache_defs, mam.mamba_mixer),
        ("mlstm", xl.mlstm_defs, xl.mlstm_cache_defs, xl.mlstm_mixer),
        ("slstm", xl.slstm_defs, xl.slstm_cache_defs, xl.slstm_mixer),
    ],
)
def test_mixer_prefill_decode_continuation(name, defs_fn, cache_fn, mixer):
    rng = jax.random.PRNGKey(1)
    x = jax.random.normal(rng, (2, 8, 16), jnp.float32)
    p = init_tree(rng, defs_fn(CFG), jnp.float32)
    y_full, _ = mixer(CFG, p, x, "train", None)
    cache = _zeros_cache(cache_fn(CFG, 2))
    y_pre, cache = mixer(CFG, p, x[:, :5], "prefill", cache)
    assert float(jnp.max(jnp.abs(y_pre - y_full[:, :5]))) < 1e-5
    for t in range(5, 8):
        y_t, cache = mixer(CFG, p, x[:, t : t + 1], "decode", cache)
        assert float(jnp.max(jnp.abs(y_t[:, 0] - y_full[:, t]))) < 1e-4, (name, t)


def test_attention_chunked_equals_naive():
    from repro.models.attention import chunked_attention

    cfg = CFG.replace(attn_chunk=4)
    rng = jax.random.PRNGKey(2)
    B, S, H, KV, hd = 2, 16, 4, 2, 8
    ks = jax.random.split(rng, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    out_c = chunked_attention(cfg, q, k, v, pos, pos, causal=True)
    out_1 = chunked_attention(cfg.replace(attn_chunk=S), q, k, v, pos, pos, causal=True)
    assert float(jnp.max(jnp.abs(out_c - out_1))) < 1e-5


def test_int8_kv_decode_close_to_bf16():
    from repro.configs.registry import get_config
    from repro.models import get_model

    cfg = get_config("glm4-9b", smoke=True).replace(attn_chunk=64)
    model = get_model(cfg)
    modelq = get_model(cfg.replace(kv_quant=True))
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0, cfg.vocab_size)
    t0, c0 = model.prefill(None, params, {"tokens": toks}, cap=16)
    t1, c1 = modelq.prefill(None, params, {"tokens": toks}, cap=16)
    # int8 quantization error shouldn't flip the greedy token on random data
    assert jnp.array_equal(t0, t1)
    d0, _ = model.decode(None, params, c0, {"token": t0[:, None], "cache_index": jnp.asarray(12)})
    d1, _ = modelq.decode(None, params, c1, {"token": t1[:, None], "cache_index": jnp.asarray(12)})
    assert jnp.array_equal(d0, d1)


def test_quantize_kv_roundtrip_error_bounded():
    from repro.models.attention import dequantize_kv, quantize_kv

    x = jax.random.normal(jax.random.PRNGKey(5), (2, 7, 3, 16), jnp.float32) * 3.0
    q, s = quantize_kv(x)
    back = dequantize_kv(q, s, jnp.float32)
    rel = float(jnp.max(jnp.abs(back - x)) / jnp.max(jnp.abs(x)))
    assert rel < 0.02
