"""Chunked vocab-parallel CE vs naive; AdamW (f32/bf16/int8 states)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ModelConfig
from repro.models.loss import IGNORE, lm_loss, next_tokens
from repro.train.optimizer import (
    OptConfig,
    adamw_update,
    dequantize_blockwise,
    global_norm,
    init_opt_state,
    opt_state_shapes,
    quantize_blockwise,
)

CFG = ModelConfig(
    name="t", family="dense", n_layers=1, d_model=32, n_heads=4, n_kv_heads=4,
    d_ff=64, vocab_size=96, param_dtype=jnp.float32, compute_dtype=jnp.float32,
    ce_chunks=4,
)


def _naive_ce(hidden, w, labels):
    logits = (hidden @ w).astype(jnp.float32)
    z = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    valid = labels != IGNORE
    return jnp.where(valid, z - ll, 0.0).sum() / jnp.maximum(valid.sum(), 1)


def test_chunked_ce_matches_naive_value_and_grad():
    rng = jax.random.PRNGKey(0)
    B, S, d, V = 2, 16, 32, 96
    hidden = jax.random.normal(rng, (B, S, d), jnp.float32)
    params = {"unembed": jax.random.normal(rng, (d, V), jnp.float32) * 0.1,
              "embedding": jnp.zeros((V, d))}
    labels = jax.random.randint(rng, (B, S), 0, V).at[0, :3].set(IGNORE)

    def mine(w):
        loss, _ = lm_loss(CFG, None, {**params, "unembed": w}, hidden, labels, z_weight=0.0)
        return loss

    def naive(w):
        return _naive_ce(hidden, w, labels)

    v0, g0 = jax.value_and_grad(mine)(params["unembed"])
    v1, g1 = jax.value_and_grad(naive)(params["unembed"])
    assert abs(float(v0 - v1)) < 1e-5
    assert float(jnp.max(jnp.abs(g0 - g1))) < 1e-5


def test_next_tokens_equals_full_argmax():
    rng = jax.random.PRNGKey(1)
    hidden = jax.random.normal(rng, (3, 5, 32), jnp.float32)
    params = {"unembed": jax.random.normal(rng, (32, 96), jnp.float32),
              "embedding": jnp.zeros((96, 32))}
    got = next_tokens(CFG, None, params, hidden)
    want = jnp.argmax(hidden[:, -1] @ params["unembed"], axis=-1)
    assert jnp.array_equal(got, want.astype(jnp.int32))


def test_blockwise_quant_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,), jnp.float32) * 5
    qs = quantize_blockwise(x)
    back = dequantize_blockwise(qs, x.shape)
    assert float(jnp.max(jnp.abs(back - x))) < 5 * 2 / 127 + 1e-3


@pytest.mark.parametrize("state_dtype", ["float32", "bfloat16", "int8"])
def test_adamw_converges_on_quadratic(state_dtype):
    ocfg = OptConfig(lr=0.1, weight_decay=0.0, clip_norm=0.0, state_dtype=state_dtype)
    params = {"w": jnp.asarray([3.0, -2.0, 1.5])}
    opt = init_opt_state(params, ocfg)
    for _ in range(300):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, opt, _ = adamw_update(grads, opt, params, ocfg)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05, state_dtype


def test_grad_clip_bounds_update():
    ocfg = OptConfig(lr=1.0, weight_decay=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params, ocfg)
    _, _, stats = adamw_update({"w": jnp.asarray([1e6, 0.0, 0.0])}, opt, params, ocfg)
    assert float(stats["grad_norm"]) > 1e5  # reported raw


def test_opt_state_shapes_match_init():
    params = {"a": jnp.zeros((7, 5)), "b": jnp.zeros((300,))}
    for sd in ("float32", "bfloat16", "int8"):
        ocfg = OptConfig(state_dtype=sd)
        st = init_opt_state(params, ocfg)
        shp = opt_state_shapes(params, ocfg)
        got = jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), st)
        want = jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), shp)
        assert got == want, sd


def test_schedule_warmup_cosine():
    from repro.train.schedule import WarmupCosine

    s = WarmupCosine(peak_lr=1.0, warmup_steps=10, total_steps=100, final_frac=0.1)
    assert float(s(0)) == 0.0
    assert abs(float(s(10)) - 1.0) < 0.11
    assert float(s(100)) == pytest.approx(0.1, abs=1e-3)
