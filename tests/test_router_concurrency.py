"""Race-hunting tests for the concurrent router runtime.

Covers the invariants concurrency can break and a serial loop never will:
conservation (submitted == completed + failed, no lost or duplicated rids),
exactly-once metrics recording for hedged requests in both finish orders,
bounded result-map growth, and warm-up-aware placement reading live engine
stats. The soak test drives real JAX engine-backed tiers from multiple
submitter threads; the hedge-race test makes the original and its duplicate
finish in both orders deterministically via event-controlled backends.
"""
import threading
import time

import pytest

from repro.core import Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, RequestFailed, StraightLineRouter


def _policy():
    # F huge: no burst path; D = 1e6: moderate payloads fall through to S_F/S_D
    return StraightLinePolicy(Thresholds(F=1e9, D=1e6))


def _conserved(router, submitted):
    """Assert conservation + exactly-once: every submitted rid appears in the
    metrics exactly once, and nothing else does."""
    m = router.metrics
    recorded = [r.rid for r in m.completed + m.failed]
    assert m.total == len(submitted), (m.total, len(submitted))
    assert len(recorded) == len(set(recorded)), "a request recorded metrics twice"
    assert set(recorded) == set(submitted), "lost or invented rids"


# ---------------------------------------------------------------------------
# Fake-backend stress: high volume, mixed failures, hedging
# ---------------------------------------------------------------------------


def test_soak_fake_backends_conservation_under_submitter_threads():
    """8 submitter threads x 25 requests over sleepy backends with injected
    tier failures and aggressive hedging: conservation and exactly-once must
    hold under whatever interleavings the scheduler produces."""

    def flask_run(req):
        time.sleep(0.001)
        if req.rid % 7 == 3:
            raise RuntimeError("flask flake")        # -> retried on serverless
        return f"f:{req.rid}"

    def docker_run(req):
        time.sleep(0.002)
        return f"d:{req.rid}"

    def sls_run(req):
        time.sleep(0.001)
        if req.rid % 50 == 11:
            raise RuntimeError("sls down")           # terminal failure path
        return f"s:{req.rid}"

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, flask_run, capacity=4, queue_cap=400),
            Tier.DOCKER: Backend(Tier.DOCKER, docker_run, capacity=4, queue_cap=400),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, sls_run, capacity=16, queue_cap=400),
        },
        policy=_policy(),
        hedge_after_s=0.005,
        results_cap=1000,
    )
    router.start(4)
    submitted = []
    sub_lock = threading.Lock()

    def submitter(base):
        for i in range(25):
            rid = base + i
            router.submit(Request(rid=rid, arrival_t=0.0, data_size=100.0, timeout_s=60.0))
            with sub_lock:
                submitted.append(rid)

    threads = [threading.Thread(target=submitter, args=(k * 1000,)) for k in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    router.drain(timeout=60)
    router.stop()

    _conserved(router, submitted)
    # the runtime left nothing behind: queues empty, inflight back to zero
    for b in router.backends.values():
        assert b.inflight == 0
        assert not any(not r.hedged for r in b.queue)  # only discarded hedge copies may remain


def test_results_bounded_and_popped_on_retrieval():
    """Regression: StraightLineRouter.results must not grow without bound —
    completed results are evicted past results_cap and popped on retrieval."""
    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, lambda req: f"ok:{req.rid}", capacity=4, queue_cap=500),
            Tier.DOCKER: Backend(Tier.DOCKER, lambda req: "d", capacity=4, queue_cap=500),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=8, queue_cap=500),
        },
        policy=_policy(),
        results_cap=32,
    )
    router.start(2)
    for i in range(200):
        router.submit(Request(rid=i, arrival_t=0.0, data_size=100.0, timeout_s=60.0))
    router.drain(timeout=30)
    router.stop()
    assert router.metrics.total == 200
    assert len(router.results) <= 32                   # eviction holds the cap
    # retrieval pops: a second result() for the same rid raises KeyError
    rid = next(reversed(router.results))
    val = router.result(rid)
    assert val == f"ok:{rid}"
    assert rid not in router.results
    with pytest.raises(KeyError):
        router.result(rid)
    # evicted rids are gone too
    with pytest.raises(KeyError):
        router.result(0)


@pytest.mark.parametrize("winner", ["original", "hedge"])
def test_hedge_race_first_result_wins_both_orders(winner):
    """Deterministic hedge race: the original (flask) and the duplicate
    (serverless) block on test-controlled events, so both finish orders are
    exercised. First result wins, metrics record exactly once, the loser's
    result is discarded."""
    release_flask = threading.Event()
    release_sls = threading.Event()
    sls_started = threading.Event()

    def flask_run(req):
        assert release_flask.wait(30)
        return "flask-result"

    def sls_run(req):
        sls_started.set()
        assert release_sls.wait(30)
        return "sls-result"

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, flask_run, capacity=1),
            Tier.DOCKER: Backend(Tier.DOCKER, lambda req: "d", capacity=1),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, sls_run, capacity=4),
        },
        policy=_policy(),
        hedge_after_s=0.01,                            # hedge fires almost immediately
    )
    router.start(2)
    try:
        router.submit(Request(rid=7, arrival_t=0.0, data_size=100.0, timeout_s=60.0))
        assert sls_started.wait(10), "hedge duplicate never started on the elastic tier"
        if winner == "original":
            release_flask.set()
            got = router.result(7, timeout=10)
            assert got == "flask-result"
            release_sls.set()                          # loser finishes after the win
        else:
            release_sls.set()
            got = router.result(7, timeout=10)
            assert got == "sls-result"
            release_flask.set()
        router.drain(timeout=10)
    finally:
        release_flask.set()
        release_sls.set()
        router.stop()
    m = router.metrics
    assert m.total == 1, "hedged request must record metrics exactly once"
    assert not m.failed
    rec = m.completed[0]
    assert rec.rid == 7
    expect_tier = Tier.FLASK if winner == "original" else Tier.SERVERLESS
    assert rec.tier == expect_tier                     # the winner's copy was recorded


def test_hedge_rollback_adopts_failure_absorbed_in_flight_window():
    """Regression for a stranding race: _fire_hedge optimistically counts
    the duplicate (live=2) before enqueueing it; if the original fails in
    that window its failure is absorbed 'because a sibling is live', and
    when the enqueue then fails (elastic saturated) nobody is left to
    settle the rid. The rollback must adopt the absorbed failure."""
    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, lambda req: "f", capacity=1),
            Tier.DOCKER: Backend(Tier.DOCKER, lambda req: "d", capacity=1),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=4),
        },
        policy=_policy(),
        hedge_after_s=1.0,
    )
    req = Request(rid=5, arrival_t=0.0, data_size=100.0, timeout_s=60.0)
    assert router.submit(req) == Tier.FLASK           # queued, never run
    c = router._completions[5]

    def racing_push(clone):                           # deterministic replay of the window
        router._fail(req, "error:Boom")               # original dies mid-hedge-fire
        return False                                  # ...and the elastic enqueue fails

    router.backends[Tier.SERVERLESS].try_push = racing_push
    router._fire_hedge(req)
    assert c.done and c.failure == "error:Boom" and c.live == 0
    assert router.metrics.total == 1 and len(router.metrics.failed) == 1
    with pytest.raises(RequestFailed):
        router.result(5, timeout=0.1)


def test_warmup_aware_placement_prefers_warm_tier():
    """While the interactive tier is still compiling its prefill buckets the
    placer routes moderate requests to the warmed-up batch tier; once the
    interactive tier is warm, Algorithm 1's S_F preference resumes."""
    stats = {
        Tier.FLASK: {"compile_events": 0, "total_buckets": 4},
        Tier.DOCKER: {"compile_events": 4, "total_buckets": 4},
    }
    mk = lambda t, cap: Backend(
        t, run=lambda req: "ok", capacity=cap, stats_fn=lambda: stats[t]
    )
    router = StraightLineRouter(
        {
            Tier.FLASK: mk(Tier.FLASK, 1),
            Tier.DOCKER: mk(Tier.DOCKER, 4),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=8),
        },
        policy=_policy(),
    )
    assert router.submit(Request(rid=0, arrival_t=0.0, data_size=100.0)) == Tier.DOCKER
    stats[Tier.FLASK]["compile_events"] = 4            # interactive finished warming
    assert router.submit(Request(rid=1, arrival_t=0.0, data_size=100.0)) == Tier.FLASK
    router.drain()


def test_warmup_gap_weighted_by_measured_compile_cost():
    """The router forwards each tier's compile-cost EMA with its warm
    fraction: a warmth gap whose expected stall is cheaper than one tier
    hop no longer pushes traffic off the interactive tier; an expensive
    one still does."""
    stats = {
        Tier.FLASK: {"compile_events": 1, "total_buckets": 4, "compile_ema_s": 0.01},
        Tier.DOCKER: {"compile_events": 4, "total_buckets": 4, "compile_ema_s": 0.01},
    }
    mk = lambda t, cap: Backend(
        t, run=lambda req: "ok", capacity=cap, stats_fn=lambda: stats[t]
    )
    router = StraightLineRouter(
        {
            Tier.FLASK: mk(Tier.FLASK, 1),
            Tier.DOCKER: mk(Tier.DOCKER, 4),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=8),
        },
        policy=_policy(),
    )
    # E[stall] = (1 - 1/4) * 10ms << hop cost: stay on the interactive tier
    assert router.submit(Request(rid=0, arrival_t=0.0, data_size=100.0)) == Tier.FLASK
    # same gap, heavyweight compiles: the hop pays for itself
    stats[Tier.FLASK]["compile_ema_s"] = 10.0
    assert router.submit(Request(rid=1, arrival_t=0.0, data_size=100.0)) == Tier.DOCKER
    router.drain()


# ---------------------------------------------------------------------------
# Engine-backed soak: real paged JAX engines behind every tier
# ---------------------------------------------------------------------------

N_SUBMITTERS = 4
REQS_PER_SUBMITTER = 6
PROMPT, NEW, MAXLEN, PS = 5, 3, 64, 16


@pytest.fixture(scope="module")
def engine_tiers():
    from repro.configs.registry import get_config
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    interactive = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + MAXLEN // PS, max_slots=1,
                          max_seq_len=MAXLEN, max_new_tokens=NEW),
    )
    batch = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS, max_slots=2,
                          max_seq_len=MAXLEN, max_new_tokens=NEW),
        params=interactive.params,
    )
    elastic = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS, max_slots=2,
                          max_seq_len=MAXLEN, max_new_tokens=NEW),
        params=interactive.params,
    )
    return cfg, interactive, batch, elastic


def test_prewarm_compiles_every_bucket_and_serving_adds_none(engine_tiers):
    """prewarm() compiles all bucket shapes up front; traffic after it must
    not trigger a single further prefill compile."""
    cfg, interactive, batch, elastic = engine_tiers
    warmed = batch.prewarm()
    snap = batch.capacity_now()
    assert snap["total_buckets"] > 0
    assert snap["compile_events"] == snap["total_buckets"]
    assert warmed or batch.compile_events == snap["total_buckets"]
    before = batch.compile_events
    out = batch.generate([[1, 2, 3, 4, 5]])
    assert len(out) == 1 and len(out[0].out) == NEW
    assert batch.compile_events == before, "a warm engine recompiled on real traffic"
    assert batch.prewarm() == []                       # idempotent


def test_soak_engine_backed_tiers(engine_tiers):
    """The soak: N submitter threads x M requests against real paged-engine
    tiers with live capacity probes and hedging enabled. Conservation and
    exactly-once metrics must hold; every completed request carries real
    engine tokens."""
    import numpy as np

    cfg, interactive, batch, elastic = engine_tiers
    for eng in (interactive, batch, elastic):
        eng.prewarm()

    def run_on(engine):
        def run(req):
            prompt = list(np.random.default_rng(req.rid).integers(1, cfg.vocab_size, PROMPT))
            return engine.generate([prompt])[0].out
        return run

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(
                Tier.FLASK, run_on(interactive), capacity=1, queue_cap=64,
                capacity_fn=lambda: interactive.admission_capacity(PROMPT + NEW),
                stats_fn=interactive.capacity_now,
            ),
            Tier.DOCKER: Backend(
                Tier.DOCKER, run_on(batch), capacity=2, queue_cap=64,
                capacity_fn=lambda: batch.admission_capacity(PROMPT + NEW),
                stats_fn=batch.capacity_now,
            ),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, run_on(elastic), capacity=4, queue_cap=64),
        },
        policy=_policy(),
        hedge_after_s=0.05,
        results_cap=256,
    )
    router.start(2)
    submitted = []
    sub_lock = threading.Lock()

    def submitter(base):
        for i in range(REQS_PER_SUBMITTER):
            rid = base + i
            router.submit(Request(rid=rid, arrival_t=0.0, data_size=100.0, timeout_s=120.0))
            with sub_lock:
                submitted.append(rid)

    threads = [threading.Thread(target=submitter, args=(k * 100,)) for k in range(N_SUBMITTERS)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    router.drain(timeout=120)
    router.stop()

    _conserved(router, submitted)
    assert not router.metrics.failed, [r.fail_reason for r in router.metrics.failed]
    # every result is a real engine generation: NEW greedy tokens, exactly
    # the sequence a lone engine produces for that rid's prompt
    probe = np.random.default_rng(submitted[0]).integers(1, cfg.vocab_size, PROMPT)
    expect = elastic.generate([list(probe)])[0].out
    got = router.result(submitted[0], timeout=5)
    assert got == expect
    for rid in submitted[1:]:
        out = router.result(rid, timeout=5)
        assert len(out) == NEW
    # engines fully drained: no sequence left running, all pages back
    for eng in (interactive, batch, elastic):
        assert all(s is None for s in eng.slot_seq)
        eng.allocator.check_invariants()
        assert eng.allocator.free_pages == eng.pcfg.num_pages - 1


# ---------------------------------------------------------------------------
# Hedge monitor pacing (PR 8 satellite): injected clock + prompt stop
# ---------------------------------------------------------------------------


def test_hedge_scan_fires_deterministically_on_injected_clock():
    """Regression: the hedge monitor paced staleness checks on a real
    ``time.sleep`` even when a fake clock was injected, so fake-clock tests
    had to sleep real wall time and hope the monitor ran. ``_hedge_scan``
    is one synchronous pass against ``self.clock`` — advance the fake
    clock, call it, and hedging is exact: fires only past ``hedge_after_s``
    and exactly once per request."""
    t = [0.0]
    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, lambda req: "f", capacity=1),
            Tier.DOCKER: Backend(Tier.DOCKER, lambda req: "d", capacity=1),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=4),
        },
        policy=_policy(),
        hedge_after_s=1.0,
        clock=lambda: t[0],
    )
    req = Request(rid=11, arrival_t=t[0], data_size=100.0, timeout_s=60.0)
    assert router.submit(req) == Tier.FLASK       # queued: router not started
    assert router._hedge_scan() == 0              # fresh
    t[0] = 1.0
    assert router._hedge_scan() == 0              # exactly at the threshold: not stale
    t[0] = 1.01
    assert router._hedge_scan() == 1              # past it: fires
    assert req.hedged
    t[0] = 50.0
    assert router._hedge_scan() == 0              # never re-fires for a hedged request


def test_hedge_monitor_stop_wakes_the_sleeping_monitor():
    """stop() must not wait out a sleeping monitor tick: the loop paces on
    the stop Event, so setting it wakes the thread immediately and the
    join in stop() returns with every thread dead."""
    router = StraightLineRouter(
        {Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=4)},
        policy=_policy(),
        hedge_after_s=60.0,                       # tick clamps to 50 ms
    )
    router.start(workers_per_tier=1)
    monitor = [th for th in router._threads if th.name == "router-hedge"]
    assert monitor and monitor[0].is_alive()
    router.stop()
    assert router._monitor_stop.is_set()
    assert not monitor[0].is_alive()
    router.start(workers_per_tier=1)              # restart re-arms the Event
    assert not router._monitor_stop.is_set()
    router.stop()
