"""Paged KV-cache subsystem: allocator invariants, kernel parity, engine v2
preemption/resume/fork, and live-capacity placement feedback."""
import dataclasses
from collections import Counter

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_fallback import given, settings, st

from repro.configs.registry import get_config
from repro.core import CapacityGauge, Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.paging import NULL_PAGE, BlockAllocator, OutOfPages, PageTable
from repro.serving.prefix_cache import PrefixCache


# ---------------------------------------------------------------------------
# BlockAllocator / PageTable
# ---------------------------------------------------------------------------


def test_allocator_alloc_free_invariants():
    a = BlockAllocator(num_pages=8, page_size=4)
    assert a.free_pages == 7                      # page 0 reserved
    p1 = a.alloc(3)
    assert len(set(p1)) == 3 and NULL_PAGE not in p1
    p2 = a.alloc(4)
    assert not (set(p1) & set(p2))                # never hand out a page twice
    assert a.free_pages == 0
    assert not a.can_alloc(1)
    with pytest.raises(OutOfPages):
        a.alloc(1)
    a.free(p1)
    assert a.free_pages == 3
    a.check_invariants()
    with pytest.raises(ValueError):
        a.free([p1[0]])                           # double free detected


def test_allocator_all_or_nothing():
    a = BlockAllocator(num_pages=4, page_size=4)
    with pytest.raises(OutOfPages):
        a.alloc(5)
    assert a.free_pages == 3                      # failed alloc leaks nothing
    a.check_invariants()


def test_allocator_refcounts_shared_pages():
    a = BlockAllocator(num_pages=6, page_size=4)
    pages = a.alloc(2)
    assert a.ref_count(pages[0]) == 1
    a.share(pages[0])
    assert a.ref_count(pages[0]) == 2
    a.free([pages[0]])                            # one owner drops
    assert a.ref_count(pages[0]) == 1
    assert pages[0] not in list(a._free)          # still held by the other
    a.free([pages[0], pages[1]])
    assert a.free_pages == 5
    a.check_invariants()


def test_page_table_fork_shares_full_pages_and_cows_partial():
    a = BlockAllocator(num_pages=10, page_size=4)
    t = PageTable(4, a.alloc(3), num_tokens=9)    # 2 full pages + 1 partial
    f = t.fork(a)
    assert f.pages[:2] == t.pages[:2]             # full prefix shared
    assert f.pages[2] != t.pages[2]               # partial page copied-on-write
    assert a.ref_count(t.pages[0]) == 2 and a.ref_count(t.pages[2]) == 1
    t.release(a)
    assert a.ref_count(f.pages[0]) == 1           # fork still holds the prefix
    f.release(a)
    a.check_invariants()
    assert a.used_pages == 0


def test_bucket_lengths_enumerates_exactly_the_bucket_fixed_points():
    from repro.serving.paging import bucket_lengths, bucket_tokens, num_buckets

    for unit, cap in [(16, 256), (16, 96), (4, 4), (8, 100)]:
        ls = bucket_lengths(unit, cap)
        assert len(ls) == num_buckets(unit, cap)       # one shape per compile
        assert ls == sorted(set(ls))
        # every enumerated length is a fixed point of bucket_tokens — i.e.
        # prewarm compiles exactly the shapes real traffic will request
        assert all(bucket_tokens(n, unit, cap) == n for n in ls)


def test_page_table_row_pads_with_null_page():
    t = PageTable(4, [3, 5], num_tokens=6)
    assert t.row(4) == [3, 5, NULL_PAGE, NULL_PAGE]
    with pytest.raises(ValueError):
        t.row(1)


@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["admit", "preempt", "fork", "grow", "free",
                             "speculate"]),
            st.integers(0, 15),            # which live table the op targets
            st.integers(1, 12),            # admit ctx length / spec k+accepted
        ),
        min_size=1,
        max_size=80,
    )
)
@settings(max_examples=120, deadline=None)
def test_allocator_pagetable_invariants_under_random_interleavings(ops):
    """Drive the orders the concurrent router runtime can produce — admit,
    preempt (release + later re-admit), fork (hedged copy: prefix sharing +
    CoW), grow, free, speculate (reserve the verify window's pages up
    front, accept a shorter run, trim the rejected tail) — against
    BlockAllocator/PageTable and assert after every step that (a) the
    allocator's free/used partition is exact, and (b) every page's
    ref-count equals the number of live tables holding it. Finally
    releasing everything must return the pool to fully free."""
    PS = 4
    alloc = BlockAllocator(num_pages=13, page_size=PS)
    tables = []                                        # live sequences
    parked = []                                        # preempted, pages released

    def check():
        alloc.check_invariants()
        held = Counter(p for t in tables for p in t.pages)
        for page, n in held.items():
            assert alloc.ref_count(page) == n, (page, n, alloc.ref_count(page))
        assert alloc.used_pages == len(held)
        assert alloc.free_pages == alloc.num_pages - 1 - len(held)

    for op, idx, n_tokens in ops:
        if op == "admit":
            from_parked = bool(parked)
            ctx = parked.pop(idx % len(parked)) if parked else n_tokens
            need = PageTable.pages_needed(ctx + 1, PS)
            if alloc.can_alloc(need):
                tables.append(PageTable(PS, alloc.alloc(need), num_tokens=ctx))
            elif from_parked:
                parked.append(ctx)                     # re-park the preempted ctx
        elif op == "preempt" and tables:
            t = tables.pop(idx % len(tables))
            parked.append(t.num_tokens)                # recompute-resume keeps only the ctx
            t.release(alloc)
        elif op == "fork" and tables:
            src = tables[idx % len(tables)]
            try:
                tables.append(src.fork(alloc))
            except OutOfPages:
                pass                                   # failed fork must leak nothing
        elif op == "grow" and tables:
            t = tables[idx % len(tables)]
            if t.capacity_tokens <= t.num_tokens and alloc.can_alloc(1):
                t.append_pages(alloc.alloc(1))
            t.num_tokens = min(t.num_tokens + 1, t.capacity_tokens)
        elif op == "free" and tables:
            tables.pop(idx % len(tables)).release(alloc)
        elif op == "speculate" and tables:
            # the paged engine's verify window: allocate pages covering
            # L..L+k up front, accept m <= k+1 tokens, trim back to
            # max(pre-spec pages, accepted coverage) — the freed tail must
            # be exactly the speculative overshoot, never a shared page
            t = tables[idx % len(tables)]
            L, k = t.num_tokens, 1 + n_tokens % 4
            n0 = len(t.pages)
            need = PageTable.pages_needed(L + k + 1, PS) - n0
            if need > 0:
                if not alloc.can_alloc(need):
                    check()
                    continue
                t.append_pages(alloc.alloc(need))
            m = 1 + (idx + n_tokens) % (k + 1)         # accepted run, 1..k+1
            keep = max(n0, PageTable.pages_needed(L + m, PS))
            t.trim(keep, alloc)
            t.num_tokens = L + m
            assert len(t.pages) >= PageTable.pages_needed(L + m, PS)
        check()

    for t in tables:
        t.release(alloc)
    alloc.check_invariants()
    assert alloc.used_pages == 0
    assert alloc.free_pages == alloc.num_pages - 1


def _stream(family: int, n: int):
    """Deterministic token stream for one prompt family: families sharing a
    base share a 10-token prefix (2.5 pages at PS=4 — real prefix overlap
    AND mid-node splits), then diverge."""
    base = family % 3
    return [base if i < 10 else base + 3 * (1 + family % 2) for i in range(n)]


@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["admit", "preempt", "fork", "grow", "free",
                             "cache", "evict"]),
            st.integers(0, 15),            # which live sequence the op targets
            st.integers(0, 5),             # prompt family (shared prefixes)
            st.integers(1, 14),            # admit context length / evict count
        ),
        min_size=1,
        max_size=80,
    )
)
@settings(max_examples=120, deadline=None)
def test_prefix_cache_allocator_invariants_under_random_interleavings(ops):
    """The radix-tree prefix cache interleaved with the full sequence
    lifecycle — admit (match-on-admit: acquire + alloc the suffix), preempt
    (drop every reference, cache survives), fork (prefix sharing + path
    pin), grow, free, release-to-cache (insert full pages, free the tail)
    and LRU eviction — asserting after every op that (a) the allocator's
    free/used partition is exact, (b) every page's ref-count equals live
    tables holding it plus the tree's single reference, and (c) the tree's
    structural/counter invariants hold. Finally releasing everything and
    dropping the cache must return the pool to fully free."""
    PS = 4
    alloc = BlockAllocator(num_pages=17, page_size=PS)
    cache = PrefixCache(alloc, PS)
    live = []                                   # (table, tokens, node-or-None)

    def check():
        alloc.check_invariants()
        cache.check_invariants()
        held = Counter(p for t, _, _ in live for p in t.pages)
        tree_pages = set(cache.pages())
        for page in set(held) | tree_pages:
            expect = held.get(page, 0) + (1 if page in tree_pages else 0)
            assert alloc.ref_count(page) == expect, (page, expect)
        assert alloc.used_pages == len(set(held) | tree_pages)
        assert alloc.free_pages == alloc.num_pages - 1 - alloc.used_pages

    for op, idx, family, n in ops:
        if op == "admit":
            toks = _stream(family, n)
            need = PageTable.pages_needed(len(toks) + 1, PS)
            pages, node, matched = cache.acquire(toks)
            if alloc.can_alloc(need - len(pages)):
                t = PageTable(PS, pages + alloc.alloc(need - len(pages)),
                              num_tokens=len(toks))
                live.append((t, toks, node))
            else:
                cache.cancel(pages, node)        # failed admission leaks nothing
        elif op == "preempt" and live:           # == free: recompute-resume
            t, _, node = live.pop(idx % len(live))
            if node is not None:
                cache.release(node)
            t.release(alloc)
        elif op == "fork" and live:
            t, toks, node = live[idx % len(live)]
            try:
                f = t.fork(alloc)
            except OutOfPages:
                continue                         # failed fork must leak nothing
            live.append((f, list(toks), cache.pin(node) if node is not None else None))
        elif op == "grow" and live:
            t, toks, node = live[idx % len(live)]
            if t.capacity_tokens <= t.num_tokens:
                if not alloc.can_alloc(1):
                    continue
                t.append_pages(alloc.alloc(1))
            toks.append(_stream(family, t.num_tokens + 1)[-1])
            t.num_tokens += 1
        elif op == "free" and live:
            t, _, node = live.pop(idx % len(live))
            if node is not None:
                cache.release(node)
            t.release(alloc)
        elif op == "cache" and live:             # release-to-cache
            t, toks, node = live.pop(idx % len(live))
            if node is not None:
                cache.release(node)
            n_full = len(toks) // PS
            cache.insert(toks, t.pages[:n_full])
            alloc.free(t.pages[n_full:])
        elif op == "evict":
            cache.evict(n)
        check()

    for t, _, node in live:
        if node is not None:
            cache.release(node)
        t.release(alloc)
    cache.drop()
    alloc.check_invariants()
    assert alloc.used_pages == 0
    assert alloc.free_pages == alloc.num_pages - 1


# ---------------------------------------------------------------------------
# Paged attention kernel vs pure-jnp reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lens", [[1, 5, 17, 32], [8, 8, 8, 8], [31, 2, 16, 1]])
def test_paged_attention_kernel_matches_ref(lens):
    from repro.kernels.paged_attention.kernel import paged_attention_grouped
    from repro.kernels.paged_attention.ref import paged_attention_ref

    rng = np.random.default_rng(0)
    B, KV, G, hd, ps, P, NP = 4, 2, 3, 16, 8, 4, 20
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32)
    pk = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
    # distinct physical pages per sequence, padded with the null page
    perm = rng.permutation(np.arange(1, NP))[: B * P].reshape(B, P)
    tab = np.where(
        np.arange(P)[None, :] < -(-np.asarray(lens) // ps)[:, None], perm, NULL_PAGE
    )
    o_kernel = paged_attention_grouped(
        q, pk, pv, jnp.asarray(tab, jnp.int32), jnp.asarray(lens, jnp.int32), interpret=True
    )
    o_ref = paged_attention_ref(q, pk, pv, jnp.asarray(tab, jnp.int32), jnp.asarray(lens, jnp.int32))
    assert jnp.allclose(o_kernel, o_ref, atol=1e-5), float(jnp.max(jnp.abs(o_kernel - o_ref)))


@pytest.mark.parametrize("softcap", [20.0, 5.0])
def test_paged_attention_kernel_softcap_matches_ref(softcap):
    """The decode kernel's gemma-style logit softcap must match the jnp
    oracle (and differ from the uncapped scores — the cap is really on)."""
    from repro.kernels.paged_attention.kernel import paged_attention_grouped
    from repro.kernels.paged_attention.ref import paged_attention_ref

    rng = np.random.default_rng(1)
    B, KV, G, hd, ps, P, NP = 3, 2, 2, 16, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(B, KV, G, hd)), jnp.float32) * 4.0
    pk = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
    pv = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
    perm = rng.permutation(np.arange(1, NP))[: B * P].reshape(B, P)
    lens = jnp.asarray([7, 19, 30], jnp.int32)
    tab = jnp.asarray(perm, jnp.int32)
    o_kernel = paged_attention_grouped(q, pk, pv, tab, lens, interpret=True, softcap=softcap)
    o_ref = paged_attention_ref(q, pk, pv, tab, lens, softcap=softcap)
    o_uncapped = paged_attention_ref(q, pk, pv, tab, lens)
    assert jnp.allclose(o_kernel, o_ref, atol=1e-5), float(jnp.max(jnp.abs(o_kernel - o_ref)))
    assert not jnp.allclose(o_ref, o_uncapped, atol=1e-5), "softcap had no effect"


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("Lp,n_real", [(16, 2), (32, 3), (8, 1)])
def test_paged_prefill_write_kernel_matches_ref(Lp, n_real, dtype):
    """The Pallas prefill-write scatter lands exactly where the jnp ref
    does: the sequence's real pages get the transposed K/V chunks, bucket
    padding is absorbed by the null page, and every untouched page of the
    pool is preserved bit-for-bit (input/output aliasing)."""
    from repro.kernels.paged_attention.kernel import paged_prefill_write_grouped
    from repro.kernels.paged_attention.ref import paged_prefill_write_ref

    rng = np.random.default_rng(2)
    KV, hd, ps, NP, P = 2, 16, 8, 12, 6
    pool_k = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32).astype(dtype)
    pool_v = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32).astype(dtype)
    k = jnp.asarray(rng.normal(size=(1, Lp, KV, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, Lp, KV, hd)), jnp.float32)
    real = rng.permutation(np.arange(1, NP))[:n_real]
    tab = np.full(P, NULL_PAGE, np.int32)
    tab[:n_real] = real
    tab = jnp.asarray(tab)
    rk, rv = paged_prefill_write_ref(pool_k, pool_v, k, v, tab)
    gk, gv = paged_prefill_write_grouped(pool_k, pool_v, k, v, tab, interpret=True)
    touched = np.zeros(NP, bool)
    touched[np.asarray(real)] = True
    # real pages carry the scattered prompt; the null page is garbage by
    # contract (duplicate pad writes race) and excluded from parity
    assert jnp.array_equal(jnp.asarray(gk)[touched], jnp.asarray(rk)[touched])
    assert jnp.array_equal(jnp.asarray(gv)[touched], jnp.asarray(rv)[touched])
    untouched = ~touched
    untouched[NULL_PAGE] = False
    assert jnp.array_equal(jnp.asarray(gk)[untouched], jnp.asarray(pool_k)[untouched])
    assert jnp.array_equal(jnp.asarray(gv)[untouched], jnp.asarray(pool_v)[untouched])


def test_paged_prefill_write_dispatch_ragged_falls_back():
    """ops.paged_prefill_write: page-multiple prompts use the Pallas kernel,
    ragged ones (bucketing off) the ref — both must agree with the ref."""
    from repro.kernels.paged_attention import ops as pa_ops
    from repro.kernels.paged_attention.ref import paged_prefill_write_ref

    rng = np.random.default_rng(3)
    KV, hd, ps, NP = 2, 8, 4, 8
    pool = jnp.asarray(rng.normal(size=(NP, KV, ps, hd)), jnp.float32)
    tab = jnp.asarray([3, 5, 0, 0], jnp.int32)
    for Lp in (8, 7):                       # page multiple, then ragged
        k = jnp.asarray(rng.normal(size=(1, Lp, KV, hd)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, Lp, KV, hd)), jnp.float32)
        gk, gv = pa_ops.paged_prefill_write(pool, pool, k, v, tab, use_pallas=True)
        rk, rv = paged_prefill_write_ref(pool, pool, k, v, tab)
        mask = np.zeros(NP, bool)
        mask[[3, 5]] = True
        assert jnp.array_equal(jnp.asarray(gk)[mask], jnp.asarray(rk)[mask]), Lp
        assert jnp.array_equal(jnp.asarray(gv)[mask], jnp.asarray(rv)[mask]), Lp


def test_softcap_dense_and_paged_engines_agree():
    """Gemma-style logit softcap now serves paged: the paged engine must
    emit exactly the dense engine's greedy tokens under softcap (regression
    for the paged_kv_pool_defs NotImplementedError)."""
    cfg = _smoke("smollm-360m").replace(logit_softcap=8.0)
    dense = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=4))
    d = dense.generate(PROMPTS)
    paged = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=8, num_pages=17, max_slots=4, max_seq_len=64, max_new_tokens=4),
        params=dense.params,
    )
    p = paged.generate(PROMPTS)
    assert [s.out for s in d] == [s.out for s in p]
    paged.allocator.check_invariants()
    assert paged.allocator.used_pages == 0


# ---------------------------------------------------------------------------
# Paged engine v2
# ---------------------------------------------------------------------------

PROMPTS = [[1, 2, 3, 4], [5, 6, 7], [9, 10, 11, 12, 13]]


def _smoke(arch):
    cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
    if cfg.moe is not None:
        # ample expert capacity => exact greedy (same trick as test_engine)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ["smollm-360m", "jamba-1.5-large-398b", "xlstm-350m"])
def test_paged_engine_matches_dense_engine(arch):
    """Paged continuous batching must be a pure memory-layout change: same
    greedy tokens as the dense v1 engine (attn layers paged; recurrent
    mixers keep per-slot state)."""
    cfg = _smoke(arch)
    dense = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=4))
    d = dense.generate(PROMPTS)
    paged = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=8, num_pages=17, max_slots=4, max_seq_len=64, max_new_tokens=4),
        params=dense.params,
    )
    p = paged.generate(PROMPTS)
    assert [s.out for s in d] == [s.out for s in p]
    paged.allocator.check_invariants()
    assert paged.allocator.used_pages == 0        # every page returned


def test_paged_engine_admission_gated_on_pages_not_slots():
    cfg = _smoke("smollm-360m")
    eng = PagedInferenceEngine(
        cfg,
        # 3 usable pages of 4 tokens; 8 slots — pages are the binding constraint
        PagedEngineConfig(page_size=4, num_pages=4, max_slots=8, max_seq_len=8, max_new_tokens=2),
    )
    for p in ([1, 2, 3, 4], [4, 5, 6, 7], [7, 8, 9, 1]):
        eng.submit(p)                             # each needs ceil(5/4) = 2 pages
    eng._admit()
    active = sum(1 for s in eng.slot_seq if s is not None)
    assert active == 1                            # only 1 more page after the first
    assert len(eng.waiting) == 2                  # rest held back by the free list
    assert eng.free_slots() == 7                  # slots were never the limit


def test_preemption_and_resume_identical_tokens():
    """Page exhaustion preempts the newest sequence; recompute-resume must
    reproduce the exact unpreempted continuation (greedy determinism)."""
    cfg = _smoke("smollm-360m")
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [2, 4, 6, 1]]
    ample = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=33, max_slots=4, max_seq_len=32, max_new_tokens=8),
    )
    a = ample.generate(prompts)
    assert ample.preemptions == 0
    # 9 usable pages: all 4 admit with 2 pages, growth to a 3rd page starves
    tight = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=10, max_slots=4, max_seq_len=32, max_new_tokens=8),
        params=ample.params,
    )
    t = tight.generate(prompts)
    assert tight.preemptions > 0
    assert [s.out for s in a] == [s.out for s in t]
    tight.allocator.check_invariants()
    assert tight.allocator.used_pages == 0


def test_fork_shares_prefix_pages_and_clones_continuation():
    cfg = _smoke("smollm-360m")
    eng = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=20, max_slots=4, max_seq_len=32, max_new_tokens=8),
    )
    sid = eng.submit([1, 2, 3, 4, 5, 6])
    eng.step()
    eng.step()                                    # a few tokens in, mid-page
    src_slot = next(i for i, s in enumerate(eng.slot_seq) if s is not None)
    shared = eng.tables[src_slot].pages[0]
    csid = eng.fork(sid)
    assert csid is not None
    assert eng.allocator.ref_count(shared) == 2   # prefix page shared, not copied
    done = {}
    for _ in range(40):
        for s in eng.step():
            done[s.sid] = s.out
        if len(done) == 2:
            break
    assert done[sid] == done[csid]                # greedy clones stay identical
    eng.allocator.check_invariants()
    assert eng.allocator.used_pages == 0


def test_stop_conditions_apply_to_prefill_emitted_token():
    """max_new_tokens=1 must yield exactly one token, delivered by the same
    step() that admitted the sequence — in both engines."""
    cfg = _smoke("smollm-360m")
    dense = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=32, max_new_tokens=1))
    paged = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=8, num_pages=9, max_slots=2, max_seq_len=32, max_new_tokens=1),
        params=dense.params,
    )
    for eng in (dense, paged):
        eng.submit([1, 2, 3])
        out = eng.step()                          # admission alone finishes it
        assert len(out) == 1 and len(out[0].out) == 1 and out[0].done
    # greedy EOS emitted straight from prefill also stops immediately
    probe = InferenceEngine(
        cfg, EngineConfig(max_slots=1, max_len=32, max_new_tokens=8), params=dense.params
    ).generate([[1, 2, 3]])[0]
    eos = probe.out[0]
    eng2 = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=8, num_pages=9, max_slots=1, max_seq_len=32,
                          max_new_tokens=8, eos_id=eos),
        params=dense.params,
    )
    s = eng2.generate([[1, 2, 3]])[0]
    assert s.out == [eos]
    eng2.allocator.check_invariants()
    assert eng2.allocator.used_pages == 0


def test_engine_capacity_telemetry_moves_with_load():
    cfg = _smoke("smollm-360m")
    eng = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=9, max_slots=4, max_seq_len=16, max_new_tokens=8),
    )
    before = eng.capacity_now()
    assert before["free_pages"] == 8
    eng.submit([1, 2, 3, 4, 5])
    eng.step()
    during = eng.capacity_now()
    assert during["free_pages"] < before["free_pages"]
    assert during["free_slots"] == 3
    assert eng.admission_capacity(est_tokens=5) < before["free_pages"]


# ---------------------------------------------------------------------------
# Live capacity feedback into Algorithm 1
# ---------------------------------------------------------------------------


def _router(flask_fn=None, docker_fn=None, queue_cap=64):
    mk = lambda t, cap, fn: Backend(
        t, run=lambda req: "ok", capacity=cap, queue_cap=queue_cap, capacity_fn=fn
    )
    return StraightLineRouter(
        {
            Tier.FLASK: mk(Tier.FLASK, 1, flask_fn),
            Tier.DOCKER: mk(Tier.DOCKER, 4, docker_fn),
            Tier.SERVERLESS: mk(Tier.SERVERLESS, 16, None),
        },
        policy=StraightLinePolicy(Thresholds(F=1e9, D=1e6)),
    )


def test_router_free_counts_only_capacity_not_queue_headroom():
    r = _router()
    b = r.backends[Tier.FLASK]
    assert r._free(Tier.FLASK) == 1
    b.inflight = 1
    assert r._free(Tier.FLASK) == 0               # busy tier is NOT available
    assert b.queue_cap > 0                        # ...even with queue headroom


def test_router_falls_back_to_static_capacity_when_probe_goes_dark():
    gauge = CapacityGauge()                       # nothing registered
    r = _router(flask_fn=lambda: gauge.free("flask"))
    assert r._free(Tier.FLASK) == 1               # None probe -> static capacity


def test_router_placement_follows_live_capacity_probe():
    gauge = CapacityGauge()
    free = {"flask": 1}
    gauge.register("flask", lambda: free["flask"])
    r = _router(flask_fn=lambda: gauge.free("flask"))
    t1 = r.submit(Request(rid=0, arrival_t=0.0, data_size=100.0))
    assert t1 == Tier.FLASK
    free["flask"] = 0                             # engine page pool exhausted
    t2 = r.submit(Request(rid=1, arrival_t=0.0, data_size=100.0))
    assert t2 == Tier.DOCKER                      # S_F empty -> fall through


def test_drain_runs_queued_work_even_when_probe_reports_zero():
    """Live capacity gates placement of NEW work; already-queued requests
    (e.g. Algorithm 1's unconditional big-payload -> docker path) must still
    drain when a probe is stuck at 0."""
    r = _router(docker_fn=lambda: 0)
    t = r.submit(Request(rid=0, arrival_t=0.0, data_size=5e6))
    assert t == Tier.DOCKER                       # r_d > D: placed regardless
    r.drain()
    assert not r.backends[Tier.DOCKER].queue
    assert r.metrics.total == 1 and not r.metrics.failed
    assert r.results[0] == "ok"


def test_submit_enforces_queue_cap_deflect_then_reject():
    """Admission control: a full backlog deflects to serverless instead of
    growing without bound; a full serverless queue rejects outright."""
    r = _router(queue_cap=1)
    big = lambda rid: Request(rid=rid, arrival_t=0.0, data_size=5e7)  # r_d > D
    assert r.submit(big(0)) == Tier.DOCKER        # placed, queued
    t1 = r.submit(big(1))
    assert t1 == Tier.SERVERLESS                  # docker backlog full -> deflect
    t2 = r.submit(big(2))
    assert t2 == Tier.SERVERLESS                  # even serverless is full...
    assert len(r.metrics.failed) == 1             # ...fast rejection, not queueing
    assert r.metrics.failed[0].fail_reason == "queue-full"
    assert len(r.backends[Tier.DOCKER].queue) == 1
    assert len(r.backends[Tier.SERVERLESS].queue) == 1
    r.drain()                                     # admitted work still completes
    assert r.metrics.total == 3 and len(r.metrics.failed) == 1


def test_retry_respects_serverless_queue_cap():
    """The failure-retry path must honor queue_cap too: with serverless
    saturated, a failing tier's request fails fast instead of growing the
    serverless backlog without bound."""
    from repro.core.router import StraightLineRouter

    def boom(req):
        raise RuntimeError("tier down")

    mk = lambda t, run, cap: Backend(t, run=run, capacity=cap, queue_cap=1)
    r = StraightLineRouter(
        {
            Tier.FLASK: mk(Tier.FLASK, boom, 1),
            Tier.DOCKER: mk(Tier.DOCKER, boom, 4),
            Tier.SERVERLESS: mk(Tier.SERVERLESS, lambda req: "ok", 16),
        },
        policy=StraightLinePolicy(Thresholds(F=1e9, D=1e6)),
    )
    r.backends[Tier.SERVERLESS].queue.append(
        Request(rid=99, arrival_t=0.0, data_size=1.0)
    )                                             # saturate the spill target
    r.submit(Request(rid=0, arrival_t=0.0, data_size=100.0))
    r.poll()                                      # flask run fails, cannot spill
    assert len(r.backends[Tier.SERVERLESS].queue) <= 1
    failed = [q for q in r.metrics.failed if q.rid == 0]
    assert failed and failed[0].fail_reason.startswith("error:")


def test_tiersim_free_slots_follows_capacity_probe():
    from repro.core.testbed import paper_tiers

    gauge = CapacityGauge()
    live = {"n": 5}
    gauge.register("flask", lambda: live["n"])
    tier = paper_tiers(seed=0)[Tier.FLASK]
    static = tier.free_slots()
    tier.capacity_probe = lambda: gauge.free("flask")
    assert tier.free_slots() == 5                 # live probe wins
    live["n"] = 0
    assert tier.free_slots() == 0
    gauge.unregister("flask")
    assert tier.free_slots() == static            # probe gone dark -> queue model


def test_place_all_big_payloads_consume_docker_availability():
    pol = StraightLinePolicy(Thresholds(F=1e9, D=1e3))
    reqs = [
        Request(rid=0, arrival_t=0.0, data_size=5e3),   # big -> docker
        Request(rid=1, arrival_t=0.0, data_size=10.0),  # moderate
    ]
    ds = pol.place_all(reqs, f_t=0.0, flask_free=0, docker_free=1)
    assert ds[0].tier == Tier.DOCKER
    assert ds[1].tier == Tier.SERVERLESS          # docker slot already consumed


# ---------------------------------------------------------------------------
# Chained (two-level) block tables
# ---------------------------------------------------------------------------


@given(
    ops=st.lists(
        st.tuples(
            st.sampled_from(["set", "grow", "trim", "clear", "pad"]),
            st.integers(0, 3),             # slot
            st.integers(0, 12),            # row length the op targets
        ),
        min_size=1,
        max_size=60,
    )
)
@settings(max_examples=120, deadline=None)
def test_chained_tables_match_flat_oracle_under_random_interleavings(ops):
    """ChainedTables is the engine's device-side view of per-slot page rows:
    drive the rewrite patterns the engine produces — whole-row set (admit /
    resume / fork), grow by one page, trim (spec-decode rollback), clear
    (release), and null-padded rows (the engine passes ``table.row(width)``
    verbatim) — and assert after every op that (a) ``flat_row`` re-derives
    exactly the flat row a one-level table would hold, and (b) the l2 row
    free-list/ownership invariants hold (no leak, no double-own, null row
    intact). Clearing every slot must return all table pages."""
    from repro.serving.paging import ChainedTables

    MAX_SLOTS, W1, TPP = 4, 3, 4
    ct = ChainedTables(MAX_SLOTS, W1, TPP)
    oracle = {s: [] for s in range(MAX_SLOTS)}   # slot -> non-null page list
    next_page = [1]

    def pages(n):
        out = list(range(next_page[0], next_page[0] + n))
        next_page[0] += n
        return out

    for op, slot, n in ops:
        if op == "set":
            oracle[slot] = pages(n)
            ct.set_row(slot, oracle[slot])
        elif op == "grow":
            if len(oracle[slot]) < W1 * TPP:
                oracle[slot] = oracle[slot] + pages(1)
            ct.set_row(slot, oracle[slot])
        elif op == "trim":
            oracle[slot] = oracle[slot][: n % (len(oracle[slot]) + 1)]
            ct.set_row(slot, oracle[slot])
        elif op == "clear":
            oracle[slot] = []
            ct.clear(slot)
        elif op == "pad":
            # engine-style: a full-width row with trailing null padding must
            # cost exactly the table pages the real prefix needs
            row = oracle[slot] + [NULL_PAGE] * (W1 * TPP - len(oracle[slot]))
            ct.set_row(slot, row)
        ct.check_invariants(MAX_SLOTS)
        for s in range(MAX_SLOTS):
            want = oracle[s] + [NULL_PAGE] * (W1 * TPP - len(oracle[s]))
            assert ct.flat_row(s) == want, (s, oracle[s])
        used_rows = sum(-(-len(r) // TPP) for r in oracle.values())
        assert ct.free_rows == ct.l2.shape[0] - 1 - used_rows

    for s in range(MAX_SLOTS):
        ct.clear(s)
    ct.check_invariants(MAX_SLOTS)
    assert ct.free_rows == ct.l2.shape[0] - 1


def test_chained_tables_reject_overlong_row():
    from repro.serving.paging import ChainedTables

    ct = ChainedTables(2, 2, 4)
    with pytest.raises(ValueError, match="chained capacity"):
        ct.set_row(0, list(range(1, 10)))
    ct.check_invariants(2)
    assert ct.free_rows == ct.l2.shape[0] - 1     # failed set leaks nothing
