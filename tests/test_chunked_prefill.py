"""Chunked prefill in the step loop (the PREFILLING slot state).

Covers the invariants the chunked admission state machine must not break:
greedy-token parity with whole-prompt prefill on both engines across mixer
families (incl. preemption-resume mid-prefill), the bounded-compilation
contract (chunk shapes reuse the bucket geometry, bound unchanged), the
decode-stall regression the feature exists for (active slots emit a token
on EVERY loop iteration while a max-length prompt is chunk-prefilling,
chunk work budget-gated), the capacity exports the placer consumes
(``prefilling_slots`` / ``prefill_backlog_tokens``), and the two satellite
bugfixes riding along: ``EngineLoop.generate`` applies ONE overall deadline
across its waits, and ``Metrics.summary`` exposes ``p99_response_s``."""
import dataclasses
import time

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.paging import num_buckets
from repro.serving.scheduler import EngineLoop

ARCHS = ["smollm-360m", "jamba-1.5-large-398b", "xlstm-350m"]
MAXLEN, PS, CHUNK = 48, 8, 16


def _smoke(arch):
    cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
    if cfg.moe is not None:
        # capacity drops are load-dependent (and chunk-local under chunked
        # prefill); ample capacity => exact greedy either way
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


def _prompts(cfg, lengths, base=0):
    return [
        list(np.random.default_rng(base + i).integers(1, cfg.vocab_size, n))
        for i, n in enumerate(lengths)
    ]


def _dense(cfg, chunk, params=None, new=3, maxlen=MAXLEN, slots=2):
    return InferenceEngine(
        cfg,
        EngineConfig(max_slots=slots, max_len=maxlen, max_new_tokens=new,
                     bucket_unit=PS, chunk_tokens=chunk),
        params=params,
    )


def _paged(cfg, chunk, params=None, new=3, maxlen=MAXLEN, slots=2, pool_pages=None, ps=PS):
    if pool_pages is None:
        pool_pages = 2 * maxlen // ps
    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=ps, num_pages=1 + pool_pages, max_slots=slots,
                          max_seq_len=maxlen, max_new_tokens=new, chunk_tokens=chunk),
        params=params,
    )


# ---------------------------------------------------------------------------
# Greedy parity: chunking must not change a single token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_chunked_matches_unchunked_greedy(arch):
    """Mixed prompt lengths (sub-chunk, multi-chunk, ragged tail, max-ish)
    through chunked engines produce exactly the whole-prompt-prefill tokens
    on BOTH engines — attention offsets, recurrent carry hand-off and the
    final-chunk token emission are all exact."""
    cfg = _smoke(arch)
    prompts = _prompts(cfg, [5, CHUNK, CHUNK + 7, 40])
    ref = _dense(cfg, chunk=0)
    base = [s.out for s in ref.generate(prompts)]
    got_d = [s.out for s in _dense(cfg, CHUNK, ref.params).generate(prompts)]
    assert got_d == base, "dense chunked prefill diverged from whole-prompt prefill"
    eng_p = _paged(cfg, CHUNK, ref.params)
    got_p = [s.out for s in eng_p.generate(prompts)]
    assert got_p == base, "paged chunked prefill diverged from whole-prompt prefill"
    eng_p.allocator.check_invariants()
    assert eng_p.allocator.free_pages == eng_p.pcfg.num_pages - 1
    assert all(not c for c in eng_p._chunking) and all(x is None for x in eng_p._chunk_carry)


def test_chunked_preemption_resume_mid_prefill():
    """A PREFILLING sequence is a preemption candidate like any occupant: a
    growing decoder that runs the pool dry evicts it MID-prefill (chunk
    progress and carry dropped, pages released); on re-admission the chunked
    prefill restarts from scratch and still reproduces the exact greedy
    continuation."""
    cfg = _smoke("smollm-360m")
    ps, maxlen, chunk, new = 4, 32, 4, 8
    short, long_p = _prompts(cfg, [3, 20])
    ref = _paged(cfg, 0, new=new, maxlen=maxlen, ps=ps)
    base_short = ref.generate([short])[0].out
    base_long = _paged(cfg, 0, ref.params, new=new, maxlen=maxlen, ps=ps).generate(
        [long_p]
    )[0].out

    # 8 usable pages: short (grows to 3) + long (needs 6) collide mid-prefill
    eng = _paged(cfg, chunk, ref.params, new=new, maxlen=maxlen, ps=ps, pool_pages=8)
    sid_s = eng.submit(short)
    for _ in range(2):
        eng.step()
    sid_l = eng.submit(long_p)
    done, evicted_mid_prefill = {}, False
    for _ in range(200):
        chunking, pos = list(eng._chunking), eng._chunk_pos.copy()
        for s in eng.step():
            done[s.sid] = s
        for i in range(2):
            if chunking[i] and pos[i] > 0 and not eng._chunking[i] and eng.slot_seq[i] is None:
                evicted_mid_prefill = True          # progress discarded, slot freed
        if len(done) == 2:
            break
    assert len(done) == 2, "sequences did not finish after preemption"
    assert done[sid_l].preemptions >= 1, "the long sequence was never preempted"
    assert evicted_mid_prefill, "preemption never hit the sequence MID-prefill"
    assert done[sid_s].out == base_short
    assert done[sid_l].out == base_long, "resume after mid-prefill preemption diverged"
    eng.allocator.check_invariants()
    assert eng.allocator.free_pages == eng.pcfg.num_pages - 1


# ---------------------------------------------------------------------------
# Bounded compilation: chunk shapes reuse the bucket geometry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_chunked_compile_count_bound_unchanged(kind):
    """Serving many distinct prompt lengths through a chunked engine
    compiles at most num_buckets(unit, chunk_tokens) prefill shapes — the
    PR 2 bound, only with the cap shrunk to the chunk size (offsets and
    chunk cursors are dynamic, never shapes)."""
    cfg = _smoke("smollm-360m")
    eng = _dense(cfg, CHUNK, new=2) if kind == "dense" else _paged(cfg, CHUNK, new=2)
    bound = num_buckets(PS, CHUNK)
    assert eng.total_buckets == bound
    for n in range(1, 42, 4):                     # sub-chunk through multi-chunk
        eng.generate([_prompts(cfg, [n], base=n)[0]])
    assert eng.compile_events <= bound, (eng.compile_events, bound)


def test_chunked_ragged_tail_without_bucketing():
    """bucket_prefill=False: full chunks stay chunk-sized but the tail chunk
    is ragged (paged: jnp-ref scatter fallback) — tokens still exact."""
    cfg = _smoke("smollm-360m")
    prompts = _prompts(cfg, [CHUNK + 5])
    ref = _dense(cfg, 0)
    base = [s.out for s in ref.generate(prompts)]
    eng = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS, max_slots=2,
                          max_seq_len=MAXLEN, max_new_tokens=3, chunk_tokens=CHUNK,
                          bucket_prefill=False),
        params=ref.params,
    )
    assert [s.out for s in eng.generate(prompts)] == base


def test_dense_chunk_must_divide_cap():
    cfg = _smoke("smollm-360m")
    with pytest.raises(ValueError, match="must divide"):
        _dense(cfg, chunk=32, maxlen=MAXLEN)      # 48 % 32 != 0


# ---------------------------------------------------------------------------
# The decode-stall regression the feature exists for
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_active_slots_decode_every_step_during_long_prefill(kind):
    """While a max-length prompt is chunk-prefilling, the already-decoding
    slot emits a token on EVERY loop iteration, the prefill spans multiple
    iterations (absorbed, not swallowed whole), per-step chunk work respects
    the token budget, and both outputs equal the whole-prefill baseline."""
    cfg = _smoke("smollm-360m")
    new = 12
    short, long_p = _prompts(cfg, [4, MAXLEN - new - 1])
    ref = _dense(cfg, 0, new=new)
    base_short = ref.generate([short])[0].out
    base_long = _dense(cfg, 0, ref.params, new=new).generate([long_p])[0].out

    eng = (_dense if kind == "dense" else _paged)(cfg, CHUNK, ref.params, new=new)
    loop = EngineLoop(eng)                         # stepped manually
    sid_s = loop.submit(short)
    for _ in range(2):
        loop.step_once()
    seq_s = next(s for s in eng.slot_seq if s is not None and s.sid == sid_s)
    sid_l = loop.submit(long_p)
    budget = eng.step_budget
    done, prefill_steps = {}, 0
    for _ in range(100):
        n_before = len(seq_s.out)
        pos_before = eng._chunk_pos.copy()
        prefilling = any(eng._chunking)
        for s in loop.step_once():
            done[s.sid] = s
        if prefilling or any(eng._chunking):
            prefill_steps += 1
            if sid_s not in done:
                assert len(seq_s.out) == n_before + 1, (
                    "decoding slot stalled during a chunked prefill iteration"
                )
            advanced = int((eng._chunk_pos - pos_before).clip(min=0).sum())
            assert advanced <= budget, (
                f"chunk work ({advanced} tokens) exceeded the step budget {budget}"
            )
        if len(done) == 2:
            break
    assert len(done) == 2
    assert prefill_steps >= (MAXLEN - new - 1) // CHUNK, (
        "the long prefill did not span multiple loop iterations"
    )
    assert done[sid_s].out == base_short
    assert done[sid_l].out == base_long


def test_capacity_exports_prefill_backlog():
    """Engines export prefilling_slots / prefill_backlog_tokens; the
    EngineLoop re-exports them (telemetry.prefill_backlog reads either)."""
    from repro.core.telemetry import prefill_backlog

    cfg = _smoke("smollm-360m")
    eng = _paged(cfg, CHUNK, new=3)
    loop = EngineLoop(eng)                         # not started: deterministic
    long_p = _prompts(cfg, [40])[0]
    sid = loop.submit(long_p)
    snap = loop.capacity_now()
    assert snap["prefill_backlog_tokens"] == len(long_p)   # still queued
    loop.step_once()                               # admit + first chunk(s)
    snap = loop.capacity_now()
    assert snap["prefilling_slots"] == 1
    assert snap["active_slots"] == 0, "a PREFILLING slot is not in the decode batch"
    assert 0 < snap["prefill_backlog_tokens"] < len(long_p)
    assert prefill_backlog(snap) == snap["prefill_backlog_tokens"]
    for _ in range(40):
        loop.step_once()
        if not any(eng._chunking) and all(s is None for s in eng.slot_seq):
            break
    assert loop.capacity_now()["prefill_backlog_tokens"] == 0
    assert len(loop.wait(sid, 0).out) == 3


# ---------------------------------------------------------------------------
# Satellite bugfixes riding along
# ---------------------------------------------------------------------------


def test_loop_generate_single_overall_deadline():
    """generate(prompts, timeout=T) shares ONE deadline across its waits:
    on a never-stepped loop with N prompts it fails after ~T, not ~N*T."""
    cfg = _smoke("smollm-360m")
    eng = _paged(cfg, 0, new=2)
    loop = EngineLoop(eng)                         # never started/stepped
    t0 = time.monotonic()
    with pytest.raises(TimeoutError):
        loop.generate(_prompts(cfg, [3, 3, 3, 3]), timeout=0.3)
    elapsed = time.monotonic() - t0
    assert elapsed < 4 * 0.3, f"deadline multiplied across sids ({elapsed:.2f}s)"


def test_loop_generate_timeout_abandons_unwaited_sids():
    """A generate() batch whose shared deadline expires abandons EVERY sid —
    including the ones never individually waited on — so their eventual
    results are discarded instead of growing the registry forever."""
    cfg = _smoke("smollm-360m")
    eng = _paged(cfg, 0, new=2, slots=1)
    loop = EngineLoop(eng)                         # stepped manually
    with pytest.raises(TimeoutError):
        loop.generate(_prompts(cfg, [3, 3, 3]), timeout=0.0)
    assert not loop._futures, "unwaited sids left futures behind"
    for _ in range(60):                            # let the work finish anyway
        loop.step_once()
        if all(s is None for s in eng.slot_seq) and not eng.waiting:
            break
    assert not loop._futures and not loop._unclaimed and not loop._abandoned
    eng.allocator.check_invariants()


def test_loop_generate_failed_submit_reaps_registered_sids():
    """A batch whose LATER submit is rejected (prompt too long for the
    engine) reaps the sibling futures already registered — the registry
    must not grow when callers retry with corrected prompts."""
    cfg = _smoke("smollm-360m")
    eng = _paged(cfg, 0, new=2, slots=1)
    loop = EngineLoop(eng)                         # stepped manually
    too_long = _prompts(cfg, [MAXLEN])[0]          # prompt + new > max_seq_len
    with pytest.raises(ValueError, match="max_seq_len"):
        loop.generate([_prompts(cfg, [3])[0], too_long])
    for _ in range(30):                            # sibling still runs; result discarded
        loop.step_once()
        if all(s is None for s in eng.slot_seq) and not eng.waiting:
            break
    assert not loop._futures and not loop._unclaimed and not loop._abandoned


def test_metrics_summary_exports_p99():
    from repro.core.telemetry import Metrics, percentile

    class R:
        def __init__(self, rt):
            self.failed, self.response_s, self.tier = False, rt, None

    m = Metrics()
    rts = [float(i) for i in range(1, 101)]
    for rt in rts:
        m.record(R(rt))
    s = m.summary()
    assert s["p99_response_s"] == round(percentile(rts, 99), 4)
    assert s["p95_response_s"] == round(percentile(rts, 95), 4)
