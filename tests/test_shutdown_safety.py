"""Shutdown idempotency + re-entrancy regressions.

The lifecycle contract for every threaded component (MonitorSampler,
EngineLoop, StraightLineRouter, Tracer): ``stop``/``close`` may be called
twice, from several threads at once, or from inside the component's own
worker thread (a probe or callback that tears down its owner), and none of
those may deadlock, double-join, or raise. The pattern under test is
swap-the-handle-under-the-lock, join-outside-the-lock, never-join-yourself.
"""
import threading
import time

import pytest

from repro.core import Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter
from repro.core.telemetry import CapacityGauge, MonitorSampler
from repro.core.tracing import Tracer


def _sampler(interval_s=0.001, probe=None):
    gauge = CapacityGauge()
    gauge.register_stats("FLASK", probe or (lambda: {"free_slots": 1}))
    return MonitorSampler(gauge, interval_s=interval_s)


# ---------------------------------------------------------------------------
# MonitorSampler
# ---------------------------------------------------------------------------


def test_sampler_stop_twice_and_never_started():
    s = _sampler()
    s.stop()                                       # never started: no-op
    s.start()
    assert s.running
    s.stop()
    s.stop()                                       # second stop: no-op, no raise
    assert not s.running


def test_sampler_concurrent_stops_single_join():
    """N racing stops: exactly one swaps the live handle out; every call
    returns without deadlock and the thread is dead afterwards."""
    s = _sampler()
    s.start()
    barrier = threading.Barrier(8)

    def stopper():
        barrier.wait()
        s.stop()

    threads = [threading.Thread(target=stopper) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert not any(t.is_alive() for t in threads)
    assert not s.running


def test_sampler_stop_during_sweep_does_not_deadlock():
    """A stop issued while sample_once holds the ring lock must not join
    under that lock: the probe blocks mid-sweep until the stopper has
    committed to stopping, forcing the historical deadlock interleaving."""
    in_probe = threading.Event()
    release = threading.Event()

    def slow_probe():
        in_probe.set()
        release.wait(10)
        return {"free_slots": 1}

    s = _sampler(probe=slow_probe)
    s.start()
    assert in_probe.wait(10), "sampler never swept"
    stopper = threading.Thread(target=s.stop)
    stopper.start()
    time.sleep(0.05)                               # stop() is past the swap
    release.set()
    stopper.join(10)
    assert not stopper.is_alive() and not s.running


def test_sampler_self_stop_from_probe():
    """A probe that stops its own sampler runs on the sampler thread: stop
    must skip the self-join instead of deadlocking on it."""
    s = _sampler(probe=lambda: s.stop() or {"free_slots": 1})
    s.start()
    deadline = time.monotonic() + 10
    while s.running and time.monotonic() < deadline:
        time.sleep(0.005)
    assert not s.running, "self-stop deadlocked"


def test_sampler_restart_after_stop():
    s = _sampler()
    s.start()
    s.stop()
    s.start()                                      # handle was cleared: restart works
    assert s.running
    s.stop()
    with s:                                        # context manager path too
        assert s.running
    assert not s.running


# ---------------------------------------------------------------------------
# EngineLoop (fake engine: no JAX)
# ---------------------------------------------------------------------------


class _FakeEngine:
    """Just enough surface for EngineLoop's step cycle: no waiting work."""

    waiting = ()
    slot_seq = (None,)

    def loop_stats(self):
        return {}

    def capacity_now(self):
        return {"free_slots": 1}

    def admit_waiting(self):
        return []

    def step_once(self):
        return []

    def submit(self, prompt):
        raise AssertionError("not used")


def _loop():
    from repro.serving.scheduler import EngineLoop

    return EngineLoop(_FakeEngine(), idle_wait_s=0.001)


def test_loop_stop_twice_and_unstarted():
    loop = _loop()
    loop.stop()                                    # never started
    loop.start()
    assert loop.running
    loop.stop()
    loop.stop()
    assert not loop.running


def test_loop_concurrent_stops():
    loop = _loop()
    loop.start()
    barrier = threading.Barrier(6)

    def stopper():
        barrier.wait()
        loop.stop()

    threads = [threading.Thread(target=stopper) for _ in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert not any(t.is_alive() for t in threads)
    assert not loop.running
    loop.start()                                   # restartable after full stop
    loop.stop()


# ---------------------------------------------------------------------------
# StraightLineRouter
# ---------------------------------------------------------------------------


def _router():
    return StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, lambda req: "f", capacity=1),
            Tier.DOCKER: Backend(Tier.DOCKER, lambda req: "d", capacity=1),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: "s", capacity=4),
        },
        policy=StraightLinePolicy(Thresholds(F=1e9, D=1e6)),
    )


def test_router_stop_twice_and_concurrent():
    router = _router()
    router.stop()                                  # never started
    router.start(2)
    router.submit(Request(rid=1, arrival_t=0.0, data_size=100.0, timeout_s=30.0))
    router.drain(timeout=30)
    barrier = threading.Barrier(4)

    def stopper():
        barrier.wait()
        router.stop()

    threads = [threading.Thread(target=stopper) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert not any(t.is_alive() for t in threads)
    assert not router._threads
    assert router.result(1) in {"f", "d", "s"}     # completed before the stops


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_tracer_close_idempotent_and_final():
    tr = Tracer(capacity=8)
    t1 = tr.begin(1)
    tr.finish(t1)
    tr.close()
    tr.close()                                     # second close: no-op
    assert tr.begin(2) is None                     # disabled after close
    assert len(tr) == 1 and tr.traces()[0]["rid"] == 1


def test_tracer_late_finish_after_close_dropped():
    """The losing copy of a hedge race settling after shutdown must not
    grow the ring."""
    tr = Tracer(capacity=8)
    straggler = tr.begin(7)
    tr.close()
    tr.finish(straggler)
    assert len(tr) == 0
    assert not straggler.finished


def test_tracer_concurrent_close_and_finish():
    tr = Tracer(capacity=1024)
    traces = [tr.begin(i) for i in range(200)]
    barrier = threading.Barrier(5)

    def finisher(chunk):
        barrier.wait()
        for t in chunk:
            tr.finish(t)

    def closer():
        barrier.wait()
        tr.close()

    threads = [threading.Thread(target=finisher, args=(traces[i::4],)) for i in range(4)]
    threads.append(threading.Thread(target=closer))
    for t in threads:
        t.start()
    for t in threads:
        t.join(10)
    assert not any(t.is_alive() for t in threads)
    # whatever landed before the close is finished exactly once; the rest
    # were dropped, and the ring only holds finished traces
    assert all(d["rid"] in range(200) for d in tr.traces())
    assert len(tr) == sum(t.finished for t in traces)
