"""MoE: capacity gather/scatter vs dense-all-experts reference (hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_fallback import given, settings, st

from repro.models import MoECfg, ModelConfig
from repro.models.common import init_tree
from repro.models.moe import capacity_for, moe_core, moe_defs, moe_ffn


def make_cfg(E=4, k=2, cf=8.0, d=16, f=32):
    return ModelConfig(
        name="m", family="moe", n_layers=1, d_model=d, n_heads=2, n_kv_heads=2,
        d_ff=f, vocab_size=64, moe=MoECfg(n_experts=E, top_k=k, capacity_factor=cf),
        param_dtype=jnp.float32, compute_dtype=jnp.float32,
    )


def dense_reference(cfg, x_flat, logits, w1, w3, w2):
    """Compute every expert densely, combine by normalized top-k weights."""
    m = cfg.moe
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(probs, m.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    h = jnp.einsum("td,edf->tef", x_flat, w1)
    h = jax.nn.silu(h)
    if w3 is not None:
        h = h * jnp.einsum("td,edf->tef", x_flat, w3)
    y_all = jnp.einsum("tef,efd->ted", h, w2)           # (T, E, d)
    w_te = jnp.zeros(probs.shape).at[
        jnp.arange(x_flat.shape[0])[:, None], topi
    ].add(topv)
    return jnp.einsum("ted,te->td", y_all, w_te)


@given(
    T=st.integers(2, 24),
    E=st.sampled_from([2, 4, 8]),
    k=st.integers(1, 2),
    seed=st.integers(0, 50),
)
@settings(max_examples=25, deadline=None)
def test_capacity_moe_equals_dense_when_capacity_ample(T, E, k, seed):
    cfg = make_cfg(E=E, k=min(k, E), cf=100.0)
    rng = jax.random.PRNGKey(seed)
    p = init_tree(rng, moe_defs(cfg), jnp.float32)
    x = jax.random.normal(rng, (T, cfg.d_model), jnp.float32)
    logits = jnp.einsum("td,de->te", x, p["router"])
    cap = capacity_for(cfg, T)
    out, aux = moe_core(cfg, x, logits, p["w1"], p.get("w3"), p["w2"], 0, cap)
    ref = dense_reference(cfg, x, logits, p["w1"], p.get("w3"), p["w2"])
    assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
    assert float(aux) > 0.0


def test_capacity_truncation_drops_tokens():
    """With capacity 4 and all tokens forced to one expert, extra tokens get
    zero output (standard drop semantics)."""
    cfg = make_cfg(E=2, k=1, cf=1.0)
    rng = jax.random.PRNGKey(0)
    p = init_tree(rng, moe_defs(cfg), jnp.float32)
    T = 16
    x = jax.random.normal(rng, (T, cfg.d_model), jnp.float32)
    logits = jnp.zeros((T, 2)).at[:, 0].set(10.0)      # everyone -> expert 0
    out, _ = moe_core(cfg, x, logits, p["w1"], p.get("w3"), p["w2"], 0, capacity=4)
    nonzero = jnp.sum(jnp.any(out != 0, axis=-1))
    assert int(nonzero) == 4


def test_moe_ffn_layer_interface():
    cfg = make_cfg()
    rng = jax.random.PRNGKey(1)
    p = init_tree(rng, moe_defs(cfg), jnp.float32)
    x = jax.random.normal(rng, (2, 6, cfg.d_model), jnp.float32)
    out, aux = moe_ffn(cfg, None, p, x)
    assert out.shape == x.shape
    assert jnp.isfinite(out).all() and jnp.isfinite(aux)


def test_aux_loss_prefers_balanced_routing():
    cfg = make_cfg(E=4, k=1)
    T, E = 64, 4
    x = jnp.ones((T, cfg.d_model))
    rng = jax.random.PRNGKey(2)
    p = init_tree(rng, moe_defs(cfg), jnp.float32)
    balanced = jnp.tile(jnp.eye(E) * 5.0, (T // E, 1))
    collapsed = jnp.zeros((T, E)).at[:, 0].set(5.0)
    cap = capacity_for(cfg, T)
    _, aux_b = moe_core(cfg, x, balanced, p["w1"], p.get("w3"), p["w2"], 0, cap)
    _, aux_c = moe_core(cfg, x, collapsed, p["w1"], p.get("w3"), p["w2"], 0, cap)
    assert float(aux_b) < float(aux_c)
