"""Observability subsystem tests: lifecycle traces for every request kind,
metrics registry correctness, MonitorSampler consistency under concurrency,
Chrome-trace export, and the read-side thread-safety regressions in
``Metrics`` / ``FrequencyEstimator``.

Router-level trace tests use fake backends (fast, deterministic — the
hedge race reuses the event-controlled idiom from
test_router_concurrency.py); engine-level trace tests drive a real tiny
paged JAX engine so chunk spans / preemption events / token stamps come
from the actual serving path.
"""
import json
import threading
import time

import pytest

from repro.core import Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter
from repro.core.telemetry import (
    CapacityGauge,
    FrequencyEstimator,
    Histogram,
    Metrics,
    MetricsRegistry,
    MonitorSampler,
    log_buckets,
)
from repro.core.tracing import NULL_TRACER, Trace, Tracer


def _policy():
    # F huge: no burst path; D = 1e6: moderate payloads fall through to S_F/S_D
    return StraightLinePolicy(Thresholds(F=1e9, D=1e6))


def _req(rid=0, size=100.0, timeout=60.0):
    return Request(rid=rid, arrival_t=0.0, data_size=size, timeout_s=timeout)


def _router(backends, tracer, **kw):
    return StraightLineRouter(
        backends, policy=_policy(), tracer=tracer, registry=MetricsRegistry(), **kw
    )


def _tiers(flask=None, docker=None, sls=None, **caps):
    return {
        Tier.FLASK: Backend(Tier.FLASK, flask or (lambda r: "f"),
                            capacity=caps.get("flask_cap", 1),
                            queue_cap=caps.get("flask_q", 8)),
        Tier.DOCKER: Backend(Tier.DOCKER, docker or (lambda r: "d"), capacity=2),
        Tier.SERVERLESS: Backend(Tier.SERVERLESS, sls or (lambda r: "s"), capacity=4),
    }


def assert_well_formed(t: dict) -> None:
    """Every span is a real interval inside the trace, and per lane the
    record order is monotone in start time."""
    assert t["spans"], f"trace {t['rid']} has no spans"
    by_lane = {}
    for s in t["spans"]:
        assert s["t1"] >= s["t0"] >= t["t0"] - 1e-9, (t["rid"], s)
        by_lane.setdefault(s["lane"], []).append(s["t0"])
    for lane, starts in by_lane.items():
        assert starts == sorted(starts), f"lane {lane} spans out of order"
    for e in t["events"]:
        assert e["t"] >= t["t0"] - 1e-9, (t["rid"], e)
    names = [s["name"] for s in t["spans"]]
    assert "placement" in names
    p = next(s for s in t["spans"] if s["name"] == "placement")
    assert {"f_t", "flask_free", "docker_free", "tier", "reason"} <= set(p["attrs"])


# ---------------------------------------------------------------------------
# Router lifecycle traces: one test per request kind
# ---------------------------------------------------------------------------


def test_completed_request_trace():
    tracer = Tracer()
    router = _router(_tiers(), tracer)
    with router:
        router.submit(_req(1))
        router.drain(timeout=10)
    [t] = tracer.traces()
    assert_well_formed(t)
    assert t["rid"] == 1 and not t["attrs"]["failed"]
    assert t["attrs"]["tier"] == "FLASK" and t["attrs"]["response_s"] > 0
    names = [s["name"] for s in t["spans"]]
    assert names.count("queue_wait") == 1 and names.count("execute") == 1
    ex = next(s for s in t["spans"] if s["name"] == "execute")
    assert ex["lane"] == "flask" and ex["attrs"]["outcome"] == "ok"
    assert any(e["name"] == "enqueued" for e in t["events"])


def test_failed_request_trace():
    def boom(req):
        raise RuntimeError("down")

    tracer = Tracer()
    # no retry tier to spill to: the error is terminal
    router = _router(_tiers(flask=boom), tracer, retry_on_failure=False)
    with router:
        router.submit(_req(2))
        router.drain(timeout=10)
    [t] = tracer.traces()
    assert_well_formed(t)
    assert t["attrs"]["failed"] and t["attrs"]["fail_reason"] == "error:RuntimeError"
    ex = next(s for s in t["spans"] if s["name"] == "execute")
    assert ex["attrs"]["outcome"] == "error:RuntimeError"
    assert any(e["name"] == "failed" for e in t["events"])


def test_retry_spill_trace_records_both_lanes():
    def flaky(req):
        raise RuntimeError("flake")

    tracer = Tracer()
    router = _router(_tiers(flask=flaky), tracer)
    with router:
        router.submit(_req(3))
        router.drain(timeout=10)
    [t] = tracer.traces()
    assert_well_formed(t)
    assert not t["attrs"]["failed"] and t["attrs"]["tier"] == "SERVERLESS"
    assert any(e["name"] == "retry_spill" for e in t["events"])
    lanes = {s["lane"] for s in t["spans"] if s["name"] == "execute"}
    assert lanes == {"flask", "serverless-retry"}


def test_deflected_request_trace():
    tracer = Tracer()
    tiers = _tiers(flask_q=0)            # flask chosen but cannot even queue
    router = _router(tiers, tracer)
    with router:
        assert router.submit(_req(4)) == Tier.SERVERLESS
        router.drain(timeout=10)
    [t] = tracer.traces()
    assert_well_formed(t)
    d = next(e for e in t["events"] if e["name"] == "deflected")
    assert d["attrs"] == {"from_tier": "FLASK", "to_tier": "SERVERLESS"}
    assert next(s for s in t["spans"] if s["name"] == "placement")["attrs"]["tier"] == "FLASK"
    assert t["attrs"]["tier"] == "SERVERLESS" and not t["attrs"]["failed"]


def test_timed_out_request_trace():
    release = threading.Event()

    def slow(req):
        assert release.wait(30)
        return "f"

    tracer = Tracer()
    router = _router(_tiers(flask=slow), tracer, retry_on_failure=False)
    with router:
        router.submit(_req(5, timeout=5.0))      # occupies the 1 flask worker
        router.submit(_req(6, timeout=0.01))     # queued behind it, expires there
        time.sleep(0.1)
        release.set()
        router.drain(timeout=10)
    t = next(t for t in tracer.traces() if t["rid"] == 6)
    assert_well_formed(t)
    assert t["attrs"]["failed"] and t["attrs"]["fail_reason"] == "timeout-in-queue"
    assert [s["name"] for s in t["spans"] if s["lane"] == "flask"] == ["queue_wait"]


@pytest.mark.parametrize("winner", ["original", "hedge"])
def test_hedged_request_trace_parallel_lanes(winner):
    """Both racing copies record spans on their own lanes in ONE trace, the
    trace finishes exactly once, and the summary reflects the winner."""
    release_flask, release_sls, sls_started = (threading.Event() for _ in range(3))

    def flask_run(req):
        assert release_flask.wait(30)
        return "flask-result"

    def sls_run(req):
        sls_started.set()
        assert release_sls.wait(30)
        return "sls-result"

    tracer = Tracer()
    router = _router(_tiers(flask=flask_run, sls=sls_run), tracer, hedge_after_s=0.01)
    with router:
        router.submit(_req(7))
        assert sls_started.wait(10)
        first, second = (
            (release_flask, release_sls) if winner == "original"
            else (release_sls, release_flask)
        )
        first.set()
        router.result(7, timeout=10)
        second.set()
        router.drain(timeout=10)
        time.sleep(0.1)                  # let the loser's worker record its span
    assert len(tracer) == 1, "hedged request must finish its trace exactly once"
    [t] = tracer.traces()
    assert_well_formed(t)
    assert any(e["name"] == "hedge_fired" for e in t["events"])
    lanes = {s["lane"] for s in t["spans"] if s["name"] == "execute"}
    assert lanes == {"flask", "serverless-hedge"}, "copies must race on parallel lanes"
    expect = "FLASK" if winner == "original" else "SERVERLESS"
    assert t["attrs"]["tier"] == expect and t["attrs"]["hedged"]


def test_tracer_disabled_is_zero_cost_and_ring_bounded():
    assert NULL_TRACER.begin(1) is None
    assert Tracer(enabled=False).begin(1, a=2) is None
    NULL_TRACER.finish(None)             # no-op, no error
    tracer = Tracer(capacity=3)
    for i in range(7):
        tracer.finish(tracer.begin(i))
    assert len(tracer) == 3
    assert [t["rid"] for t in tracer.traces()] == [4, 5, 6]   # oldest evicted
    # finish is exactly-once even when called twice with the same trace
    t = tracer.begin(99)
    tracer.finish(t)
    tracer.finish(t)
    assert [x["rid"] for x in tracer.traces()].count(99) == 1


def test_untraced_router_records_no_trace_but_metrics_still_flow():
    reg = MetricsRegistry()
    router = StraightLineRouter(_tiers(), policy=_policy(), registry=reg)
    with router:
        router.submit(_req(8))
        router.drain(timeout=10)
    assert router.metrics.total == 1
    assert reg.counter("router_requests_total", {"tier": "flask"}).value == 1
    h = reg.histogram("router_queue_wait_seconds", {"tier": "flask"})
    assert h.total == 1                  # metrics are independent of tracing


# ---------------------------------------------------------------------------
# Engine-side traces: chunk spans, preemption, per-token stamps (real JAX)
# ---------------------------------------------------------------------------

MAXLEN, PS, CHUNK, NEW = 48, 8, 16, 4


@pytest.fixture(scope="module")
def smoke_cfg():
    from repro.configs.registry import get_config

    return get_config("smollm-360m", smoke=True).replace(attn_chunk=32)


def test_engine_loop_trace_chunks_tokens_and_latency_histograms(smoke_cfg):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine
    from repro.serving.scheduler import EngineLoop

    eng = PagedInferenceEngine(
        smoke_cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS, max_slots=2,
                          max_seq_len=MAXLEN, max_new_tokens=NEW, chunk_tokens=CHUNK),
    )
    reg = MetricsRegistry()
    tracer = Tracer()
    trace = tracer.begin(0, model="smollm")
    prompt = list(range(1, 2 * CHUNK + 2))           # 33 tokens -> 3 chunks
    with EngineLoop(eng, name="t0", registry=reg) as loop:
        seq = loop.wait(loop.submit(prompt, trace=trace), timeout=120)
    tracer.finish(trace)
    [t] = tracer.traces()
    lane = f"engine-sid{seq.sid}"
    chunks = [s for s in t["spans"] if s["name"] == "prefill_chunk"]
    assert len(chunks) == 3 and all(s["lane"] == lane for s in chunks)
    assert [c["attrs"]["offset"] for c in chunks] == [0, CHUNK, 2 * CHUNK]
    ev = {e["name"] for e in t["events"]}
    assert {"engine_submit", "admitted", "resolved"} <= ev
    # one stamp per emitted token, strictly after the submit stamp, ordered
    times = t["tokens"][lane]
    assert len(times) == len(seq.out) == NEW
    assert times == sorted(times) and times[0] >= seq.submit_t
    # the loop fed the latency histograms, traced or not
    assert reg.histogram("ttft_seconds", {"engine": "t0"}).total == 1
    assert reg.histogram("itl_seconds", {"engine": "t0"}).total == NEW - 1
    assert t["attrs"]["model"] == "smollm"


def test_preemption_resume_trace_events(smoke_cfg):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    # 4 usable pages, two sequences that each grow to 3 pages: the newest
    # gets preempted, resumes (recompute) after the first finishes
    eng = PagedInferenceEngine(
        smoke_cfg,
        PagedEngineConfig(page_size=16, num_pages=5, max_slots=2,
                          max_seq_len=64, max_new_tokens=32),
    )
    tracer = Tracer()
    traces = [tracer.begin(i) for i in range(2)]
    for i, tr in enumerate(traces):
        eng.submit([1 + i] * 5, trace=tr)
    for _ in range(200):
        eng.step()
        if all(s is None for s in eng.slot_seq) and not eng.waiting:
            break
    assert eng.preemptions >= 1
    for tr in traces:
        tracer.finish(tr)
    dicts = tracer.traces()
    preempted = [t for t in dicts
                 if any(e["name"] == "preempted" for e in t["events"])]
    assert preempted, "tight page pool produced no preemption event"
    t = preempted[0]
    resumes = [s for s in t["spans"]
               if s["name"] == "prefill" and s["attrs"].get("resume", 0) >= 1]
    assert resumes, "no resume re-prefill span after preemption"
    ev = next(e for e in t["events"] if e["name"] == "preempted")
    assert ev["attrs"]["preemptions"] >= 1 and ev["attrs"]["n_out"] >= 1


# ---------------------------------------------------------------------------
# Metrics registry: histogram merge, exposition, snapshot
# ---------------------------------------------------------------------------


def test_histogram_merge_correctness():
    a, b = Histogram(), Histogram()
    xs_a = [1e-4, 3e-3, 0.5, 7.0]
    xs_b = [2e-3, 2e-3, 1e9]            # 1e9 overflows into +Inf
    for x in xs_a:
        a.observe(x)
    for x in xs_b:
        b.observe(x)
    merged = Histogram().merge(a).merge(b)
    assert merged.total == len(xs_a) + len(xs_b)
    assert merged.sum == pytest.approx(sum(xs_a) + sum(xs_b))
    assert merged.counts == [x + y for x, y in zip(a.counts, b.counts)]
    assert merged.counts[-1] == 1       # the 1e9 overflow
    assert a.total == len(xs_a)         # merge does not mutate sources
    with pytest.raises(ValueError):
        a.merge(Histogram(bounds=(1.0, 2.0)))


def test_histogram_percentile_and_bounds_semantics():
    h = Histogram(bounds=(0.01, 0.1, 1.0))
    for x in (0.005, 0.05, 0.05, 0.5):
        h.observe(x)
    assert h.percentile(25) == 0.01
    assert h.percentile(75) == 0.1
    assert h.percentile(100) == 1.0
    assert Histogram().percentile(50) != Histogram().percentile(50)   # NaN


def test_registry_prometheus_text_and_merged_view():
    reg = MetricsRegistry()
    reg.counter("reqs_total", {"tier": "flask"}).inc(3)
    reg.gauge("occ", {"tier": "flask"}).set(0.5)
    for tier, v in (("flask", 0.001), ("docker", 0.03), ("docker", 0.3)):
        reg.histogram("lat_seconds", {"tier": tier}).observe(v)
    text = reg.prometheus_text()
    assert '# TYPE reqs_total counter' in text
    assert 'reqs_total{tier="flask"} 3' in text
    assert 'occ{tier="flask"} 0.5' in text
    # cumulative buckets: counts along le= must be non-decreasing, and the
    # +Inf bucket equals _count
    rows = [l for l in text.splitlines() if l.startswith('lat_seconds_bucket{tier="docker"')]
    counts = [int(l.rsplit(" ", 1)[1]) for l in rows]
    assert counts == sorted(counts) and counts[-1] == 2
    assert 'lat_seconds_count{tier="docker"} 2' in text
    merged = reg.merged_histogram("lat_seconds")
    assert merged.total == 3 and reg.merged_histogram("nope") is None
    # same instance comes back for the same (name, labels)
    assert reg.counter("reqs_total", {"tier": "flask"}).value == 3


# ---------------------------------------------------------------------------
# MonitorSampler: time series + windows under concurrent sampling
# ---------------------------------------------------------------------------


def _stats_probe(state):
    def probe():
        return {
            "free_slots": state["free"], "num_slots": 4, "free_pages": state["free"] * 2,
            "waiting": state["q"], "prefill_backlog_tokens": 7,
            "compile_events": 1, "total_buckets": 2,
        }
    return probe


def test_sampler_series_and_prometheus_gauges():
    gauge = CapacityGauge()
    state = {"free": 1, "q": 3}
    gauge.register_stats("docker", _stats_probe(state))
    reg = MetricsRegistry()
    clock_t = [0.0]
    s = MonitorSampler(gauge, interval_s=1.0, registry=reg, clock=lambda: clock_t[0])
    for i in range(5):
        clock_t[0] = float(i)
        s.sample_once()
    assert s.tiers() == ["docker"] and len(s.series("docker")) == 5
    latest = s.latest("docker")
    assert latest == {
        "t": 4.0, "occupancy": 0.75, "free_pages": 2, "free_slots": 1,
        "queue_depth": 3, "prefill_backlog": 7, "warmth": 0.5,
        # no prefix cache or quantized pool on this probe: keys sampled as
        # unknown, exported as no gauge at all (None values never reach the
        # registry)
        "cached_pages": None, "prefix_hit_rate": None,
        "kv_bytes_per_token": None, "kv_cache_dtype": None,
    }
    assert [x["t"] for x in s.window("docker", last_s=2.0)] == [2.0, 3.0, 4.0]
    assert reg.gauge("tier_occupancy", {"tier": "docker"}).value == 0.75
    assert reg.gauge("tier_queue_depth", {"tier": "docker"}).value == 3.0


def test_sampler_concurrent_reads_and_flapping_probe():
    gauge = CapacityGauge()
    state = {"free": 2, "q": 0}
    gauge.register_stats("flask", _stats_probe(state))
    calls = [0]

    def flapping():
        calls[0] += 1
        if calls[0] % 2:
            raise RuntimeError("probe down")
        return {"free_slots": 1, "num_slots": 2}

    gauge.register_stats("elastic", flapping)
    s = MonitorSampler(gauge, interval_s=0.001, capacity=256)
    errors = []

    def reader():
        try:
            for _ in range(300):
                for tier in s.tiers():
                    win = s.window(tier, last_s=0.05)
                    assert all(w["t"] <= s.clock() for w in win)
                    _ = s.series(tier), s.latest(tier)
                state["free"] = (state["free"] + 1) % 4     # mutate under sampling
        except Exception as e:                               # pragma: no cover
            errors.append(e)

    with s:                              # context manager starts/stops the thread
        threads = [threading.Thread(target=reader) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    assert not s.running and s.samples_taken >= len(s.series("flask"))
    assert "flask" in s.tiers()          # flapping elastic never killed the sweep
    assert len(s.series("flask")) <= 256


# ---------------------------------------------------------------------------
# Chrome trace-event export
# ---------------------------------------------------------------------------


def test_chrome_trace_roundtrips_and_names_lanes(tmp_path):
    tracer = Tracer()
    t = tracer.begin(42, model="m")
    t.add_span("placement", 1.0, 1.1)
    t.add_span("execute", 1.2, 2.0, lane="flask", outcome="ok")
    t.add_span("execute", 1.5, 1.9, lane="serverless-hedge", outcome="ok")
    t.event("hedge_fired", t=1.45)
    t.add_tokens("engine-sid3", [1.3, 1.4, 1.6])
    tracer.finish(t, tier="FLASK")
    path = tmp_path / "trace.json"
    tracer.export_chrome(str(path))
    with open(path) as f:
        doc = json.load(f)               # json.loads round-trip
    evs = doc["traceEvents"]
    thread_names = {e["args"]["name"] for e in evs
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    assert {"router", "flask", "serverless-hedge", "engine-sid3"} <= thread_names
    assert {e["args"]["name"] for e in evs if e["ph"] == "M" and e["name"] == "process_name"} \
        == {"request 42"}
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 3 and all(e["dur"] >= 0 and e["pid"] == 42 for e in xs)
    toks = [e for e in evs if e["ph"] == "i" and e["name"] == "token"]
    assert len(toks) == 3 and toks[0]["ts"] == pytest.approx(1.3e6)
    # lanes map to distinct tids within the request's process
    assert len({e["tid"] for e in evs if e["ph"] != "M"}) == 4


def test_trace_derived_latencies():
    t = Trace(0, t0=10.0)
    t.add_tokens("engine-sid0", [10.5, 10.6, 10.8])
    t.add_tokens("engine-sid1", [10.9, 11.0])
    assert t.ttft_s() == pytest.approx(0.5)                 # earliest lane
    assert t.ttft_s("engine-sid1") == pytest.approx(0.9)
    assert sorted(t.itl_s()) == pytest.approx([0.1, 0.1, 0.2])
    assert t.lanes() == ["engine-sid0", "engine-sid1"]
    assert Trace(1).ttft_s() is None and Trace(1).itl_s() == []


# ---------------------------------------------------------------------------
# Read-side thread-safety regressions (satellites 1 and 2)
# ---------------------------------------------------------------------------


def _done_req(rid, failed=False):
    r = _req(rid)
    r.tier = Tier.FLASK
    r.finish_t = 0.5
    r.failed = failed
    return r


def test_metrics_reads_safe_under_concurrent_record():
    m = Metrics()
    stop = threading.Event()
    errors = []

    def writer():
        i = 0
        while not stop.is_set():
            m.record(_done_req(i, failed=(i % 5 == 0)))
            i += 1

    def reader():
        try:
            while not stop.is_set():
                m.response_times()
                m.summary()
                _ = m.total, m.failure_rate
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=writer) for _ in range(2)] + [
        threading.Thread(target=reader) for _ in range(4)
    ]
    for t in threads:
        t.start()
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert m.total == len(m.completed) + len(m.failed)
    s = m.summary()
    assert 0.0 <= s["failure_rate"] <= 1.0


def test_frequency_estimator_safe_under_concurrent_observe_and_read():
    est = FrequencyEstimator(window_s=0.05)
    stop = threading.Event()
    errors = []

    def observer():
        while not stop.is_set():
            est.observe(time.monotonic())

    def reader():
        try:
            while not stop.is_set():
                f = est.frequency(time.monotonic())
                assert f >= 0.0
        except Exception as e:
            errors.append(e)

    threads = [threading.Thread(target=observer) for _ in range(2)] + [
        threading.Thread(target=reader) for _ in range(4)
    ]
    for t in threads:
        t.start()
    time.sleep(0.3)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors
    assert est.frequency(time.monotonic()) >= 0.0
