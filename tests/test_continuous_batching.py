"""Continuous-batching step loop (serving/scheduler.py EngineLoop).

Covers the invariants the shared step loop must not break: token parity
with the serialized ``generate`` baseline (batching must not change greedy
outputs), conservation + exactly-once through the router's two-phase
``submit_fn``/``wait_fn`` execution path under submitter threads x engines
(mirroring tests/test_router_concurrency.py), fairness (no admitted
sequence starves while later arrivals finish), and a deterministic
admit-during-step interleaving test (a sequence submitted while a batched
step is in flight is admitted at the next step and still decodes exactly)."""
import threading
import time

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core import Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.scheduler import EngineLoop

PROMPT, NEW, MAXLEN, PS = 5, 4, 64, 16


@pytest.fixture(scope="module")
def cfg():
    return get_config("smollm-360m", smoke=True).replace(attn_chunk=64)


def _paged(cfg, slots=2, pools=2, new=NEW):
    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + pools * MAXLEN // PS,
                          max_slots=slots, max_seq_len=MAXLEN, max_new_tokens=new),
    )


def _prompts(cfg, n, base=0):
    return [
        list(np.random.default_rng(base + i).integers(1, cfg.vocab_size, PROMPT))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Parity: the step loop batches, it must not change tokens
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged"])
def test_loop_matches_serialized_generate(cfg, kind):
    """Concurrent submitters through one EngineLoop produce exactly the
    tokens the serialized lock-holding generate produces."""
    if kind == "dense":
        eng = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=MAXLEN, max_new_tokens=NEW))
    else:
        eng = _paged(cfg)
    prompts = _prompts(cfg, 5)
    base = [s.out for s in eng.generate(prompts)]
    outs = [None] * len(prompts)
    with EngineLoop(eng) as loop:
        def worker(i):
            outs[i] = loop.wait(loop.submit(prompts[i]), 120).out

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(prompts))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert outs == base
    assert all(s is None for s in eng.slot_seq)
    if kind == "paged":
        eng.allocator.check_invariants()
        assert eng.allocator.free_pages == eng.pcfg.num_pages - 1


def test_loop_generate_is_drop_in(cfg):
    eng = _paged(cfg)
    prompts = _prompts(cfg, 3)
    base = [s.out for s in eng.generate(prompts)]
    with EngineLoop(eng) as loop:
        got = [s.out for s in loop.generate(prompts, timeout=120)]
    assert got == base


def test_loop_capacity_exports_occupancy(cfg):
    """The loop's capacity_now() adds the occupancy/queue gauges telemetry
    consumes (active_slots, batch_occupancy, queue_depth, loop_steps)."""
    from repro.core.telemetry import batch_occupancy, queue_depth

    eng = _paged(cfg)
    loop = EngineLoop(eng)                         # not started: deterministic
    snap = loop.capacity_now()
    assert snap["active_slots"] == 0 and snap["batch_occupancy"] == 0.0
    assert batch_occupancy(snap) == 0.0 and queue_depth(snap) == 0
    sids = [loop.submit(p) for p in _prompts(cfg, 3)]
    assert loop.capacity_now()["queue_depth"] == 3
    loop.step_once()                               # admits 2 (slots), decodes
    snap = loop.capacity_now()
    assert snap["active_slots"] == 2 and snap["batch_occupancy"] == 1.0
    assert snap["queue_depth"] == 1 and snap["loop_steps"] == 1
    for _ in range(40):
        loop.step_once()
        if all(loop.engine.slot_seq[i] is None for i in range(2)) and not loop.engine.waiting:
            break
    for sid in sids:
        assert len(loop.wait(sid, 0).out) == NEW


# ---------------------------------------------------------------------------
# Fairness: FIFO admission, every active slot advances every step
# ---------------------------------------------------------------------------


def test_fairness_no_admitted_sequence_starves(cfg):
    """Under continuous submission pressure, sequences finish in submission
    order (equal lengths) and each finishes within a bounded number of steps
    of its admission — later arrivals can never starve an earlier one."""
    eng = _paged(cfg, slots=2)
    loop = EngineLoop(eng)                         # stepped manually
    prompts = _prompts(cfg, 8)
    finish_step = {}
    sids = [loop.submit(prompts[0]), loop.submit(prompts[1])]
    next_i = 2
    for step in range(100):
        # keep the queue pressurized: one new arrival per step
        if next_i < len(prompts):
            sids.append(loop.submit(prompts[next_i]))
            next_i += 1
        for seq in loop.step_once():
            finish_step[seq.sid] = step
        if len(finish_step) == len(prompts):
            break
    assert len(finish_step) == len(prompts), "a sequence never finished (starved)"
    order = [sid for sid, _ in sorted(finish_step.items(), key=lambda kv: (kv[1], kv[0]))]
    assert order == sids, "equal-length sequences must finish in submission order"
    # bounded latency: with 2 slots and NEW tokens each, a sequence waits at
    # most ceil(queue_ahead / slots) generations before admission
    waves = -(-len(prompts) // 2)
    assert max(finish_step.values()) <= waves * (NEW + 2), "tail latency unbounded"


# ---------------------------------------------------------------------------
# Deterministic admit-during-step interleaving
# ---------------------------------------------------------------------------


def test_admit_during_step_interleaves_next_step(cfg):
    """A sequence submitted while a batched step is IN FLIGHT is admitted at
    the next step, joins the live decode batch, and still produces exactly
    its serialized tokens. The step entry blocks on a test-controlled event
    (before the engine lock), so the interleaving is deterministic."""
    eng = _paged(cfg, slots=2, new=8)
    prompts = _prompts(cfg, 2, base=40)
    expect = [s.out for s in eng.generate(prompts)]

    orig_step = eng.step
    entered, release = threading.Event(), threading.Event()

    def gated_step():
        entered.set()
        assert release.wait(30)
        return orig_step()

    eng.step = gated_step
    eng.peak_active = 0
    loop = EngineLoop(eng).start()
    try:
        sid0 = loop.submit(prompts[0])
        assert entered.wait(10), "step loop never woke for the first submit"
        sid1 = loop.submit(prompts[1])     # lands while step 1 is in flight
        eng.step = orig_step               # only the first step is gated
        release.set()
        out0 = loop.wait(sid0, 60).out
        out1 = loop.wait(sid1, 60).out
    finally:
        release.set()
        loop.stop()
    assert [out0, out1] == expect
    assert eng.peak_active == 2, "late submit was not interleaved into the batch"


def test_timed_out_wait_abandons_future_without_leaking(cfg):
    """A wait that times out reaps its future immediately and the sequence's
    eventual result is discarded — timed-out requests must not grow the
    loop's registry without bound (long-running service leak regression)."""
    eng = _paged(cfg, slots=1)
    loop = EngineLoop(eng)                         # manual stepping
    sid = loop.submit(_prompts(cfg, 1)[0])
    with pytest.raises(TimeoutError):
        loop.wait(sid, 0.0)                        # nothing stepped yet
    assert sid not in loop._futures and sid in loop._abandoned
    with pytest.raises(KeyError):
        loop.wait(sid, 0.0)                        # abandoned == unknown
    for _ in range(30):
        loop.step_once()
        if all(s is None for s in eng.slot_seq) and not eng.waiting:
            break
    assert not loop._futures and not loop._unclaimed and not loop._abandoned
    eng.allocator.check_invariants()


def test_stop_unblocks_waiters_and_poisoned_loop_rejects(cfg):
    eng = _paged(cfg, slots=1, new=8)

    def boom():
        raise RuntimeError("device on fire")

    prompts = _prompts(cfg, 1)
    loop = EngineLoop(eng).start()
    sid = loop.submit(prompts[0])
    eng.step = boom
    with pytest.raises(RuntimeError, match="engine loop failed"):
        loop.wait(sid, 10)
    with pytest.raises(RuntimeError, match="engine loop failed"):
        loop.submit(prompts[0])
    loop.stop()


# ---------------------------------------------------------------------------
# Router soak through the two-phase submit_fn/wait_fn path
# ---------------------------------------------------------------------------


def test_soak_router_step_loop_conservation_exactly_once(cfg):
    """Submitter threads x engine loops through the router's two-phase
    execution path, hedging enabled: conservation and exactly-once metrics
    hold, every result carries the exact serialized-engine tokens, and the
    engines drain completely."""
    engines = {
        Tier.FLASK: _paged(cfg, slots=1),
        Tier.DOCKER: _paged(cfg, slots=2),
        Tier.SERVERLESS: _paged(cfg, slots=2),
    }
    for eng in engines.values():
        eng.prewarm()
    loops = {t: EngineLoop(e).start() for t, e in engines.items()}

    def prompt_for(rid):
        return list(np.random.default_rng(rid).integers(1, cfg.vocab_size, PROMPT))

    def backend(tier, loop, capacity, eng):
        return Backend(
            tier,
            run=lambda req: loop.wait(loop.submit(prompt_for(req.rid)), 120).out,
            capacity=capacity, queue_cap=64,
            capacity_fn=lambda: eng.admission_capacity(PROMPT + NEW),
            stats_fn=loop.capacity_now,
            submit_fn=lambda req: loop.submit(prompt_for(req.rid)),
            wait_fn=lambda sid, timeout: loop.wait(sid, timeout).out,
        )

    router = StraightLineRouter(
        {
            Tier.FLASK: backend(Tier.FLASK, loops[Tier.FLASK], 1, engines[Tier.FLASK]),
            Tier.DOCKER: backend(Tier.DOCKER, loops[Tier.DOCKER], 2, engines[Tier.DOCKER]),
            Tier.SERVERLESS: backend(
                Tier.SERVERLESS, loops[Tier.SERVERLESS], 2, engines[Tier.SERVERLESS]
            ),
        },
        policy=StraightLinePolicy(Thresholds(F=1e9, D=1e6)),
        hedge_after_s=0.05,
        results_cap=256,
    )
    router.start(2)
    submitted, sub_lock = [], threading.Lock()

    def submitter(base):
        for i in range(6):
            rid = base + i
            router.submit(Request(rid=rid, arrival_t=0.0, data_size=100.0, timeout_s=120.0))
            with sub_lock:
                submitted.append(rid)

    threads = [threading.Thread(target=submitter, args=(k * 100,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    router.drain(timeout=120)
    router.stop()
    for loop in loops.values():
        loop.stop()

    m = router.metrics
    recorded = [r.rid for r in m.completed + m.failed]
    assert m.total == len(submitted)
    assert len(recorded) == len(set(recorded)), "a request recorded metrics twice"
    assert set(recorded) == set(submitted), "lost or invented rids"
    assert not m.failed, [r.fail_reason for r in m.failed]
    # spot-check real tokens: exactly what a lone serialized engine produces
    probe = _paged(cfg, slots=1)
    expect = probe.generate([prompt_for(submitted[0])])[0].out
    assert router.result(submitted[0], timeout=5) == expect
    for rid in submitted[1:]:
        assert len(router.result(rid, timeout=5)) == NEW
    for eng in engines.values():
        assert all(s is None for s in eng.slot_seq)
        eng.allocator.check_invariants()
        assert eng.allocator.free_pages == eng.pcfg.num_pages - 1


# ---------------------------------------------------------------------------
# Lifecycle registries (PR 8 satellites): stop() hygiene + snapshot clamping
# ---------------------------------------------------------------------------


def test_stop_clears_unclaimed_and_abandoned_registries(cfg):
    """Regression: stop() failed pending futures but left ``_unclaimed``
    results and ``_abandoned`` sids behind, so a stopped-then-restarted
    loop carried orphaned registry state forever. Both must be cleared —
    nothing will ever claim them once their waiters are gone."""
    eng = _paged(cfg, slots=1, new=2)
    loop = EngineLoop(eng)                         # manual stepping
    direct = eng.submit(_prompts(cfg, 1)[0])       # no future: loop can't hand it off
    for _ in range(30):
        loop.step_once()
        if all(s is None for s in eng.slot_seq) and not eng.waiting:
            break
    assert direct in loop._unclaimed
    sid = loop.submit(_prompts(cfg, 1, base=7)[0])
    with pytest.raises(TimeoutError):
        loop.wait(sid, 0.0)                        # abandons: never stepped again
    assert sid in loop._abandoned
    loop.stop()
    assert not loop._unclaimed and not loop._abandoned and not loop._futures
    loop.start()                                   # restart begins with a clean registry
    assert not loop._unclaimed and not loop._abandoned
    loop.stop()


def test_capacity_now_clamps_sparse_engine_snapshots(cfg):
    """Regression: ``capacity_now`` read ``num_slots`` with a different
    default at each use, so a sparse snapshot (an engine exporting
    ``free_slots`` but not ``num_slots``, or neither) produced a negative
    active-slot count. One default, clamped once: occupancy is always in
    [0, 1] and ``active_slots`` never negative."""

    class _Stub:
        def __init__(self, snap):
            self._snap = snap

        def capacity_now(self):
            return dict(self._snap)

    for snap in ({}, {"free_slots": 5}, {"num_slots": 4},
                 {"num_slots": 2, "free_slots": 9},
                 {"free_slots": 0, "prefilling_slots": 3}):
        out = EngineLoop(_Stub(snap)).capacity_now()
        assert out["active_slots"] >= 0, snap
        assert 0.0 <= out["batch_occupancy"] <= 1.0, snap
    full = EngineLoop(_Stub({"num_slots": 4, "free_slots": 1,
                             "prefilling_slots": 1})).capacity_now()
    assert full["active_slots"] == 2 and full["batch_occupancy"] == 0.5
