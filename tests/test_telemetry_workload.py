"""Telemetry, workload generators, HLO analyzer, estimator, router."""
import numpy as np
import pytest
from hypothesis_fallback import given, settings, st

from repro.core.telemetry import FrequencyEstimator, Metrics, percentile
from repro.core.workload import burst, poisson, ramp


def test_window_frequency_counts_exactly():
    fe = FrequencyEstimator(window_s=10.0)
    for t in [0.0, 1.0, 2.0, 3.0]:
        fe.observe(t)
    assert fe.frequency(3.0) == 4
    assert fe.frequency(11.5) == 2   # window (1.5, 11.5]: observations 2,3 remain
    assert fe.frequency(20.0) == 0


def test_ewma_tracks_rate_changes():
    fe = FrequencyEstimator(window_s=1.0, mode="ewma", halflife_s=1.0)
    t = 0.0
    for _ in range(200):     # 10 rps
        t += 0.1
        fe.observe(t)
    slow = fe.frequency(t)
    for _ in range(400):     # 100 rps
        t += 0.01
        fe.observe(t)
    fast = fe.frequency(t)
    assert fast > 3 * slow


@given(st.lists(st.floats(0, 100), min_size=1, max_size=50))
@settings(max_examples=30, deadline=None)
def test_percentile_bounds(xs):
    assert min(xs) <= percentile(xs, 50) <= max(xs)
    assert percentile(xs, 100) == max(xs)


def test_ramp_properties():
    reqs = ramp(1000, duration_s=180.0, seed=0)
    assert len(reqs) == 1000
    ts = [r.arrival_t for r in reqs]
    assert ts == sorted(ts) and 0 <= ts[0] and ts[-1] <= 180.0
    # linearly increasing rate: second half has more arrivals than first
    first = sum(1 for t in ts if t < 90)
    assert first < 450


def test_poisson_rate_roughly_matches():
    reqs = poisson(50.0, duration_s=100.0, seed=1)
    assert 4000 < len(reqs) < 6000


def test_burst_shape():
    reqs = burst(1.0, 100.0, burst_at_s=50, burst_len_s=10, seed=2)
    in_burst = sum(1 for r in reqs if 50 <= r.arrival_t <= 60)
    out_burst = len(reqs) - in_burst
    assert in_burst > 3 * out_burst


def test_estimator_monotonicity():
    from repro.core.estimator import LatencyEstimator, SliceProfile, xception_profile

    app = xception_profile()
    s1 = SliceProfile(chips=1)
    s8 = SliceProfile(chips=8)
    t1 = LatencyEstimator.service_time(app, 4.0, s1)
    t8 = LatencyEstimator.service_time(app, 4.0, s8)
    assert t8 < t1
    assert LatencyEstimator.service_time(app, 8.0, s1) > t1
    assert LatencyEstimator.cold_start(app, s1) > 0.5   # ~110 MB at 150 MB/s


def test_estimator_reads_dryrun_records():
    from repro.core.estimator import LatencyEstimator

    est = LatencyEstimator("benchmarks/results/dryrun")
    t = est.step_time("glm4-9b", "decode_32k")
    if t is not None:          # present once the sweep has run
        assert 0 < t < 10


def test_router_online_with_fake_clock():
    from repro.core.request import Request, Tier
    from repro.core.router import Backend, StraightLineRouter

    now = [0.0]
    clock = lambda: now[0]
    calls = {"f": 0, "d": 0, "s": 0}

    def mk(key):
        def run(req):
            calls[key] += 1
            now[0] += 0.01
            return f"{key}:{req.rid}"
        return run

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, mk("f"), capacity=1),
            Tier.DOCKER: Backend(Tier.DOCKER, mk("d"), capacity=2),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, mk("s"), capacity=100),
        },
        clock=clock,
    )
    for i in range(5):
        router.submit(Request(rid=i, arrival_t=0.0, data_size=1e5))
        now[0] += 0.05
    router.drain()
    assert router.metrics.total == 5 and router.metrics.failure_rate == 0.0
    assert len(router.results) == 5
    assert calls["f"] > 0


def test_router_retries_failed_tier_on_elastic():
    from repro.core.request import Request, Tier
    from repro.core.router import Backend, StraightLineRouter

    now = [0.0]

    def boom(req):
        raise RuntimeError("tier down")

    def ok(req):
        return "ok"

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, boom, capacity=1),
            Tier.DOCKER: Backend(Tier.DOCKER, boom, capacity=1),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, ok, capacity=10),
        },
        clock=lambda: now[0],
    )
    router.submit(Request(rid=0, arrival_t=0.0, data_size=1e5))
    router.drain()
    assert router.metrics.failure_rate == 0.0   # failover saved it
    assert router.results[0] == "ok"


def test_hlo_analyzer_on_scan_program():
    """The trip-count correction: a 8-iteration scan of a matmul must count
    ~8x the flops of its body (cost_analysis alone counts it once)."""
    import jax
    import jax.numpy as jnp

    from repro.launch.hlo_analysis import HloCost

    L, B, D, F = 8, 4, 32, 64

    def model(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x.sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    compiled = jax.jit(model).lower(x, w).compile()
    cost = HloCost(compiled.as_text(), 1).cost()
    analytic = L * 2 * B * D * D
    assert 0.9 * analytic <= cost["flops"] <= 1.2 * analytic, (cost["flops"], analytic)
