"""Runtime lock-order witness (``repro.analysis.witness``).

Unit tests drive the witness wrappers directly (edge recording, inversion
detection, RLock reentrancy, the Condition wait dance); the soak tests
instrument the real runtime's locks and replay the concurrency soaks from
``test_router_concurrency`` / ``test_continuous_batching`` under the
witness, then assert the observed acquisition orders are acyclic on their
own AND when combined with the committed static lock-order graph.

The engine-backed soaks carry "engine" in their names so the fast
``scripts/ci.sh analyze`` gate can deselect them with ``-k "not engine"``
while the full tier-1 run still exercises them.
"""
import threading
import time

import pytest

from repro.analysis.witness import (
    LockWitness,
    base_name,
    instrument_loop,
    instrument_router,
)
from repro.core import Request, StraightLinePolicy, Thresholds, Tier
from repro.core.router import Backend, StraightLineRouter


def static_edges():
    from repro.analysis.__main__ import repo_root, run_all

    _, graph = run_all(repo_root(), ["lockorder"])
    return {(e.src, e.dst) for e in graph.edges}


REENTRANT = {"_EngineBase.lock"}


# ---------------------------------------------------------------------------
# Wrapper unit tests
# ---------------------------------------------------------------------------


def test_nested_acquire_records_edge():
    w = LockWitness()
    a, b = w.wrap("A"), w.wrap("B")
    with a:
        with b:
            pass
    assert w.edge_set() == {("A", "B")}
    w.assert_consistent()                          # one direction: fine


def test_inversion_detected_without_deadlocking():
    """A-under-B and B-under-A observed in sequence (never concurrently, so
    the run itself cannot deadlock) must still fail the consistency check."""
    w = LockWitness()
    a, b = w.wrap("A"), w.wrap("B")
    with a:
        with b:
            pass
    with b:
        with a:
            pass
    with pytest.raises(AssertionError, match="cycle"):
        w.assert_consistent()


def test_rlock_reentry_records_no_self_edge():
    w = LockWitness()
    r = w.wrap("R", reentrant=True)
    with r:
        with r:
            with r:
                pass
    assert w.edge_set() == set()
    w.assert_consistent()


def test_non_reentrant_self_edge_fails():
    w = LockWitness()
    w.on_acquired("L")
    w.on_acquired("L")                             # simulated re-acquire while held
    with pytest.raises(AssertionError, match="re-acquired while held"):
        w.assert_consistent()
    w.assert_consistent(reentrant=["L"])           # declared reentrant: legal


def test_observed_edge_inverting_static_graph_fails():
    w = LockWitness()
    b, a = w.wrap("B"), w.wrap("A")
    with b:
        with a:
            pass
    w.assert_consistent()                          # acyclic on its own
    with pytest.raises(AssertionError, match="static"):
        w.assert_consistent(static_edges={("A", "B")})


def test_instance_suffixes_distinguish_locks_but_strip_for_static():
    w = LockWitness()
    c1, c2 = w.wrap("Backend.cond[FLASK]"), w.wrap("Backend.cond[DOCKER]")
    with c1:
        with c2:
            pass
    with c2:
        with c1:
            pass
    assert base_name("Backend.cond[FLASK]") == "Backend.cond"
    # two instances of one static node taken in both orders is a real
    # ordering hazard: full instance names participate in cycle detection
    with pytest.raises(AssertionError, match="cycle"):
        w.assert_consistent()


def test_condition_wait_releases_and_reacquires_through_witness():
    """Condition.wait's release/re-acquire dance must route through the
    wrapper: while the consumer sleeps in wait() it holds nothing, so a
    producer acquiring other locks records no edge from the condition."""
    w = LockWitness()
    lk = w.wrap("C.cond")
    cond = threading.Condition(lk)
    other = w.wrap("C.other")
    ready = []

    def consumer():
        with cond:
            while not ready:
                cond.wait(5)

    t = threading.Thread(target=consumer)
    t.start()
    time.sleep(0.05)                               # consumer is inside wait()
    with other:                                    # no lock held by this thread
        pass
    with cond:
        ready.append(1)
        cond.notify()
    t.join(5)
    assert not t.is_alive()
    assert w.edge_set() == set()                   # no ordering was ever observed
    w.assert_consistent()


def test_unknown_edges_reports_unpredicted_orderings():
    w = LockWitness()
    with w.wrap("X[1]"):
        with w.wrap("Y"):
            pass
    assert w.unknown_edges({("A", "B")}) == {("X", "Y")}
    assert w.unknown_edges({("X", "Y")}) == set()


# ---------------------------------------------------------------------------
# Router soak under the witness (fake backends: fast, no JAX)
# ---------------------------------------------------------------------------


def test_router_soak_under_witness():
    """The fake-backend router soak from test_router_concurrency, with the
    registry lock and every backend condition witnessed: whatever
    interleavings the workers + hedge monitor produce, the observed lock
    orders must stay consistent with the static graph."""
    w = LockWitness()

    def flask_run(req):
        time.sleep(0.001)
        if req.rid % 7 == 3:
            raise RuntimeError("flask flake")
        return f"f:{req.rid}"

    router = StraightLineRouter(
        {
            Tier.FLASK: Backend(Tier.FLASK, flask_run, capacity=4, queue_cap=400),
            Tier.DOCKER: Backend(Tier.DOCKER, lambda req: f"d:{req.rid}", capacity=4, queue_cap=400),
            Tier.SERVERLESS: Backend(Tier.SERVERLESS, lambda req: f"s:{req.rid}", capacity=8, queue_cap=400),
        },
        policy=StraightLinePolicy(Thresholds(F=1e9, D=1e6)),
        hedge_after_s=0.005,
        results_cap=500,
    )
    instrument_router(router, w)
    router.start(4)

    def submitter(base):
        for i in range(20):
            router.submit(Request(rid=base + i, arrival_t=0.0, data_size=100.0, timeout_s=60.0))

    threads = [threading.Thread(target=submitter, args=(k * 1000,)) for k in range(6)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    router.drain(timeout=60)
    router.stop()

    assert router.metrics.total == 120
    counts = w.acquire_counts()
    assert counts.get("StraightLineRouter._lock", 0) > 0
    assert any(base_name(k) == "Backend.cond" and v > 0 for k, v in counts.items())
    w.assert_consistent(static_edges(), reentrant=REENTRANT)


# ---------------------------------------------------------------------------
# Engine-backed soaks (real JAX engines; deselectable with -k "not engine")
# ---------------------------------------------------------------------------

PROMPT, NEW, MAXLEN, PS = 5, 3, 64, 16


@pytest.fixture(scope="module")
def cfg():
    from repro.configs.registry import get_config

    return get_config("smollm-360m", smoke=True).replace(attn_chunk=64)


def _paged(cfg, prefix_cache=False, params=None):
    from repro.serving.engine import PagedEngineConfig, PagedInferenceEngine

    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=PS, num_pages=1 + 2 * MAXLEN // PS,
                          max_slots=2, max_seq_len=MAXLEN, max_new_tokens=NEW,
                          prefix_cache=prefix_cache),
        params=params,
    )


@pytest.mark.parametrize("prefix_cache", [False, True])
def test_engine_loop_soak_under_witness(cfg, prefix_cache):
    """The continuous-batching soak under the witness: EngineLoop registry
    lock + the engine's coarse step RLock witnessed while submitter threads
    run the admit->resolve cycle — with and without the prefix cache in the
    admission path."""
    import numpy as np

    from repro.serving.scheduler import EngineLoop

    w = LockWitness()
    eng = _paged(cfg, prefix_cache=prefix_cache)
    loop = EngineLoop(eng)
    instrument_loop(loop, w)

    prompts = [
        list(np.random.default_rng(i).integers(1, cfg.vocab_size, PROMPT))
        for i in range(4)
    ]
    prompts.append(list(prompts[0]))               # shared prefix: cache hit path
    outs = [None] * len(prompts)
    with loop:
        def worker(i):
            outs[i] = loop.wait(loop.submit(prompts[i]), 120).out

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(len(prompts))]
        for t in threads:
            t.start()
        for t in threads:
            t.join()

    assert all(len(o) == NEW for o in outs)
    assert outs[4] == outs[0]                      # prefix reuse must not change tokens
    counts = w.acquire_counts()
    assert counts.get("EngineLoop._lock", 0) > 0
    assert counts.get("_EngineBase.lock", 0) > 0
    w.assert_consistent(static_edges(), reentrant=REENTRANT)
    if prefix_cache:
        eng.prefix_cache.check_invariants()
