"""N-gram speculative decoding (PR 8 tentpole): greedy-token parity.

Speculation must be a pure throughput change — the accepted stream IS the
greedy stream, byte for byte, on every covered architecture combination:
dense + paged engines, chunked + unchunked prefill, prefix cache warm and
cold, and across preemption-mid-speculation restarts. A small vocabulary
makes the smoke model's greedy output repetitive (it settles into short
cycles), so the prompt-lookup proposer genuinely fires and every parity
test also asserts ``spec_accepted > 0`` — a proposer that never proposes
would pass parity vacuously.

The page-accounting side (verify-window reservation, rejected-tail trim)
is covered property-style by the ``speculate`` op in
tests/test_paging.py's allocator interleaving harness; here the engines'
end-to-end page hygiene is asserted instead (invariants + fully drained
pool after every run).
"""
import dataclasses

import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.scheduler import EngineLoop

# Small vocab => repetitive greedy output => the n-gram proposer fires.
VOCAB = 24
NEW = 48
MAXLEN = 128
PS = 8

# Prompts with repeated n-grams (the proposer also matches inside prompts)
PROMPTS = [
    [1, 2, 3, 4, 5, 1, 2, 3, 4, 5],
    [7, 8, 9, 7, 8, 9],
    [3, 1, 4, 1, 5, 9, 2, 6],
]


@pytest.fixture(scope="module")
def cfg():
    return get_config("smollm-360m", smoke=True).replace(
        attn_chunk=64, vocab_size=VOCAB
    )


def _dense(cfg, params=None, **kw):
    e = EngineConfig(max_slots=4, max_len=MAXLEN, max_new_tokens=NEW, **kw)
    return InferenceEngine(cfg, e, params=params)


def _paged(cfg, params=None, num_pages=1 + 4 * MAXLEN // PS, **kw):
    e = PagedEngineConfig(page_size=PS, num_pages=num_pages, max_slots=4,
                          max_seq_len=MAXLEN, max_new_tokens=NEW, **kw)
    return PagedInferenceEngine(cfg, e, params=params)


def _outs(seqs):
    return [list(s.out) for s in seqs]


# ---------------------------------------------------------------------------
# Parity: speculation must not change a single token
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["dense", "paged"])
@pytest.mark.parametrize("chunk", [0, 32])
def test_spec_matches_plain_greedy(cfg, kind, chunk):
    """Spec-on and spec-off engines sharing params emit identical tokens,
    with and without chunked prefill, and the speculated run genuinely
    accepts (non-vacuous parity)."""
    make = _dense if kind == "dense" else _paged
    off = make(cfg, chunk_tokens=chunk)
    base = _outs(off.generate(PROMPTS))
    on = make(cfg, params=off.params, chunk_tokens=chunk, spec_tokens=4)
    assert _outs(on.generate(PROMPTS)) == base
    assert on.spec_proposed > 0 and on.spec_accepted > 0
    assert on.tokens_emitted == sum(len(o) for o in base)
    if kind == "paged":
        on.allocator.check_invariants()
        assert on.allocator.used_pages == 0


def test_spec_matches_plain_greedy_prefix_cache_warm_and_cold(cfg):
    """Speculation composes with the prefix cache: the cold pass and the
    warm pass (same prompts resubmitted — prefill skipped from the radix
    tree) both reproduce the spec-off stream, and release-to-cache inserts
    post-rollback tables (invariants hold with pages retained warm)."""
    off = _paged(cfg, prefix_cache=True)
    cold_base = _outs(off.generate(PROMPTS))
    warm_base = _outs(off.generate(PROMPTS))

    on = _paged(cfg, params=off.params, prefix_cache=True, spec_tokens=4)
    assert _outs(on.generate(PROMPTS)) == cold_base
    cold_accepted = on.spec_accepted
    assert cold_accepted > 0
    warm = on.generate(PROMPTS)
    assert _outs(warm) == warm_base
    assert any(s.cached_tokens > 0 for s in warm), "warm pass never hit the cache"
    assert on.spec_accepted > cold_accepted
    on.allocator.check_invariants()
    on.prefix_cache.check_invariants()
    assert on.allocator.used_pages == on.prefix_cache.cached_pages


def test_preemption_mid_speculation_restart_parity(cfg):
    """A sequence preempted while speculating resumes via recompute and —
    because the proposer is deterministic in the context alone — re-emits
    the exact unpreempted continuation. Ample vs tight pools, spec on
    both; the tight pool must actually preempt."""
    prompts = [p[:6] for p in PROMPTS] + [[2, 4, 2, 4, 2, 4]]
    ample = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=81, max_slots=4,
                          max_seq_len=64, max_new_tokens=24, spec_tokens=4),
    )
    a = ample.generate(prompts)
    assert ample.preemptions == 0 and ample.spec_accepted > 0
    tight = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=24, max_slots=4,
                          max_seq_len=64, max_new_tokens=24, spec_tokens=4),
        params=ample.params,
    )
    t = tight.generate(prompts)
    assert tight.preemptions > 0, "tight pool never preempted"
    assert _outs(a) == _outs(t)
    tight.allocator.check_invariants()
    assert tight.allocator.used_pages == 0


def test_spec_through_engine_loop_records_throughput(cfg):
    """The shared step loop is spec-transparent (same tokens as the
    serialized generate) and records the new throughput metrics: the
    tokens-per-step gauge reads >0 and the accepted-run histogram holds
    one observation per verify pass."""
    off = _paged(cfg)
    base = _outs(off.generate(PROMPTS))
    eng = _paged(cfg, params=off.params, spec_tokens=4)
    loop = EngineLoop(eng)                        # manual stepping
    sids = [loop.submit(p) for p in PROMPTS]
    done = {}
    for _ in range(400):
        for s in loop.step_once():
            done[s.sid] = s
        if len(done) == len(sids):
            break
    assert [list(done[sid].out) for sid in sids] == base
    labels = {"engine": loop.name}
    assert loop.registry.gauge("engine_tokens_per_step", labels).value > 0
    hist = loop.registry.histogram("spec_accepted_run", labels)
    assert hist.total > 0, "no verify pass was observed"
    assert hist.sum == float(eng.spec_accepted)   # one observation per verify


# ---------------------------------------------------------------------------
# Config gate + accounting
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["jamba-1.5-large-398b", "xlstm-350m"])
def test_spec_rejects_recurrent_architectures(arch):
    """Verify replays positions statelessly; recurrent mixers carry state a
    rolled-back verify cannot restore — spec_tokens must refuse them."""
    cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
    with pytest.raises(ValueError, match="attention-only"):
        InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, spec_tokens=2))


def test_spec_parity_on_moe_arch():
    """Speculation covers every attention-only decoder, MoE blocks
    included (ample expert capacity => exact greedy, the test_engine
    trick) — parity must hold beyond the dense llama family."""
    moe_cfg = get_config("dbrx-132b", smoke=True).replace(
        attn_chunk=64, vocab_size=VOCAB
    )
    moe_cfg = moe_cfg.replace(
        moe=dataclasses.replace(moe_cfg.moe, capacity_factor=8.0)
    )
    off = _paged(moe_cfg)
    base = _outs(off.generate(PROMPTS))
    on = _paged(moe_cfg, params=off.params, spec_tokens=4)
    assert _outs(on.generate(PROMPTS)) == base
    assert on.spec_accepted > 0
    on.allocator.check_invariants()
    assert on.allocator.used_pages == 0


def test_spec_capacity_snapshot_and_acceptance_helper(cfg):
    """capacity_now exports the speculation counters and the telemetry
    helper derives the acceptance rate from them (None before any
    proposal — no fake 0.0 during warm-up)."""
    from repro.core.telemetry import spec_acceptance

    eng = _paged(cfg, spec_tokens=4)
    snap = eng.capacity_now()
    assert snap["spec_tokens"] == 4
    assert snap["spec_proposed"] == snap["spec_accepted"] == 0
    assert spec_acceptance(snap) is None
    eng.generate(PROMPTS)
    snap = eng.capacity_now()
    rate = spec_acceptance(snap)
    assert rate is not None and 0.0 < rate <= 1.0
    assert snap["tokens_emitted"] == eng.tokens_emitted > 0
