"""Cross-request prefix cache: radix-tree unit tests, engine integration
(token parity warm vs cold, chunk cursor at the match boundary, fork
pinning, mid-prefill preemption re-validation, eviction-before-preemption),
and the capacity/metrics exports."""
import pytest

from repro.configs.registry import get_config
from repro.core import CapacityGauge
from repro.core.telemetry import (
    MetricsRegistry,
    MonitorSampler,
    cached_pages,
    prefix_hit_rate,
    reclaimable_pages,
)
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.paging import BlockAllocator, PageTable
from repro.serving.prefix_cache import PrefixCache


def _smoke(arch="smollm-360m"):
    return get_config(arch, smoke=True).replace(attn_chunk=64)


# ---------------------------------------------------------------------------
# Radix tree over a BlockAllocator (no model)
# ---------------------------------------------------------------------------

PS = 4


def _cache(num_pages=20):
    a = BlockAllocator(num_pages=num_pages, page_size=PS)
    return a, PrefixCache(a, PS)


def test_acquire_miss_insert_then_hit_shares_pages():
    a, pc = _cache()
    toks = list(range(10))                        # 2 full pages + partial
    pages, node, matched = pc.acquire(toks)
    assert (pages, node, matched) == ([], None, 0)
    seq_pages = a.alloc(3)
    assert pc.insert(toks, seq_pages[:2]) == 2    # adopts the seq's 2 full pages
    a.free(seq_pages[2:])                         # partial tail really freed
    assert pc.cached_pages == 2 and a.used_pages == 2
    pages, node, matched = pc.acquire(toks)
    assert matched == 8 and pages == seq_pages[:2]
    assert all(a.ref_count(p) == 2 for p in pages)  # tree + acquirer
    assert node.holders == 1 and pc.evictable_pages() == 0
    pc.release(node)
    a.free(pages)
    assert pc.evictable_pages() == 2
    pc.check_invariants()
    a.check_invariants()


def test_acquire_capped_one_token_short_of_context():
    """A fully-cached context must still leave >= 1 token to prefill — the
    final chunk produces the next-token logits."""
    a, pc = _cache()
    toks = list(range(8))                         # exactly 2 pages
    pages = a.alloc(2)
    pc.insert(toks, pages)
    got, node, matched = pc.acquire(toks)
    assert matched == 4 and len(got) == 1         # (8-1)//4 = 1 page, not 2
    pc.cancel(got, node)
    pc.check_invariants()


def test_insert_splits_node_at_divergence_and_frees_duplicates():
    a, pc = _cache()
    shared = [7] * 8                              # 2 shared full pages
    s1, s2 = shared + [1] * 4, shared + [2] * 4
    p1 = a.alloc(3)
    pc.insert(s1, p1)
    assert len(pc.nodes()) == 1                   # one 3-page run
    p2 = a.alloc(3)
    pc.insert(s2, p2)
    # duplicates of the shared prefix freed, divergent page adopted
    assert pc.cached_pages == 4 and a.used_pages == 4
    nodes = pc.nodes()
    assert len(nodes) == 3                        # split parent + two leaves
    parent = next(n for n in nodes if n.children)
    assert len(parent.pages) == 2 and parent.pages == p1[:2]
    leaf_pages = sorted(p for n in nodes if not n.children for p in n.pages)
    assert leaf_pages == sorted([p1[2], p2[2]])
    # a mid-prefix acquire matches through the split parent only
    got, node, matched = pc.acquire(shared + [9])
    assert matched == 8 and got == p1[:2] and node is parent
    pc.cancel(got, node)
    pc.check_invariants()
    a.check_invariants()


def test_lru_eviction_drops_cold_unpinned_leaves_first():
    a, pc = _cache()
    cold, warm = [1] * 8, [2] * 8
    pc.insert(cold + [0], a.alloc(2))             # 9 tokens: 2 full pages
    pc.insert(warm + [0], a.alloc(2))
    pc.acquire(warm + [9])                        # touches + re-pins warm
    got, node, _ = pc.acquire(cold + [9])         # touch cold LAST...
    pc.cancel(got, node)                          # ...but leave it UNPINNED
    # warm is pinned: despite being older by LRU it must survive
    freed = pc.evict(10)
    assert freed == 2                             # only the cold leaf went
    assert pc.cached_pages == 2 and pc.evictions == 1
    remaining = {tuple(k for k in n.keys[0]) for n in pc.nodes()}
    assert remaining == {(2, 2, 2, 2)}
    pc.check_invariants()
    a.check_invariants()


def test_evict_reports_actually_reclaimed_pages_only():
    """Pages still shared with a live sequence don't return to the free
    list when their tree leaf dies — evict() must not count them."""
    a, pc = _cache()
    toks = [3] * 12
    pc.insert(toks, a.alloc(3))
    got, node, matched = pc.acquire(toks)
    assert matched == 8                           # capped: 2 of 3 pages
    pc.release(node)                              # unpin, but KEEP the shares
    free_before = a.free_pages
    assert pc.evict(3) == 1                       # only the unshared 3rd page
    assert a.free_pages == free_before + 1
    a.free(got)                                   # the "sequence" lets go
    assert a.free_pages == free_before + 3
    pc.check_invariants()
    a.check_invariants()


def test_drop_restores_pool_and_path_pin_counters_balance():
    a, pc = _cache()
    pc.insert([1] * 8 + [0], a.alloc(2))
    pc.insert([1] * 4 + [2] * 4 + [0], a.alloc(2))   # splits the first run
    got, node, _ = pc.acquire([1] * 8 + [9])
    for n in pc.nodes():
        assert (n.holders == 1) == (n in _path(node))
    pc.release(node)
    a.free(got)
    assert pc.evictable_pages() == pc.cached_pages == 3
    assert pc.drop() == 3
    a.check_invariants()
    assert a.used_pages == 0 and pc.cached_pages == 0


def _path(node):
    out = []
    while node is not None and node.parent is not None:
        out.append(node)
        node = node.parent
    return out


# ---------------------------------------------------------------------------
# Engine integration: parity, boundary, fork, preemption, eviction ordering
# ---------------------------------------------------------------------------

SYS = list(range(1, 26))                          # 25-token shared "system prompt"


def _paged(cfg, prefix_cache=True, chunk_tokens=0, num_pages=60, max_new=6,
           page_size=4, max_slots=4, max_seq_len=64, params=None, **kw):
    return PagedInferenceEngine(
        cfg,
        PagedEngineConfig(
            page_size=page_size, num_pages=num_pages, max_slots=max_slots,
            max_seq_len=max_seq_len, max_new_tokens=max_new,
            chunk_tokens=chunk_tokens, prefix_cache=prefix_cache, **kw,
        ),
        params=params,
    )


@pytest.mark.parametrize("chunk_tokens", [0, 8])
def test_warm_prefix_token_parity_and_boundary(chunk_tokens):
    """Greedy outputs with a cached prefix must be identical to cold prefill
    — and the chunk cursor must start at the page-aligned match boundary."""
    cfg = _smoke()
    eng = _paged(cfg, chunk_tokens=chunk_tokens)
    cold = eng.generate([SYS + [30, 31, 32]])[0]
    assert cold.cached_tokens == 0
    warm = eng.generate([SYS + [30, 31, 32]])[0]
    # 28-token context: (28-1)//4 = 6 pages = 24 tokens served from cache
    assert warm.cached_tokens == 24
    assert warm.out == cold.out
    div = eng.generate([SYS + [40]])[0]           # same SYS, different tail
    assert div.cached_tokens == 24                # 26-token ctx: (26-1)//4=6 pages
    eng.prefix_cache.check_invariants()
    eng.allocator.check_invariants()
    # reference: an engine with the cache OFF produces the same tokens
    ref = _paged(cfg, prefix_cache=False, chunk_tokens=chunk_tokens,
                 params=eng.params).generate([SYS + [30, 31, 32]])[0]
    assert ref.out == cold.out


def test_cached_prefix_matches_dense_engine():
    cfg = _smoke()
    dense = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=6))
    d = dense.generate([SYS + [30, 31, 32]])[0]
    eng = _paged(cfg, params=dense.params)
    eng.generate([SYS + [30, 31, 32]])            # populate
    warm = eng.generate([SYS + [30, 31, 32]])[0]
    assert warm.cached_tokens > 0 and warm.out == d.out


def test_release_to_cache_retains_pages_cache_off_frees_them():
    cfg = _smoke()
    on = _paged(cfg)
    on.generate([SYS + [30]])
    assert on.allocator.used_pages == on.prefix_cache.cached_pages > 0
    off = _paged(cfg, prefix_cache=False, params=on.params)
    off.generate([SYS + [30]])
    assert off.allocator.used_pages == 0          # legacy lifecycle unchanged


def test_fork_of_cache_attached_sequence_pins_tree_path():
    """Satellite regression: a fork sharing cache-attached pages must hold
    the tree path too — the source finishing (or being preempted) must not
    leave the clone decoding from evictable pages."""
    cfg = _smoke()
    eng = _paged(cfg, max_new=8)
    eng.generate([SYS + [30, 31, 32]])            # populate the tree
    sid = eng.submit(SYS + [30, 31, 32])
    for _ in range(10):                           # absorb prefill, decode a bit
        eng.step()
        slot = next((i for i, s in enumerate(eng.slot_seq) if s is not None), None)
        if slot is not None and not eng._chunking[slot]:
            break
    node = eng._cache_nodes[slot]
    assert node is not None and node.holders == 1
    csid = eng.fork(sid)
    assert csid is not None
    assert node.holders == 2                      # clone pinned the path
    clone_slot = next(i for i, s in enumerate(eng.slot_seq)
                      if s is not None and s.sid == csid)
    assert eng.slot_seq[clone_slot].cached_tokens == eng.slot_seq[slot].cached_tokens
    # evicting now must not touch the pinned path
    assert eng.prefix_cache.evict(100) == 0 or node.holders == 2
    done = {}
    for _ in range(60):
        for s in eng.step():
            done[s.sid] = s.out
        if len(done) == 2:
            break
    assert done[sid] == done[csid]                # greedy clones identical
    assert node.holders == 0                      # pins balanced on release
    eng.prefix_cache.check_invariants()
    eng.allocator.check_invariants()


def test_mid_prefill_preemption_restarts_at_revalidated_boundary():
    """Satellite regression: preempting a prefix-hit sequence mid-prefill
    must re-match on resume — cursor at the re-validated boundary — and
    still produce the cold-prefill tokens. The eviction variant (cache
    dropped while parked) must degrade to a cold restart, same tokens."""
    cfg = _smoke()
    prompt = SYS + list(range(30, 46))            # 41-token ctx, long fresh tail
    ample = _paged(cfg, chunk_tokens=4, num_pages=80)
    ample.generate([SYS + [99]])                  # populate the shared prefix
    ref = ample.generate([prompt])[0]
    assert ref.cached_tokens == 24                # divergence at the SYS boundary

    for evict_while_parked in (False, True):
        eng = _paged(cfg, chunk_tokens=4, num_pages=80, params=ample.params)
        eng.generate([SYS + [99]])                # populate the tree
        sid = eng.submit(prompt)
        eng.step()                                # admit + first chunk only
        slot = next(i for i, s in enumerate(eng.slot_seq) if s is not None)
        seq = eng.slot_seq[slot]
        assert eng._chunking[slot] and seq.cached_tokens == 24
        assert int(eng._chunk_pos[slot]) >= 24    # cursor began at the boundary
        with eng.lock:                            # deterministic mid-prefill preempt
            eng._preempt_newest([slot])
        assert seq.preemptions == 1
        if evict_while_parked:
            assert eng.prefix_cache.evict(10_000) > 0
            assert eng.prefix_cache.cached_pages == 0
        done = []
        for _ in range(60):
            done += eng.step()
            if done:
                break
        (res,) = done
        assert res.sid == sid and res.out == ref.out
        # boundary re-validated on resume: full re-match normally, cold
        # restart (0) when the cache was evicted under it
        assert res.cached_tokens == (0 if evict_while_parked else 24)
        eng.prefix_cache.check_invariants()
        eng.allocator.check_invariants()


def test_eviction_reclaims_cold_leaves_before_any_preemption():
    """Cached pages are reclaimable capacity: under page pressure the engine
    must drain cold tree leaves and never preempt a live sequence while any
    evictable leaf remains."""
    cfg = _smoke()
    # 15 usable pages of 4 tokens; cache fills with finished sequences (11
    # prompt + 8 output tokens = 4 full pages each), then a burst of fresh
    # (unshared) prompts needs nearly the whole pool
    eng = _paged(cfg, num_pages=16, max_slots=2, max_seq_len=32, max_new=8)
    for t in (50, 60):
        eng.generate([[t] * 11])
    assert eng.prefix_cache.cached_pages == 8
    assert eng.prefix_cache.evictable_pages() == 8
    out = eng.generate([[70 + i] * 11 for i in range(4)])
    assert len(out) == 4 and all(len(s.out) == 8 for s in out)
    assert eng.prefix_cache.evicted_pages_total > 0
    assert eng.preemptions == 0                   # eviction covered the pressure
    eng.prefix_cache.check_invariants()
    eng.allocator.check_invariants()


# ---------------------------------------------------------------------------
# Capacity / metrics exports
# ---------------------------------------------------------------------------


def test_capacity_now_exports_cache_keys_only_when_enabled():
    cfg = _smoke()
    on = _paged(cfg)
    on.generate([SYS + [30]])
    snap = on.capacity_now()
    assert snap["cached_pages"] == on.prefix_cache.cached_pages > 0
    assert snap["evictable_pages"] == snap["cached_pages"]
    assert snap["prefix_hit_rate"] == 0.0 and snap["prefix_cached_tokens"] == 0
    on.generate([SYS + [30]])
    assert on.capacity_now()["prefix_hit_rate"] > 0
    off = _paged(cfg, prefix_cache=False, params=on.params)
    absent = off.capacity_now()
    for key in ("cached_pages", "evictable_pages", "prefix_hit_rate",
                "prefix_cached_tokens"):
        assert key not in absent                  # policy stays byte-faithful
    # telemetry helpers mirror the presence/absence contract
    assert cached_pages(snap) > 0 and cached_pages(absent) is None
    assert prefix_hit_rate(absent) is None
    assert reclaimable_pages(snap) == snap["free_pages"] + snap["evictable_pages"]
    assert reclaimable_pages(absent) == absent["free_pages"]


def test_admission_capacity_counts_evictable_cache_as_free():
    cfg = _smoke()
    eng = _paged(cfg, num_pages=20, max_slots=8, max_seq_len=32, max_new=4)
    for t in (50, 60, 70):
        eng.generate([[t] * 11])
    free = eng.allocator.free_pages
    evictable = eng.prefix_cache.evictable_pages()
    assert evictable > 0
    per_seq = PageTable.pages_needed(12, 4)
    got = eng.admission_capacity(est_tokens=11)
    assert got == min(eng.free_slots(), (free + evictable) // per_seq)
    assert got > free // per_seq                  # the cache widened the view


def test_engine_loop_and_sampler_export_prefix_metrics():
    from repro.serving.scheduler import EngineLoop

    cfg = _smoke()
    eng = _paged(cfg)
    reg = MetricsRegistry()
    loop = EngineLoop(eng, name="paged", registry=reg)
    with loop:
        loop.generate([SYS + [30, 31], SYS + [30, 31]], timeout=120)
        loop.generate([SYS + [30, 31]], timeout=120)
    text = reg.prometheus_text()
    assert 'prefix_matched_tokens_bucket{engine="paged"' in text
    assert 'prefix_cache_hit_ratio{engine="paged"}' in text
    assert reg.counter("prefix_cached_tokens_total", {"engine": "paged"}).value > 0
    hist = reg.merged_histogram("prefix_matched_tokens")
    assert hist.total == 3 and hist.counts[0] >= 1        # misses observe 0
    # the sampler surfaces the cache keys as a per-tier time series
    gauge = CapacityGauge()
    gauge.register_stats("paged", loop.capacity_now)
    sampler = MonitorSampler(gauge, registry=reg)
    sampler.sample_once()
    latest = sampler.latest("paged")
    assert latest["cached_pages"] > 0 and latest["prefix_hit_rate"] > 0
    assert reg.gauge("tier_cached_pages", {"tier": "paged"}).value > 0


def test_prefix_cache_requires_attention_only_decoder():
    for arch in ("jamba-1.5-large-398b", "xlstm-350m"):
        with pytest.raises(ValueError, match="attention-only"):
            _paged(get_config(arch, smoke=True))


def test_hit_ratio_gauge_is_windowed_not_lifetime(cfg=None):
    """Regression (PR 8 satellite): the ``prefix_cache_hit_ratio`` gauge
    exported the cache's lifetime-cumulative ``hit_rate``, which goes inert
    on a long-running engine — millions of old queries drown any behavior
    change. The gauge must report the ratio over the window since its last
    observation; the cumulative counts stay available as counters."""
    from repro.serving.engine import Sequence
    from repro.serving.scheduler import EngineLoop

    class _PC:
        queries = 0
        hits = 0

    class _Eng:
        prefix_cache = _PC()

        def capacity_now(self):
            return {}

    loop = EngineLoop(_Eng(), name="w", registry=MetricsRegistry())
    labels = {"engine": "w"}
    pc = loop.engine.prefix_cache
    seq = Sequence(sid=0, prompt=[1], out=[2])

    pc.queries, pc.hits = 4, 1                    # first window: 1/4 hit
    loop._observe_finished(seq)
    assert loop.registry.gauge("prefix_cache_hit_ratio", labels).value == 0.25

    pc.queries, pc.hits = 8, 5                    # next window: 4 more, ALL hit
    loop._observe_finished(seq)
    # lifetime hit_rate would read 5/8; the windowed gauge reads 4/4
    assert loop.registry.gauge("prefix_cache_hit_ratio", labels).value == 1.0
    assert loop.registry.counter("prefix_cache_queries_total", labels).value == 8
    assert loop.registry.counter("prefix_cache_hits_total", labels).value == 5

    loop._observe_finished(seq)                   # empty window: gauge holds
    assert loop.registry.gauge("prefix_cache_hit_ratio", labels).value == 1.0
