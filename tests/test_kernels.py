"""Pallas kernels vs pure-jnp oracles (interpret=True on CPU): shape/dtype
sweeps per the deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention.kernel import decode_attention_grouped
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.kernel import flash_attention_bhsd
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.mlstm_chunk.ops import mlstm_chunkwise
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.models.common import rmsnorm as rmsnorm_oracle
from repro.models.xlstm import mlstm_sequential

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,H,KV,S,hd,bq",
    [(1, 2, 1, 128, 64, 64), (2, 4, 2, 256, 64, 128), (1, 6, 2, 128, 128, 128), (1, 3, 3, 192, 64, 64)],
)
def test_flash_attention_sweep(B, H, KV, S, hd, bq, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), jnp.float32).astype(dtype)
    out = flash_attention_bhsd(q, k, v, bq=bq, bkv=bq, interpret=True)
    ref = attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < TOL[dtype], err


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,KV,G,T,hd,bt", [(2, 2, 3, 256, 64, 128), (1, 4, 1, 128, 128, 64), (3, 1, 5, 384, 64, 128)]
)
def test_decode_attention_sweep(B, KV, G, T, hd, bt, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, KV, G, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, KV, T, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, KV, T, hd), jnp.float32).astype(dtype)
    lens = jnp.asarray(np.random.default_rng(0).integers(1, T, B), jnp.int32)
    out = decode_attention_grouped(q, k, v, lens, bt=bt, interpret=True)
    ref = decode_attention_ref(q, k, v, lens)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < TOL[dtype], err


@pytest.mark.parametrize(
    "B,S,NH,DH,chunk", [(2, 128, 2, 64, 32), (1, 64, 4, 128, 64), (2, 96, 1, 64, 32)]
)
def test_mlstm_chunk_vs_sequential(B, S, NH, DH, chunk):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    q = jax.random.normal(ks[0], (B, S, NH, DH), jnp.float32) * 0.5
    k = jax.random.normal(ks[1], (B, S, NH, DH), jnp.float32) * 0.5
    v = jax.random.normal(ks[2], (B, S, NH, DH), jnp.float32)
    i = jax.random.normal(ks[3], (B, S, NH), jnp.float32)
    f = jax.random.normal(ks[4], (B, S, NH), jnp.float32) + 2.0
    z = jnp.zeros
    h_k, (C_k, n_k, m_k) = mlstm_chunkwise(
        q, k, v, i, f, z((B, NH, DH, DH)), z((B, NH, DH)), z((B, NH)), chunk=chunk
    )
    h_s, (C_s, n_s, m_s) = mlstm_sequential(
        q, k, v, i, f, z((B, NH, DH, DH)), z((B, NH, DH)), z((B, NH))
    )
    assert float(jnp.max(jnp.abs(h_k - h_s))) < 1e-4
    assert float(jnp.max(jnp.abs(C_k - C_s))) < 1e-3
    assert float(jnp.max(jnp.abs(m_k - m_s))) < 1e-5


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(4, 96, 160), (2, 8, 64), (512, 256)])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(3), shape, jnp.float32).astype(dtype)
    w = jnp.linspace(0.5, 1.5, shape[-1], dtype=jnp.float32)
    out = rmsnorm(x, w)
    ref = rmsnorm_oracle(x, w)
    err = float(jnp.max(jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32))))
    assert err < TOL[dtype], err


def test_model_parity_jnp_vs_pallas_path():
    from repro.configs.registry import get_config
    from repro.models import get_model

    for arch in ("smollm-360m", "xlstm-350m"):
        cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
        model = get_model(cfg)
        modelp = get_model(cfg.replace(use_pallas=True))
        params = model.init(jax.random.PRNGKey(0))
        B, S = 2, 64
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size),
        }
        l0, _ = model.loss(None, params, batch)
        l1, _ = modelp.loss(None, params, batch)
        assert abs(float(l0) - float(l1)) < 1e-3, arch
