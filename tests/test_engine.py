"""Serving engine: continuous batching == teacher-forced greedy decoding."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config
from repro.models.loss import next_tokens
from repro.models.transformer import forward
from repro.serving.engine import EngineConfig, InferenceEngine


@pytest.mark.parametrize("arch", ["smollm-360m", "jamba-1.5-large-398b", "xlstm-350m"])
def test_engine_matches_teacher_forced(arch):
    cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
    if cfg.moe is not None:
        # capacity drops are load-dependent; ample capacity => exact greedy
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    eng = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=5))
    seqs = eng.generate([[1, 2, 3, 4], [5, 6, 7], [9, 10, 11, 12, 13]])
    assert len(seqs) == 3
    for s in seqs:
        ctxt = list(s.prompt)
        for t in range(4):
            h, _, _ = forward(
                cfg, None, eng.params,
                tokens=jnp.asarray([ctxt], jnp.int32),
                positions=jnp.arange(len(ctxt), dtype=jnp.int32)[None, :],
                mode="train",
            )
            nxt = int(next_tokens(cfg, None, eng.params, h)[0])
            assert nxt == s.out[t], (arch, s.sid, t)
            ctxt.append(nxt)


def test_engine_slots_reused_across_waves():
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    eng = InferenceEngine(cfg, EngineConfig(max_slots=2, max_len=32, max_new_tokens=3))
    seqs = eng.generate([[i, i + 1] for i in range(6)])   # 6 prompts, 2 slots
    assert len(seqs) == 6
    assert all(len(s.out) == 3 for s in seqs)


def test_engine_eos_stops_early():
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    eng = InferenceEngine(cfg, EngineConfig(max_slots=1, max_len=32, max_new_tokens=8))
    probe = eng.generate([[1, 2, 3]])[0]
    eos = probe.out[1]
    eng2 = InferenceEngine(
        cfg, EngineConfig(max_slots=1, max_len=32, max_new_tokens=8, eos_id=eos)
    )
    s = eng2.generate([[1, 2, 3]])[0]
    assert s.out[-1] == eos and len(s.out) <= 2
