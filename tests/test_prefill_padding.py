"""Bucketed pad-aware prefill: padded outputs/state match unpadded across
attn/mamba/xlstm mixers, bucketing preserves greedy tokens end-to-end (incl.
preemption-resume), and a sweep of distinct context lengths compiles prefill
at most num_buckets times."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import get_model
from repro.serving.engine import (
    EngineConfig,
    InferenceEngine,
    PagedEngineConfig,
    PagedInferenceEngine,
)
from repro.serving.paging import bucket_tokens, num_buckets

ARCHS = ["smollm-360m", "jamba-1.5-large-398b", "xlstm-350m"]
PROMPT = [3, 1, 4, 1, 5, 9, 2]


def _smoke(arch):
    cfg = get_config(arch, smoke=True).replace(attn_chunk=64)
    if cfg.moe is not None:
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


# ---------------------------------------------------------------------------
# Bucket math
# ---------------------------------------------------------------------------


def test_bucket_tokens_pow2_page_multiples_capped():
    assert bucket_tokens(1, 16, 256) == 16
    assert bucket_tokens(16, 16, 256) == 16
    assert bucket_tokens(17, 16, 256) == 32
    assert bucket_tokens(33, 16, 256) == 64
    assert bucket_tokens(200, 16, 256) == 256
    assert bucket_tokens(90, 16, 96) == 96          # cap need not be pow2*unit
    assert num_buckets(16, 256) == 5                # 16,32,64,128,256
    assert num_buckets(4, 32) == 4                  # 4,8,16,32
    # every achievable bucket for lengths 1..cap is one of num_buckets values
    seen = {bucket_tokens(n, 4, 32) for n in range(1, 33)}
    assert seen == {4, 8, 16, 32}


# ---------------------------------------------------------------------------
# Model-level parity: padded prefill == unpadded prefill
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
def test_padded_prefill_matches_unpadded(arch):
    """Right-padding with n_valid must be invisible: same emitted token, same
    recurrent state (identity pad steps), same valid-prefix KV, and identical
    greedy continuation when decoding from either cache."""
    cfg = _smoke(arch)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = len(PROMPT)
    cap = 32
    tok_u, cache_u = model.prefill(
        None, params, {"tokens": jnp.asarray([PROMPT], jnp.int32)}, cap=cap
    )
    padded = jnp.zeros((1, 16), jnp.int32).at[0, :n].set(jnp.asarray(PROMPT))
    tok_p, cache_p = model.prefill(
        None, params, {"tokens": padded, "n_valid": jnp.asarray([n])}, cap=cap
    )
    assert int(tok_u[0]) == int(tok_p[0])

    for i, kind in enumerate(cfg.block_pattern):
        cu = cache_u["blocks"][f"l{i}_mixer"]
        cp = cache_p["blocks"][f"l{i}_mixer"]
        if kind == "attn":
            for leaf in ("k", "v"):
                a = np.asarray(cu[leaf], np.float32)[:, :, :n]
                b = np.asarray(cp[leaf], np.float32)[:, :, :n]
                np.testing.assert_array_equal(a, b, err_msg=(arch, i, leaf))
        else:
            # recurrent state: pad steps must have been identity
            for leaf in cu:
                a = np.asarray(cu[leaf], np.float32)
                b = np.asarray(cp[leaf], np.float32)
                np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5, err_msg=(arch, i, leaf))

    # greedy continuation from either cache stays token-for-token identical
    lens = jnp.asarray([n], jnp.int32)
    tu, tp, cu, cp = tok_u, tok_p, cache_u, cache_p
    for step in range(3):
        bu = {"token": tu[:, None], "cache_index": lens[0] + step, "lengths": lens + step}
        bp = {"token": tp[:, None], "cache_index": lens[0] + step, "lengths": lens + step}
        tu, cu = model.decode(None, params, cu, bu)
        tp, cp = model.decode(None, params, cp, bp)
        assert int(tu[0]) == int(tp[0]), (arch, step)


def test_padded_prefill_matches_unpadded_moe_binding_capacity():
    """With the DEFAULT (binding) capacity factor, bucket padding must not
    inflate per-expert capacity: the dynamic capacity_for(valid tokens)
    prefix cut keeps dropped-token behavior identical to an unpadded run."""
    cfg = get_config("jamba-1.5-large-398b", smoke=True).replace(attn_chunk=64)
    assert cfg.moe is not None and cfg.moe.capacity_factor < 2.0
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = len(PROMPT)
    tok_u, _ = model.prefill(
        None, params, {"tokens": jnp.asarray([PROMPT], jnp.int32)}, cap=32
    )
    padded = jnp.zeros((1, 16), jnp.int32).at[0, :n].set(jnp.asarray(PROMPT))
    tok_p, _ = model.prefill(
        None, params, {"tokens": padded, "n_valid": jnp.asarray([n])}, cap=32
    )
    assert int(tok_u[0]) == int(tok_p[0])


def test_padded_prefill_matches_unpadded_encdec():
    """The n_valid contract holds for enc-dec too: decoder pads are masked,
    the emitted token comes from the last valid decoder position."""
    cfg = get_config("whisper-large-v3", smoke=True).replace(attn_chunk=64)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = len(PROMPT)
    frames = jax.random.normal(
        jax.random.PRNGKey(1), (1, cfg.encoder.n_ctx, cfg.d_model), cfg.compute_dtype
    )
    tok_u, _ = model.prefill(
        None, params, {"tokens": jnp.asarray([PROMPT], jnp.int32), "frames": frames}, cap=32
    )
    padded = jnp.zeros((1, 16), jnp.int32).at[0, :n].set(jnp.asarray(PROMPT))
    tok_p, _ = model.prefill(
        None, params,
        {"tokens": padded, "frames": frames, "n_valid": jnp.asarray([n])}, cap=32,
    )
    assert int(tok_u[0]) == int(tok_p[0])


# ---------------------------------------------------------------------------
# Engine-level parity: bucketing on == bucketing off
# ---------------------------------------------------------------------------

PROMPTS = [[1, 2, 3, 4], [5, 6, 7], [9, 10, 11, 12, 13], [2, 4]]


@pytest.mark.parametrize("arch", ["smollm-360m", "xlstm-350m"])
def test_dense_engine_bucketing_token_parity(arch):
    cfg = _smoke(arch)
    off = InferenceEngine(
        cfg, EngineConfig(max_slots=2, max_len=64, max_new_tokens=4, bucket_prefill=False)
    )
    a = off.generate(PROMPTS)
    on = InferenceEngine(
        cfg,
        EngineConfig(max_slots=2, max_len=64, max_new_tokens=4, bucket_unit=8),
        params=off.params,
    )
    b = on.generate(PROMPTS)
    assert [s.out for s in a] == [s.out for s in b]
    assert on.compile_events <= num_buckets(8, 64)


def test_paged_engine_bucketing_token_parity_with_preemption():
    """Bucketed paged prefill must reproduce unbucketed tokens exactly, even
    when page exhaustion forces preemption-resume (resume contexts hit
    different buckets than the original prompts)."""
    cfg = _smoke("smollm-360m")
    prompts = [[1, 2, 3, 4], [5, 6, 7, 8], [9, 10, 11, 12], [2, 4, 6, 1]]
    pc = dict(page_size=4, num_pages=10, max_slots=4, max_seq_len=32, max_new_tokens=8)
    off = PagedInferenceEngine(cfg, PagedEngineConfig(bucket_prefill=False, **pc))
    a = off.generate(prompts)
    on = PagedInferenceEngine(cfg, PagedEngineConfig(**pc), params=off.params)
    b = on.generate(prompts)
    assert on.preemptions > 0                      # resume path exercised
    assert [s.out for s in a] == [s.out for s in b]
    on.allocator.check_invariants()
    assert on.allocator.used_pages == 0


# ---------------------------------------------------------------------------
# Compile-count regression: O(#buckets), not O(#lengths)
# ---------------------------------------------------------------------------


def _sweep(eng, lengths, vocab):
    for L in lengths:
        eng.submit([1 + (i % (vocab - 1)) for i in range(L)])
    eng.generate([])


def test_prefill_compilations_bounded_by_buckets():
    """>= 16 distinct context lengths on each engine must compile prefill at
    most ceil(log2(cap/unit)) + 1 times (the acceptance bound)."""
    cfg = _smoke("smollm-360m")
    lengths = list(range(1, 17))                   # 16 distinct lengths
    bound = num_buckets(4, 32)                     # 4, 8, 16, 32 -> 4
    assert bound == 4

    paged = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=33, max_slots=2, max_seq_len=32,
                          max_new_tokens=1),
    )
    _sweep(paged, lengths, cfg.vocab_size)
    dense = InferenceEngine(
        cfg,
        EngineConfig(max_slots=2, max_len=32, max_new_tokens=1, bucket_unit=4),
        params=paged.params,
    )
    _sweep(dense, lengths, cfg.vocab_size)

    for eng in (paged, dense):
        assert eng.compile_events <= bound, eng._prefill_shapes
        assert eng._prefill_shapes <= {4, 8, 16, 32}
        assert eng.capacity_now()["compile_events"] == eng.compile_events
        # cross-check against the actual jit cache when this JAX exposes it
        cache_size = getattr(eng._prefill, "_cache_size", None)
        if cache_size is not None:
            assert cache_size() <= bound


def test_unbucketed_engine_compiles_per_length():
    """Control: with bucketing off the tracked shape count grows with every
    distinct length — the churn this refactor exists to remove."""
    cfg = _smoke("smollm-360m")
    eng = PagedInferenceEngine(
        cfg,
        PagedEngineConfig(page_size=4, num_pages=33, max_slots=2, max_seq_len=32,
                          max_new_tokens=1, bucket_prefill=False),
    )
    _sweep(eng, [3, 5, 9, 11], cfg.vocab_size)
    assert eng.compile_events == 4
