"""Algorithm 1 unit + property tests (hypothesis)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis_fallback import given, settings, st

from repro.core import (
    PlacementDecision,
    Request,
    StraightLinePolicy,
    Thresholds,
    Tier,
    placing_batch_jax,
)


def req(rid=0, size=1e5):
    return Request(rid=rid, arrival_t=0.0, data_size=size)


POL = StraightLinePolicy(Thresholds(F=1000, D=1e6))


def test_line3_burst_small_payload_goes_serverless():
    d = POL.place(req(size=1e5), f_t=2000, flask_free=5, docker_free=5)
    assert d.tier == Tier.SERVERLESS


def test_line6_large_payload_goes_docker_even_in_burst():
    d = POL.place(req(size=5e6), f_t=2000, flask_free=5, docker_free=5)
    assert d.tier == Tier.DOCKER


def test_line10_moderate_goes_flask_when_available():
    d = POL.place(req(size=1e5), f_t=100, flask_free=1, docker_free=5)
    assert d.tier == Tier.FLASK


def test_line14_flask_exhausted_goes_docker():
    d = POL.place(req(size=1e5), f_t=100, flask_free=0, docker_free=1)
    assert d.tier == Tier.DOCKER


def test_line18_everything_busy_goes_serverless():
    d = POL.place(req(size=1e5), f_t=100, flask_free=0, docker_free=0)
    assert d.tier == Tier.SERVERLESS


def test_warmup_gap_without_cost_prefers_warmer_tier():
    """Bare-float warmup entries (no measured compile cost): the original
    warm-preference behavior — a colder flask loses to a warmer docker."""
    warm = {Tier.FLASK: 0.25, Tier.DOCKER: 1.0}
    d = POL.place(req(size=1e5), f_t=100, flask_free=1, docker_free=1, warmup=warm)
    assert d.tier == Tier.DOCKER


def test_warmup_gap_cheaper_than_tier_hop_is_ignored():
    """Measured compile cost below the hop price: the warmth gap is not
    worth leaving the interactive tier (a one-bucket gap on a tiny model)."""
    pol = StraightLinePolicy(Thresholds(F=1000, D=1e6), hop_cost_s=0.05)
    warm = {
        Tier.FLASK: {"warmth": 0.75, "compile_cost_s": 0.1},  # E[stall] = 0.025
        Tier.DOCKER: 1.0,
    }
    d = pol.place(req(size=1e5), f_t=100, flask_free=1, docker_free=1, warmup=warm)
    assert d.tier == Tier.FLASK


def test_warmup_gap_with_expensive_compiles_still_hops():
    """Same warmth gap but heavyweight compiles: E[stall] = (1-0.75)*10s
    dwarfs the hop price, so the warmer batch tier wins."""
    pol = StraightLinePolicy(Thresholds(F=1000, D=1e6), hop_cost_s=0.05)
    warm = {
        Tier.FLASK: {"warmth": 0.75, "compile_cost_s": 10.0},
        Tier.DOCKER: 1.0,
    }
    d = pol.place(req(size=1e5), f_t=100, flask_free=1, docker_free=1, warmup=warm)
    assert d.tier == Tier.DOCKER


def test_place_all_consumes_availability():
    reqs = [req(rid=i, size=1e5) for i in range(5)]
    ds = POL.place_all(reqs, f_t=100, flask_free=2, docker_free=2)
    tiers = [d.tier for d in ds]
    assert tiers[:2] == [Tier.FLASK, Tier.FLASK]
    assert tiers[2:4] == [Tier.DOCKER, Tier.DOCKER]
    assert tiers[4] == Tier.SERVERLESS


@given(
    f_t=st.floats(0, 1e4),
    sizes=st.lists(st.floats(1.0, 1e8), min_size=1, max_size=40),
    flask_free=st.integers(0, 10),
    docker_free=st.integers(0, 10),
)
@settings(max_examples=60, deadline=None)
def test_every_request_placed_on_valid_tier(f_t, sizes, flask_free, docker_free):
    reqs = [req(rid=i, size=s) for i, s in enumerate(sizes)]
    ds = POL.place_all(reqs, f_t, flask_free, docker_free)
    assert len(ds) == len(reqs)                       # exactly one decision each
    assert {d.rid for d in ds} == set(range(len(reqs)))
    for d, r in zip(ds, reqs):
        assert d.tier in (Tier.FLASK, Tier.DOCKER, Tier.SERVERLESS)
        # faithful threshold semantics
        if f_t > POL.th.F and r.data_size < POL.th.D:
            assert d.tier == Tier.SERVERLESS
        elif r.data_size > POL.th.D:
            assert d.tier == Tier.DOCKER
    assert sum(d.tier == Tier.FLASK for d in ds) <= flask_free


@given(
    f_t=st.floats(0, 1e4),
    sizes=st.lists(st.floats(1.0, 1e8), min_size=1, max_size=32),
    flask_free=st.integers(0, 8),
    docker_free=st.integers(0, 8),
)
@settings(max_examples=40, deadline=None)
def test_vectorized_jax_matches_python_loop(f_t, sizes, flask_free, docker_free):
    reqs = [req(rid=i, size=s) for i, s in enumerate(sizes)]
    ds = POL.place_all(reqs, f_t, flask_free, docker_free)
    got = placing_batch_jax(
        jnp.float32(f_t),
        jnp.asarray(sizes, jnp.float32),
        jnp.int32(flask_free),
        jnp.int32(docker_free),
        F=POL.th.F,
        D=POL.th.D,
    )
    assert [int(t) for t in got] == [int(d.tier) for d in ds]


def test_adaptive_thresholds_move_with_utilization():
    from repro.core.placing import AdaptiveThresholds

    at = AdaptiveThresholds(Thresholds(F=1200, D=1e6), interactive_capacity_rps=7.0)
    th_idle = at.update(0.1, docker_service_s=0.8, flask_service_s=0.15)
    f_idle = th_idle.F
    for _ in range(30):
        th_busy = at.update(1.0, docker_service_s=0.8, flask_service_s=0.15)
    assert th_busy.F < f_idle           # saturated interactive => lower F
    assert th_busy.D > 0


def test_slo_aware_policy_picks_cheapest_meeting_slo():
    from repro.core.placing import SLOAwarePolicy

    models = {
        Tier.FLASK: lambda r, f: 0.2,
        Tier.DOCKER: lambda r, f: 0.8,
        Tier.SERVERLESS: lambda r, f: 0.5,
    }
    pol = SLOAwarePolicy(models, cost=(1.0, 0.6, 0.3))
    r = req(size=1e5)
    r.slo_s = 0.6
    d = pol.place(r, f_t=10, flask_free=1, docker_free=1)
    assert d.tier == Tier.SERVERLESS    # cheapest meeting 0.6 s
    r.slo_s = 0.3
    d = pol.place(r, f_t=10, flask_free=1, docker_free=1)
    assert d.tier == Tier.FLASK         # only flask meets 0.3 s
