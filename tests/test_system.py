"""End-to-end behaviour tests for the StraightLine system."""
import numpy as np
import pytest

from repro.core import (
    SimConfig,
    Simulation,
    StaticPolicy,
    StraightLinePolicy,
    Tier,
)
from repro.core.testbed import paper_tiers
from repro.core.workload import burst, ramp


def run(policy, load, mem="3GB", seed=1, **sim_kw):
    sim = Simulation(policy, paper_tiers(seed=seed, elastic_mem=mem), SimConfig(**sim_kw))
    return sim.run(ramp(load, seed=load)).summary()


def test_all_requests_accounted_for():
    sim = Simulation(StraightLinePolicy(), paper_tiers(seed=0), SimConfig())
    reqs = ramp(500, seed=3)
    m = sim.run(reqs)
    assert m.total == len(reqs)            # conservation: no lost requests
    assert all(r.finish_t is not None for r in m.completed)


def test_interactive_tier_saturates_at_paper_knee():
    """Paper Fig 4: failure knee ~1200-1300 sessions/180 s on Flask."""
    low = run(StaticPolicy(Tier.FLASK), 800)
    knee = run(StaticPolicy(Tier.FLASK), 1400)
    high = run(StaticPolicy(Tier.FLASK), 2000)
    assert low["failure_rate"] < 0.05
    assert knee["failure_rate"] > 0.15
    assert high["failure_rate"] > knee["failure_rate"]


def test_interactive_fastest_at_low_load():
    """Paper Fig 8: Flask beats Docker and Lambda on response time."""
    f = run(StaticPolicy(Tier.FLASK), 200)
    d = run(StaticPolicy(Tier.DOCKER), 200)
    s = run(StaticPolicy(Tier.SERVERLESS), 200)
    assert f["median_response_s"] < d["median_response_s"]
    assert f["median_response_s"] < s["median_response_s"]


def test_elastic_tier_flat_latency_under_load():
    """Paper Fig 5b/c: Lambda median response barely moves with load."""
    lo = run(StaticPolicy(Tier.SERVERLESS), 500)
    hi = run(StaticPolicy(Tier.SERVERLESS), 5000)
    assert hi["median_response_s"] < 2.0 * lo["median_response_s"]


def test_elastic_memory_class_failure_ordering():
    """Paper Fig 5a: failed rate drops when memory goes 2 GB -> 3 GB."""
    two = run(StaticPolicy(Tier.SERVERLESS), 6000, mem="2GB")
    three = run(StaticPolicy(Tier.SERVERLESS), 6000, mem="3GB")
    assert two["failure_rate"] > 0.25          # paper: up to ~60%
    assert three["failure_rate"] < two["failure_rate"] * 0.5


@pytest.mark.parametrize("load,bound", [(1400, 0.05), (4000, 0.05), (6000, 0.15)])
def test_straightline_beats_every_static_policy(load, bound):
    """The paper's headline: resource-aware placement reduces failure rate
    and response time vs any single platform. At 6000 sessions even the best
    static tier fails ~46%; StraightLine stays under 15% (elastic-contention
    spillover it cannot see — the SLO-aware variant addresses this)."""
    sl = run(StraightLinePolicy(), load)
    for tier in Tier:
        st = run(StaticPolicy(tier), load, mem="2GB" if tier == Tier.SERVERLESS else "3GB")
        assert sl["failure_rate"] <= st["failure_rate"] + 1e-9
    assert sl["failure_rate"] < bound


def test_large_payloads_route_to_batch_tier():
    sim = Simulation(StraightLinePolicy(), paper_tiers(seed=0), SimConfig())
    reqs = ramp(300, dist="image-hires", seed=5)
    m = sim.run(reqs)
    placed = [r.tier for r in m.completed + m.failed]
    assert placed.count(Tier.DOCKER) > 0.9 * len(placed)   # r_d > D => docker


def test_hedging_reduces_tail_latency_under_overload():
    base = run(StraightLinePolicy(), 3000)
    hedged = run(StraightLinePolicy(), 3000, hedge_after_s=2.0)
    assert hedged["p95_response_s"] <= base["p95_response_s"] + 0.5


def test_burst_absorbed_by_elastic_tier():
    sim = Simulation(StraightLinePolicy(), paper_tiers(seed=0), SimConfig())
    reqs = burst(background_rate=2.0, burst_rate=120.0, burst_at_s=60, burst_len_s=20, seed=7)
    m = sim.run(reqs)
    assert m.failure_rate < 0.05
    tiers = [r.tier for r in m.completed]
    assert tiers.count(Tier.SERVERLESS) > 0    # burst overflowed to elastic


def test_retry_on_failure_lowers_failure_rate():
    plain = run(StaticPolicy(Tier.FLASK), 2500)
    retried = run(StaticPolicy(Tier.FLASK), 2500, retry_failed_on_elastic=True)
    assert retried["failure_rate"] < plain["failure_rate"]


def test_autoscaler_prewarming_cuts_cold_starts():
    from repro.core.autoscaler import Autoscaler

    reqs = burst(background_rate=1.0, burst_rate=80.0, burst_at_s=90, burst_len_s=15, seed=9)
    cold = Simulation(StaticPolicy(Tier.SERVERLESS), paper_tiers(seed=2), SimConfig()).run(
        [r for r in reqs]
    ).summary()
    reqs2 = burst(background_rate=1.0, burst_rate=80.0, burst_at_s=90, burst_len_s=15, seed=9)
    warm = Simulation(
        StaticPolicy(Tier.SERVERLESS), paper_tiers(seed=2),
        SimConfig(autoscaler=Autoscaler()),
    ).run(reqs2).summary()
    assert warm["p95_response_s"] <= cold["p95_response_s"] + 1e-9
