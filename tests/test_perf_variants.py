"""Perf-variant features must preserve semantics (EXPERIMENTS.md §Perf)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.launch.steps import make_train_step
from repro.models import get_model
from repro.models.quant import qeinsum, quantize_params, quantize_weight
from repro.train.optimizer import OptConfig, init_opt_state


def test_microbatch_grad_accumulation_matches_full_batch():
    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=16, ce_chunks=2)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ocfg = OptConfig(lr=1e-3)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 16), 0, cfg.vocab_size),
    }
    p1, _, m1 = make_train_step(model, None, ocfg)(params, init_opt_state(params, ocfg), batch)
    p2, _, m2 = make_train_step(model, None, ocfg, microbatches=2)(
        params, init_opt_state(params, ocfg), batch
    )
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-2
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        # bf16 grad accumulation: small quantization differences allowed
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=5e-3
        )


def test_qeinsum_matches_fp_within_quant_error():
    w = jax.random.normal(jax.random.PRNGKey(0), (64, 96), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 64), jnp.float32)
    qw = quantize_weight(w)
    got = qeinsum("bd,df->bf", x, qw)
    want = x @ w
    rel = float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))
    assert rel < 0.02


def test_weight_int8_engine_greedy_parity():
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_config("smollm-360m", smoke=True).replace(attn_chunk=64)
    base = InferenceEngine(cfg, EngineConfig(max_slots=1, max_len=48, max_new_tokens=5))
    qcfg = cfg.replace(weights_int8=True)
    quant = InferenceEngine(
        qcfg, EngineConfig(max_slots=1, max_len=48, max_new_tokens=5),
        params=quantize_params(base.params),
    )
    s0 = base.generate([[1, 2, 3, 4, 5]])[0]
    s1 = quant.generate([[1, 2, 3, 4, 5]])[0]
    # int8 noise may flip a near-tie deep into generation on random weights;
    # the prefix must match (and quant.py's logits-level bound is tested above)
    assert s0.out[:3] == s1.out[:3]


def test_scores_bf16_close_to_f32():
    cfg = get_config("glm4-9b", smoke=True).replace(attn_chunk=8)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
    }
    l0, _ = model.loss(None, params, batch)
    l1, _ = get_model(cfg.replace(attn_scores_bf16=True)).loss(None, params, batch)
    assert abs(float(l0) - float(l1)) < 5e-2


def test_seq_shard_flag_is_noop_on_single_device():
    cfg = get_config("granite-8b", smoke=True).replace(attn_chunk=8)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0, cfg.vocab_size),
    }
    l0, _ = model.loss(None, params, batch)
    l1, _ = get_model(cfg.replace(seq_shard_activations=True)).loss(None, params, batch)
    assert float(l0) == float(l1)
