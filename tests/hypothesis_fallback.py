"""Import shim: when ``hypothesis`` is missing, property tests degrade to
individual skips instead of taking the whole module down with them — the
plain unit tests sharing those modules (placing, telemetry, MoE) must always
run. Import ``given``/``settings``/``st`` from here, never from hypothesis
directly."""
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        return lambda f: f

    class _AnyStrategy:
        """Stands in for ``hypothesis.strategies``: every attribute is a
        constructor returning None, enough to evaluate @given(...) args."""

        def __getattr__(self, _name):
            return lambda *a, **k: None

    st = _AnyStrategy()
