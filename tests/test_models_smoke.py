"""Per-arch smoke: reduced config forward + train step on CPU, no NaNs.

Covers all 10 assigned architectures (deliverable f) — each SMOKE config is
a structurally faithful reduction of the FULL config (same family, pattern,
norm, gating)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import get_config, list_archs
from repro.models import get_model

B, S = 2, 16


def make_batch(cfg, rng):
    batch = {
        "tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(rng, (B, S), 0, cfg.vocab_size),
    }
    if cfg.inputs == "embeds":
        batch = {
            "inputs_embeds": jax.random.normal(rng, (B, S, cfg.d_model), jnp.float32),
            "positions": jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (3, B, S)).copy(),
            "labels": batch["labels"],
        }
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(rng, (B, cfg.encoder.n_ctx, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)

    def lf(p):
        loss, metrics = model.loss(None, p, batch)
        return loss, metrics

    (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
    assert jnp.isfinite(loss), (arch, loss)
    assert float(metrics["tokens"]) == B * S
    gnorm = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.abs(g.astype(jnp.float32))), grads, 0.0
    )
    assert jnp.isfinite(gnorm), arch


@pytest.mark.parametrize("arch", list_archs())
def test_arch_smoke_prefill_decode_shapes(arch):
    cfg = get_config(arch, smoke=True)
    model = get_model(cfg)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    batch = make_batch(cfg, rng)
    batch.pop("labels")
    tok, cache = model.prefill(None, params, batch, cap=S + 4)
    assert tok.shape == (B,) and tok.dtype == jnp.int32
    tok2, cache = model.decode(
        None, params, cache, {"token": tok[:, None], "cache_index": jnp.asarray(S, jnp.int32)}
    )
    assert tok2.shape == (B,)
    assert jnp.all((tok2 >= 0) & (tok2 < cfg.vocab_size))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact published dimensions."""
    import numpy as np

    expect = {
        "xlstm-350m": dict(n_layers=24, d_model=1024, n_heads=4, d_ff=0, vocab_size=50304),
        "smollm-360m": dict(n_layers=32, d_model=960, n_heads=15, n_kv_heads=5, d_ff=2560, vocab_size=49152),
        "glm4-9b": dict(n_layers=40, d_model=4096, n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=151552),
        "granite-8b": dict(n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=49152),
        "qwen1.5-32b": dict(n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392, vocab_size=152064),
        "jamba-1.5-large-398b": dict(n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576, vocab_size=65536),
        "dbrx-132b": dict(n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752, vocab_size=100352),
        "llama4-maverick-400b-a17b": dict(n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202048),
        "qwen2-vl-2b": dict(n_layers=28, d_model=1536, n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936),
        "whisper-large-v3": dict(n_layers=32, d_model=1280, n_heads=20, d_ff=5120, vocab_size=51866),
    }
    for arch, fields in expect.items():
        cfg = get_config(arch)
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    moe = {"jamba-1.5-large-398b": (16, 2), "dbrx-132b": (16, 4), "llama4-maverick-400b-a17b": (128, 1)}
    for arch, (e, k) in moe.items():
        cfg = get_config(arch)
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (e, k)
